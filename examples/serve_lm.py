"""Serve a small LM with batched requests through Emerald remotable steps.

Continuous-batching-lite: requests queue, pack into slots, prefill once,
decode until done. Params + KV caches stay resident on the serving tier.

    PYTHONPATH=src python examples/serve_lm.py --requests 12
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeProfile, reduced
from repro.launch.serve import Request, Server
from repro.models.model_zoo import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), n_layers=4, d_model=128)
    run = RunConfig(model=cfg,
                    shape=ShapeProfile("serve", 256, args.batch, "decode"),
                    remat="none")
    params = Model(run).init_params(jax.random.PRNGKey(0))
    srv = Server(run, params)

    rng = np.random.default_rng(7)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(8, 64))).astype(np.int32)
        srv.submit(Request(rid, prompt, max_new=args.max_new))

    t0 = time.time()
    finished = []
    while srv.queue:
        batch = srv.step_batch()
        finished += batch
        print(f"batch done: {[r.rid for r in batch]} "
              f"({srv.stats['tokens_out']} tokens so far)")
    dt = time.time() - t0
    tok = srv.stats["tokens_out"]
    print(f"\n{len(finished)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s on CPU)")
    print("stats:", srv.stats)
    print("transfers:", srv.transfer_report())


if __name__ == "__main__":
    main()
