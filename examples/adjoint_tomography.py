"""End-to-end adjoint tomography — the paper's evaluation app (§4).

Runs the 4-step AT workflow (forward sim, misfit, Fréchet kernel, update)
with steps 2-4 offloaded, iterating "until the seismograms match" — and
shows the Emerald event log + MDSS transfer savings per iteration.

    PYTHONPATH=src python examples/adjoint_tomography.py [--iters 12]
"""
import argparse
import time

import jax.numpy as jnp

from repro.apps.adjoint_tomography import (ATConfig, build_workflow,
                                           make_observations, starting_model,
                                           true_model)
from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        default_tiers, partition)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--nx", type=int, default=64)
    ap.add_argument("--nt", type=int, default=150)
    ap.add_argument("--policy", default="annotate",
                    choices=["annotate", "cost_model", "never"])
    args = ap.parse_args()

    cfg = ATConfig(nx=args.nx, ny=max(args.nx // 4, 8),
                   nz=max(args.nx // 4, 8), nt=args.nt)
    print(f"mesh {cfg.mesh_name}, {cfg.nt} timesteps; policy={args.policy}")
    obs = make_observations(cfg)

    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    ex = EmeraldExecutor(partition(build_workflow(cfg)), mgr,
                         policy=args.policy)

    model = starting_model(cfg)
    chi0 = None
    t0 = time.time()
    for it in range(args.iters):
        mdss.reset_accounting()
        res = ex.run({"model": model, "obs": obs}, fetch=("model", "chi"))
        model = res["model"]
        chi = float(res["chi"])
        chi0 = chi0 or chi
        bar = "#" * max(1, int(40 * chi / chi0))
        moved = mdss.total_bytes_moved()
        print(f"iter {it:2d}  misfit {chi:10.3e}  {bar:<40s} "
              f"[{moved/1e6:6.2f} MB moved]")
    err = float(jnp.sqrt(jnp.mean((model - true_model(cfg)) ** 2)))
    print(f"\nfinal model RMS error vs true model: {err:.2f} m/s "
          f"({time.time()-t0:.1f}s total)")
    offl = [e for e in ex.events if e.kind == "offload"]
    print(f"offloads: {len(offl)} (steps 2-4 x {args.iters} iterations)")


if __name__ == "__main__":
    main()

# emlint (scripts/emlint.py) collects these for static verification
def _emlint_wf():
    from repro.apps.adjoint_tomography import ATConfig, build_workflow
    return build_workflow(ATConfig(nx=16, ny=8, nz=8, nt=10))


EMLINT_WORKFLOWS = [_emlint_wf]
