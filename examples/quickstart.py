"""Quickstart: build a cloud-offloading scientific workflow in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        Workflow, default_tiers, partition)

# 1. Declare the workflow: steps, dataflow variables, remotable annotations.
wf = Workflow("quickstart")
wf.var("signal")
wf.step("prepare", lambda signal: {"spectrum": jnp.fft.rfft(signal).real},
        inputs=("signal",), outputs=("spectrum",))
wf.step("heavy_filter",                                   # offloaded
        lambda spectrum: {"filtered": jnp.tanh(spectrum) * spectrum},
        inputs=("spectrum",), outputs=("filtered",), remotable=True)
wf.step("heavy_energy",                                   # offloaded, parallel
        lambda spectrum: {"energy": jnp.sum(spectrum ** 2)},
        inputs=("spectrum",), outputs=("energy",), remotable=True)
wf.step("report", lambda filtered, energy:
        {"summary": jnp.array([filtered.mean(), energy])},
        inputs=("filtered", "energy"), outputs=("summary",))

# 2. Partition: validates Properties 1-3, inserts migration points.
pwf = partition(wf)
print("migration points:", [m.name for m in pwf.migration_points])

# 3. Execute: remotable steps offload to the cloud tier; parallel steps
#    run concurrently; MDSS moves only stale data.
tiers = default_tiers()
cost = CostModel(tiers)
mdss = MDSS(tiers, cost_model=cost)
ex = EmeraldExecutor(partition(wf), MigrationManager(tiers, mdss, cost))
result = ex.run({"signal": jnp.linspace(0, 1, 4096)})

print("summary:", result["summary"])
print("events:")
for e in ex.events:
    print(f"  {e.kind:<8s} {e.step:<14s} {e.tier}")
print(f"bytes moved: {dict(mdss.bytes_moved)}")
print(f"modeled transfer seconds: {mdss.modeled_seconds:.6f}")
