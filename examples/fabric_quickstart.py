"""Fabric quickstart: offload workflow steps into real worker processes.

    PYTHONPATH=src python examples/fabric_quickstart.py

Where examples/quickstart.py runs every "offload" in-process, this one
attaches the Emerald offload fabric to the cloud tier: a broker
dispatches remotable steps over loopback TCP to a pool of worker
subprocesses, MDSS transfers ship real bytes through the RPCTransport,
the cost model learns the observed wire bandwidth, and an autoscaler
grows/shrinks the pool with the queue.
"""
import os
import time

import numpy as np

from repro.cloud import AutoscalerConfig, Fabric, attach
from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        Workflow, default_tiers, partition)

# 1. Register step implementations by name — every worker resolves these
#    from repro.cloud.tasklib at task time (lambdas can't cross processes).
#    Here we just use the built-in "add_one" and "matmul" steps.

# 2. Declare the workflow. `remote_impl` names the registry entry; fn=None
#    means the local fallback also resolves from the registry.
wf = Workflow("fabric_quickstart")
wf.var("a")
wf.var("b")
wf.step("multiply", None, inputs=("a", "b"), outputs=("c",),
        remotable=True, jax_step=False, remote_impl="matmul")
wf.step("norm", lambda c: {"score": np.linalg.norm(c)},
        inputs=("c",), outputs=("score",), jax_step=False)

# 3. Bring up the fabric: 2 workers now, autoscaling 1..4.
with Fabric(workers=2,
            autoscaler=AutoscalerConfig(min_workers=1, max_workers=4)) as fabric:
    tiers = default_tiers()
    cost = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cost)
    attach(tiers, fabric, mdss=mdss, cost_model=cost)   # cloud tier backed

    ex = EmeraldExecutor(partition(wf), MigrationManager(tiers, mdss, cost))
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 256)).astype(np.float32)
    result = ex.run({"a": a, "b": a})

    print(f"driver pid {os.getpid()}, worker pids {fabric.broker.worker_pids()}")
    print(f"score: {result['score']:.3f}")
    print("events:")
    for e in ex.events:
        extra = ""
        if e.kind == "offload":
            extra = (f"remote={e.info['remote']} pid={e.info['worker_pid']} "
                     f"bytes_in={e.info['bytes_in']} "
                     f"bytes_out={e.info['bytes_out']}")
        print(f"  {e.kind:<8s} {e.step:<12s} {e.tier:<6s} {extra}")
    print(f"mdss bytes moved: {dict(mdss.bytes_moved)}")
    bw = {k: f"{v / 1e6:.1f}MB/s" for k, v in cost.measured_bw.items()}
    print(f"observed wire bandwidth: {bw}")

    # 4. Elasticity: flood the broker and let the autoscaler react.
    tasks = [fabric.broker.submit(step="sleep", kwargs={"seconds": 0.2})
             for _ in range(8)]
    act = fabric.autoscaler.tick()
    print(f"autoscaler after burst: {act}")
    for t in tasks:
        t.result(30)
    time.sleep(0.1)
    print(f"workers active={fabric.broker.num_workers()} "
          f"(incl warm={fabric.broker.num_workers(include_warm=True)}), "
          f"tasks done={fabric.broker.tasks_done}, "
          f"requeued={fabric.broker.tasks_requeued}")
