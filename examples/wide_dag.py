"""Wide heterogeneous DAG through the event-driven executor.

    PYTHONPATH=src python examples/wide_dag.py

Builds the `bench_dag` shape by hand — four independent offloadable
sources with a 10:1 runtime spread, the fast sources feeding short chains
of follow-up steps, one reduce joining everything — and shows what the
completion-triggered runtime does with it: fast branches' successors
dispatch (and their inputs prefetch) while the long pole is still
running, so the makespan tracks the critical path instead of
sum-of-wave-maxima.
"""
import time

import numpy as np

from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        Workflow, critical_path_lengths, default_tiers,
                        partition)


def sleeper(name, seconds, out):
    def fn(**kw):
        time.sleep(seconds)
        return {out: np.float64(seconds)}
    return fn


# 1. Four sources: 0.05s, 0.05s, 0.05s and a 0.5s long pole. Each fast
#    source feeds a 2-deep chain; the reduce joins all tails.
wf = Workflow("wide")
wf.var("x")
tails = []
for i, dur in enumerate((0.05, 0.05, 0.05, 0.5)):
    wf.step(f"src{i}", sleeper(f"src{i}", dur, f"y{i}"), inputs=("x",),
            outputs=(f"y{i}",), remotable=True, jax_step=False)
    tail = f"y{i}"
    if dur < 0.5:
        for c in range(2):
            nm = f"mid{i}_{c}"
            wf.step(nm, sleeper(nm, 0.1, f"y_{nm}"), inputs=(tail,),
                    outputs=(f"y_{nm}",), remotable=True, jax_step=False)
            tail = f"y_{nm}"
    tails.append(tail)
wf.step("reduce", sleeper("reduce", 0.05, "y_r"), inputs=tuple(tails),
        outputs=("y_r",), remotable=True, jax_step=False)

# 2. Dispatch priorities: critical-path length first.
print("critical-path priorities (dispatch order under contention):")
for name, cpl in sorted(critical_path_lengths(wf).items(),
                        key=lambda kv: -kv[1]):
    print(f"  {name:<10s} {cpl:.1f}")

# 3. Run. Wave-barrier bound would be 0.5 + 2*0.1 + 0.05 = 0.75s; the
#    critical path (and the event-driven makespan) is 0.5 + 0.05 = 0.55s.
tiers = default_tiers()
cm = CostModel(tiers)
mdss = MDSS(tiers, cost_model=cm)
ex = EmeraldExecutor(partition(wf), MigrationManager(tiers, mdss, cm))
t0 = time.perf_counter()
ex.run({"x": np.float64(0.0)})
makespan = time.perf_counter() - t0
print(f"\nmakespan: {makespan * 1e3:.0f} ms "
      f"(critical path 550 ms, wave barrier would pay ~750 ms)")

# 4. The event log shows per-step suspend -> offload -> resume (Property 3)
#    interleaved across steps — e.g. mid0_0 resumes long before src3 does.
print("\nevent log:")
t_first = ex.events[0].t
for e in ex.events:
    if e.kind in ("suspend", "offload", "resume", "prefetch"):
        print(f"  t={1e3 * (e.t - t_first):6.0f}ms {e.kind:<9s} {e.step}")
