"""Train a ~100M-parameter LM end-to-end through the Emerald workflow
(deliverable (b): train a ~100M model for a few hundred steps).

The training loop is the workflow; ``train_step`` is remotable; params and
optimizer state live on the cloud tier between steps (code-only offloads).
Checkpoints save locally every 50 steps and the run is resumable.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

from repro.configs.base import ModelConfig, RunConfig, ShapeProfile
from repro.launch.train import Trainer

# ~100M params: 2*V*d + L*(4*d^2 + 3*d*ff) = 2*32000*512 + 12*(1M + 2.4M)
MODEL_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=32000,
    dtype="float32", param_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/emerald-lm-100m")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--policy", default="annotate")
    args = ap.parse_args()

    run = RunConfig(model=MODEL_100M,
                    shape=ShapeProfile("train", args.seq, args.batch, "train"),
                    remat="none", learning_rate=args.lr)
    tr = Trainer(run, policy=args.policy, ckpt_dir=args.ckpt_dir,
                 ckpt_every=50)
    import jax
    import numpy as np
    n = sum(int(np.prod(s.shape))
            for s in jax.tree.leaves(tr.model.abstract_params()))
    print(f"model: {n/1e6:.1f}M params; {args.steps} steps "
          f"of {args.batch}x{args.seq} tokens")
    tr.fit(args.steps, resume=args.resume, log_every=10)
    print("transfer report:", tr.transfer_report())
    tr.close()


if __name__ == "__main__":
    main()
