"""Two heterogeneous tenants — adjoint tomography + an LM scorer — on ONE
long-lived EmeraldRuntime.

The paper runs one workflow at a time; the multi-tenant runtime amortises
the expensive parts (lanes, compile caches, cloud-resident data) across
concurrent submissions:

  * the **AT tenant** iterates the 4-step inversion in its own MDSS
    namespace ``at`` — the updated model stays resident there between
    iterations, so every iteration after the first offloads code-only,
  * the **LM tenant** scores prompt batches against params published once
    to the *shared* namespace — every LM submission reads the same
    cloud-resident copy; submissions carry an interactive priority class
    and a higher fair-share weight,
  * both tenants interleave over the same lane pair: the runtime grants
    each free slot to the run with the smallest deficit-weighted share,
    so the wide AT iterations cannot starve the LM requests.

    PYTHONPATH=src python examples/multi_tenant.py [--at-iters 6]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.adjoint_tomography import (ATConfig, build_workflow,
                                           make_observations, starting_model)
from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeProfile, reduced
from repro.core import (CostModel, EmeraldRuntime, MDSS, MigrationManager,
                        Workflow, default_tiers)
from repro.models.model_zoo import Model


def build_lm_workflow(model):
    """Score a prompt batch: remotable prefill + local argmax readout."""
    prefill = model.prefill

    def score(params, batch, cache):
        logits, _ = prefill(params, batch, cache)
        return {"logits": logits}

    def readout(logits):
        return {"top": jnp.argmax(logits, -1)}

    wf = Workflow("lm-score")
    for v in ("params", "batch", "cache"):
        wf.var(v)
    wf.step("score", score, inputs=("params", "batch", "cache"),
            outputs=("logits",), remotable=True)
    wf.step("readout", readout, inputs=("logits",), outputs=("top",))
    return wf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--at-iters", type=int, default=6)
    ap.add_argument("--lm-requests", type=int, default=6)
    ap.add_argument("--nx", type=int, default=48)
    args = ap.parse_args()

    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)

    # --- tenant setup -----------------------------------------------------
    at_cfg = ATConfig(nx=args.nx, ny=max(args.nx // 4, 8),
                      nz=max(args.nx // 4, 8), nt=100)
    at_wf = build_workflow(at_cfg)              # built once, submitted N times

    lm_cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2, d_model=64,
                     d_ff=128)
    lm_run = RunConfig(model=lm_cfg, shape=ShapeProfile("mt", 64, 2, "decode"),
                       remat="none")
    lm_model = Model(lm_run)
    lm_wf = build_lm_workflow(lm_model)
    rng = np.random.default_rng(0)

    with EmeraldRuntime(mgr, max_workers=6, name="multi-tenant") as rt:
        # warm cross-run data: published ONCE into the shared namespace,
        # read by every submission, cloud-resident after the first offload
        rt.publish("obs", make_observations(at_cfg))
        rt.publish("params", lm_model.init_params(jax.random.PRNGKey(0)))
        rt.publish("cache", lm_model.init_cache())

        t0 = time.time()
        # seed the AT namespace with the starting model; later iterations
        # read the previous update straight from namespace residency
        at_handle = rt.submit(at_wf, {"model": starting_model(at_cfg)},
                              namespace="at", fetch=("chi",))
        lm_handles, at_done, chis = [], 0, []
        for j in range(args.lm_requests):
            batch = {"tokens": jnp.asarray(rng.integers(
                0, lm_cfg.vocab_size, (2, 16)).astype(np.int32))}
            # interactive class + double fair-share weight: LM requests
            # overtake the batch AT tenant under lane contention
            lm_handles.append(rt.submit(lm_wf, {"batch": batch},
                                        weight=2.0, priority=1,
                                        fetch=("top",)))
            if at_handle.done():
                chis.append(float(at_handle.result()["chi"]))
                at_done += 1
                if at_done < args.at_iters:
                    at_handle = rt.submit(at_wf, {}, namespace="at",
                                          fetch=("chi",))
        while at_done < args.at_iters:
            chis.append(float(at_handle.result(300)["chi"]))
            at_done += 1
            if at_done < args.at_iters:
                at_handle = rt.submit(at_wf, {}, namespace="at",
                                      fetch=("chi",))
        tops = [h.result(300)["top"] for h in lm_handles]
        dt = time.time() - t0

        # --- report -------------------------------------------------------
        print(f"{at_done} AT iterations + {len(tops)} LM scores in {dt:.1f}s "
              f"on one runtime ({rt.runs_completed} runs)")
        print(f"AT misfit: {chis[0]:.3e} -> {chis[-1]:.3e}")
        print(f"LM top tokens (req 0): {np.asarray(tops[0]).ravel()[:8]}")
        print(f"compile-cache hits across runs: {mgr.compile_cache_hits}")
        for ns in ("shared", "at"):
            print(f"namespace {ns!r}: {len(mdss.namespace_entries(ns))} "
                  f"entries, {mdss.namespace_bytes(ns) / 1e6:.2f} MB moved")
        lm_ns_bytes = sum(v for k, v in mdss.ns_bytes_moved.items()
                          if k.startswith("run"))
        print(f"per-LM-run namespaces moved {lm_ns_bytes / 1e6:.2f} MB total "
              f"(params/cache stayed shared + resident)")


if __name__ == "__main__":
    main()

# emlint (scripts/emlint.py) collects these for static verification
def _emlint_wf():
    import types
    stub = types.SimpleNamespace(prefill=lambda params, batch, cache:
                                 (None, None))
    return build_lm_workflow(stub)


EMLINT_WORKFLOWS = [_emlint_wf]
