"""Unified LM assembler for every assigned architecture family.

The per-layer block types produced by ``ModelConfig.block_type`` are
compressed into *stages* ``(pattern, repeats)``; parameters of a stage are
stacked along a leading ``repeats`` axis and the stage runs under
``jax.lax.scan`` (compact HLO — a hard requirement for compiling full-size
configs against 512 fake devices on this container; see DESIGN.md).

Modes:
  * ``full``    — train forward over a whole sequence (no cache),
  * ``prefill`` — full forward that also fills decode caches,
  * ``decode``  — one token against caches.

Encoder-decoder (seamless) adds an encoder stack + cross-attention; VLM /
audio frontends are embedding stubs per the assignment spec.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_DENSE, ATTN_MOE, MAMBA_DENSE, MAMBA_MOE,
                                MAMBA_ONLY, ModelConfig, RunConfig)
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models.layers import (adt, embed, embed_template, lm_logits, mlp,
                                 mlp_template, rmsnorm, rmsnorm_template,
                                 xent_loss)
from repro.models.params import ParamSpec, abstract_params, init_params, logical_axes
from repro.models.params import stack_specs
from repro.parallel.sharding import constrain


def _has_attn(bt: str) -> bool:
    return bt in (ATTN_DENSE, ATTN_MOE)


def _has_moe(bt: str) -> bool:
    return bt in (ATTN_MOE, MAMBA_MOE)


def _has_mlp(bt: str) -> bool:
    return bt != MAMBA_ONLY


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder only): GQA projections, no RoPE.
# ---------------------------------------------------------------------------

def xattn_template(cfg: ModelConfig) -> dict:
    return attn.gqa_template(cfg)


def xattn_full(cfg, p, x, enc_out, rules, cache=None):
    from repro.kernels.flash_attention import ops as fops
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    o = fops.flash_attention(q, k, v, scale=cfg.hdim ** -0.5, causal=False)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cache is not None:
        cache = dict(cache, xk=k.astype(cache["xk"].dtype),
                     xv=v.astype(cache["xv"].dtype))
    return out, cache


def xattn_decode(cfg, p, x, cache, rules):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = cache["xk"].astype(q.dtype), cache["xv"].astype(q.dtype)
    o = attn.attend(q, k, v, q_pos=jnp.zeros((1,), jnp.int32),
                    kv_len=k.shape[1], scale=cfg.hdim ** -0.5,
                    rules=rules, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), cache


# ---------------------------------------------------------------------------
# Block template / apply
# ---------------------------------------------------------------------------

def block_template(cfg: ModelConfig, bt: str, *, cross: bool = False) -> dict:
    d = cfg.d_model
    t: Dict[str, Any] = {"ln1": rmsnorm_template(d)}
    if _has_attn(bt):
        t["attn"] = attn.attn_template(cfg)
    else:
        t["mixer"] = mam.mamba_template(cfg)
    if cross:
        t["ln_x"] = rmsnorm_template(d)
        t["xattn"] = xattn_template(cfg)
    if _has_mlp(bt):
        t["ln2"] = rmsnorm_template(d)
        t["moe" if _has_moe(bt) else "mlp"] = (
            moe_mod.moe_template(cfg) if _has_moe(bt) else mlp_template(cfg))
    return t


def block_cache_spec(cfg: ModelConfig, bt: str, batch: int, seq: int,
                     *, cross: bool = False, enc_len: int = 0):
    val: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    if _has_attn(bt):
        v, a = attn.attn_cache_spec(cfg, batch, seq)
        val.update(v), axes.update(a)
    else:
        v, a = mam.mamba_cache_spec(cfg, batch, seq)
        val.update(v), axes.update(a)
    if cross:
        kvp, hd = cfg.kv_heads_padded, cfg.hdim
        dt = jnp.dtype(cfg.dtype)
        val["xk"] = jax.ShapeDtypeStruct((batch, enc_len, kvp, hd), dt)
        val["xv"] = jax.ShapeDtypeStruct((batch, enc_len, kvp, hd), dt)
        axes["xk"] = ("act_batch", None, "act_kv_heads", None)
        axes["xv"] = ("act_batch", None, "act_kv_heads", None)
    return val, axes


def block_apply(cfg: ModelConfig, run: RunConfig, bt: str, p, x, rules, *,
                mode: str, cache=None, enc_out=None, causal: bool = True):
    """Returns (x, cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(cfg, p["ln1"], x)
    if _has_attn(bt):
        if mode == "decode":
            a, cache = attn.attn_decode(cfg, p["attn"], h, cache, rules)
        else:
            a, cache = attn.attn_full(cfg, p["attn"], h, rules,
                                      cache=cache if mode == "prefill" else None,
                                      causal=causal)
    else:
        if mode == "decode":
            a, cache = mam.mamba_decode(cfg, p["mixer"], h, cache, rules)
        else:
            a, cache = mam.mamba_full(cfg, p["mixer"], h, rules,
                                      cache=cache if mode == "prefill" else None,
                                      chunk=run.ssm_chunk,
                                      scan_dtype=run.ssm_scan_dtype)
    x = x + a
    if "xattn" in p:
        h = rmsnorm(cfg, p["ln_x"], x)
        if mode == "decode":
            xa, cache = xattn_decode(cfg, p["xattn"], h, cache, rules)
        else:
            xa, cache = xattn_full(cfg, p["xattn"], h, enc_out, rules,
                                   cache=cache if mode == "prefill" else None)
        x = x + xa
    if _has_mlp(bt):
        h = rmsnorm(cfg, p["ln2"], x)
        if _has_moe(bt):
            moe_fn = {"sort": moe_mod.moe, "manual_ep": moe_mod.moe_manual_ep,
                      "gshard": moe_mod.moe_gshard}[run.moe_impl]
            m, aux = moe_fn(cfg, p["moe"], h, rules)
        else:
            m = mlp(cfg, p["mlp"], h, rules)
        x = x + m
    x = constrain(x, rules, "act_batch", None, None)
    return x, cache, aux


# ---------------------------------------------------------------------------
# Whole-model template
# ---------------------------------------------------------------------------

def model_template(cfg: ModelConfig) -> dict:
    t: Dict[str, Any] = {"embed": embed_template(cfg)}
    cross = cfg.is_encoder_decoder
    for si, (pattern, reps) in enumerate(cfg.stages()):
        stage = {f"pos_{j}": block_template(cfg, bt, cross=cross)
                 for j, bt in enumerate(pattern)}
        t[f"stage_{si}"] = stack_specs(stage, reps)
    t["final_norm"] = rmsnorm_template(cfg.d_model)
    if cfg.is_encoder_decoder:
        enc = {"pos_0": block_template(cfg, ATTN_DENSE)}
        t["enc_stage"] = stack_specs(enc, cfg.n_encoder_layers)
        t["enc_norm"] = rmsnorm_template(cfg.d_model)
    if cfg.mtp:
        t["mtp_proj"] = ParamSpec((2 * cfg.d_model, cfg.d_model),
                                  ("embed", "embed"), fan_in_axis=0)
        t["mtp_block"] = block_template(cfg, ATTN_DENSE)
        t["mtp_norm"] = rmsnorm_template(cfg.d_model)
    return t


# ---------------------------------------------------------------------------
# Stage runners (scan over stacked repeats)
# ---------------------------------------------------------------------------

def _remat(run: RunConfig, fn):
    if run.remat == "none":
        return fn
    if run.remat == "dots_saveable":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def run_stages(cfg, run, params, x, rules, *, mode, caches=None, enc_out=None,
               causal=True, prefix="stage"):
    """Scan every stage. Returns (x, new_caches, aux_sum)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None
    stages = cfg.stages() if prefix == "stage" else ((("enc",), cfg.n_encoder_layers),)
    for si, (pattern, reps) in enumerate(stages):
        key = f"{prefix}_{si}" if prefix == "stage" else "enc_stage"
        sp = params[key]
        c_in = caches.get(key) if caches is not None else None

        def body(carry, xs, _pattern=pattern):
            xx = carry
            lp, lc = xs
            aux = jnp.zeros((), jnp.float32)
            c_out = {} if lc is not None else None
            for j, bt in enumerate(_pattern):
                bt_eff = ATTN_DENSE if bt == "enc" else bt
                pj = lp[f"pos_{j}"]
                cj = lc[f"pos_{j}"] if lc is not None else None
                xx, cj, a = block_apply(
                    cfg, run, bt_eff, pj, xx, rules, mode=mode, cache=cj,
                    enc_out=enc_out, causal=causal)
                aux = aux + a
                if c_out is not None:
                    c_out[f"pos_{j}"] = cj
            return xx, (aux, c_out)

        body = _remat(run, body)
        xs = (sp, c_in)
        unroll = run.unroll_factor if run.unroll_stage == key else run.scan_unroll
        x, (auxs, c_outs) = jax.lax.scan(body, x, xs, unroll=unroll)
        aux_total = aux_total + jnp.sum(auxs)
        if new_caches is not None:
            new_caches[key] = c_outs
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Input embedding front (tokens + optional frontend stub prefix)
# ---------------------------------------------------------------------------

def embed_inputs(cfg, params, batch, rules):
    x = embed(cfg, params["embed"], batch["tokens"], rules)
    if cfg.frontend and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    return x


def encode(cfg, run, params, batch, rules):
    """Encoder stack over stub frame embeddings (seamless)."""
    x = batch["encoder_embeds"].astype(adt(cfg))
    x, _, _ = run_stages(cfg, run, params, x, rules, mode="full",
                         causal=False, prefix="enc")
    return rmsnorm(cfg, params["enc_norm"], x)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward_train(cfg: ModelConfig, run: RunConfig, params, batch, rules):
    """batch: tokens (B,S[-F]), labels (B,S_total-1 aligned), optional stubs.

    Returns (loss, metrics).
    """
    enc_out = encode(cfg, run, params, batch, rules) if cfg.is_encoder_decoder else None
    x = embed_inputs(cfg, params, batch, rules)
    x, _, aux = run_stages(cfg, run, params, x, rules, mode="full",
                           enc_out=enc_out)
    x = rmsnorm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params["embed"], x, rules)

    n_front = batch.get("frontend_embeds").shape[1] if (
        cfg.frontend and "frontend_embeds" in batch) else 0
    # next-token loss over token positions (frontend prefix excluded)
    tok_logits = logits[:, n_front:, :]
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    loss = xent_loss(cfg, tok_logits[:, :-1], labels[:, 1:],
                     None if mask is None else mask[:, 1:])
    metrics = {"xent": loss, "aux": aux}
    if cfg.mtp:
        emb_next = embed(cfg, params["embed"],
                         jnp.pad(labels[:, 1:], ((0, 0), (0, 1))), rules)
        h = jnp.concatenate([rmsnorm(cfg, params["mtp_norm"], x[:, n_front:]),
                             emb_next], axis=-1) @ params["mtp_proj"]
        h, _, _ = block_apply(cfg, run, ATTN_DENSE, params["mtp_block"], h,
                              rules, mode="full")
        mtp_logits = lm_logits(cfg, params["embed"], h, rules)
        # predict t+2: logits at t score labels[t+2]
        mtp_loss = xent_loss(cfg, mtp_logits[:, :-2], labels[:, 2:])
        metrics["mtp"] = mtp_loss
        loss = loss + cfg.mtp_loss_weight * mtp_loss
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


def forward_prefill(cfg, run, params, batch, cache, rules):
    """Full forward filling caches; returns (last-position logits, cache)."""
    enc_out = encode(cfg, run, params, batch, rules) if cfg.is_encoder_decoder else None
    x = embed_inputs(cfg, params, batch, rules)
    x, cache, _ = run_stages(cfg, run, params, x, rules, mode="prefill",
                             caches=cache, enc_out=enc_out)
    x = rmsnorm(cfg, params["final_norm"], x[:, -1:, :])
    return lm_logits(cfg, params["embed"], x, rules)[:, 0], cache


def forward_decode(cfg, run, params, tokens, cache, rules):
    """tokens: (B,) int32. Returns (logits (B,V), cache)."""
    x = embed(cfg, params["embed"], tokens[:, None], rules)
    x, cache, _ = run_stages(cfg, run, params, x, rules, mode="decode",
                             caches=cache)
    x = rmsnorm(cfg, params["final_norm"], x)
    return lm_logits(cfg, params["embed"], x, rules)[:, 0], cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, seq: int, enc_len: int = 0):
    """Abstract decode-cache pytree + logical-axes pytree (stacked per stage)."""
    cross = cfg.is_encoder_decoder
    val: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    for si, (pattern, reps) in enumerate(cfg.stages()):
        sv, sa = {}, {}
        for j, bt in enumerate(pattern):
            v, a = block_cache_spec(cfg, bt, batch, seq, cross=cross,
                                    enc_len=enc_len)
            sv[f"pos_{j}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), v)
            sa[f"pos_{j}"] = jax.tree.map(
                lambda ax: ("layers",) + ax, a,
                is_leaf=lambda t: isinstance(t, tuple) and all(
                    isinstance(e, (str, type(None))) for e in t))
        val[f"stage_{si}"] = sv
        axes[f"stage_{si}"] = sa
    return val, axes


def init_cache(cfg: ModelConfig, batch: int, seq: int, enc_len: int = 0):
    val, _ = cache_spec(cfg, batch, seq, enc_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), val)
