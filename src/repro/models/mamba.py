"""Mamba-1 mixer block (falcon-mamba, jamba mamba layers).

Full-sequence path uses the chunked selective scan from
``repro.kernels.mamba_scan`` (Pallas on TPU, associative-scan ref on CPU).
Decode keeps O(1) state: SSM state (B, d_inner, N) + conv window (B, k-1, d_inner).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.mamba_scan import ops as scan_ops
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain


def mamba_template(cfg: ModelConfig) -> dict:
    d, di, n, r, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.dt_rank_, cfg.ssm_conv)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), fan_in_axis=0),
        "conv_w": ParamSpec((k, di), ("conv_k", "ssm_inner"), scale=0.5,
                            fan_in_axis=0),
        "conv_b": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), ("ssm_inner", None), fan_in_axis=0),
        "dt_proj": ParamSpec((r, di), ("dt_rank", "ssm_inner"), fan_in_axis=0),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), init="ssm_dt",
                             dtype="float32"),
        "A_log": ParamSpec((di, n), ("ssm_inner", "ssm_state"), init="ssm_a",
                           dtype="float32"),
        "D": ParamSpec((di,), ("ssm_inner",), init="ones", dtype="float32"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), fan_in_axis=0),
    }


def _dt_bc(cfg: ModelConfig, p, x):
    """x: (...,di) -> dt(...,di) f32, B(...,N), C(...,N)."""
    r, n = cfg.dt_rank_, cfg.ssm_state
    proj = x @ p["x_proj"]
    dt_r, Bm, Cm = proj[..., :r], proj[..., r : r + n], proj[..., r + n :]
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])
    return dt, Bm, Cm


def _causal_conv(cfg: ModelConfig, p, x):
    """Depthwise causal conv over seq. x: (B,S,di)."""
    k = cfg.ssm_conv
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * p["conv_w"][i] for i in range(k))
    return out + p["conv_b"]


def mamba_full(cfg: ModelConfig, p, x, rules, *, cache: Optional[dict] = None,
               chunk: int = 512, scan_dtype: str = "float32"):
    B, S, _ = x.shape
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    xz = constrain(xz, rules, "act_batch", None, "act_ssm_inner")
    xs, z = xz[..., :di], xz[..., di:]
    xc = jax.nn.silu(_causal_conv(cfg, p, xs))
    dt, Bm, Cm = _dt_bc(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32) if cache is None \
        else cache["h"]
    y, h_last = scan_ops.selective_scan(xc, dt, A, Bm, Cm, p["D"], h0,
                                        chunk=min(chunk, S),
                                        scan_dtype=scan_dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if cache is not None:
        k = cfg.ssm_conv
        conv_tail = jax.lax.dynamic_slice_in_dim(
            jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0))), S, k - 1, axis=1)
        cache = dict(cache, h=h_last, conv=conv_tail.astype(cache["conv"].dtype),
                     pos=jnp.int32(S))
    return out, cache


def mamba_decode(cfg: ModelConfig, p, x, cache, rules):
    """x: (B,1,D); cache: {h:(B,di,N) f32, conv:(B,k-1,di), pos}."""
    B = x.shape[0]
    di, k = cfg.d_inner, cfg.ssm_conv
    xz = x[:, 0] @ p["in_proj"]
    xs, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([cache["conv"].astype(xs.dtype), xs[:, None]], 1)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _dt_bc(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    y, h = scan_ops.selective_step(xc, dt, A, Bm, Cm, p["D"], cache["h"])
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    cache = dict(cache, h=h, conv=window[:, 1:].astype(cache["conv"].dtype),
                 pos=cache["pos"] + 1)
    return out, cache


def mamba_cache_spec(cfg: ModelConfig, batch: int, seq: int):
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    val = {
        "h": jax.ShapeDtypeStruct((batch, di, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, k - 1, di), jnp.dtype(cfg.dtype)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    axes = {
        "h": ("act_batch", "act_ssm_inner", None),
        "conv": ("act_batch", None, "act_ssm_inner"),
        "pos": (),
    }
    return val, axes
