"""Mixture-of-Experts with two dispatch implementations.

``moe`` (default) — **grouped sort-based dispatch**: tokens are reshaped
into shardable groups (G over the data axis); within each group the top-k
assignments are sorted by expert, capacity-bounded positions come from a
running count, and expert input buffers (G, E, C, D) are built by *gather*
— zero dispatch FLOPs. With the expert dim sharded (EP) the gathers/
scatters become the expert all-to-all under SPMD. This matters at
deepseek scale: the classic one-hot dispatch einsum costs T*E*C*D FLOPs
(~100x the expert matmuls at E=256); gather dispatch removes it.

``moe_gshard`` — the classic GShard/Switch dense one-hot einsum dispatch,
kept as the reference implementation (tests assert both produce identical
outputs when capacity is not binding).

When ``n_experts`` does not divide the model axis (qwen2-moe: 60), the rule
system replicates the expert dim and shards ``moe_ff`` instead (TP inside
experts) — see DESIGN.md §5.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import mlp, mlp_template
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain

CAPACITY_FACTOR = 1.25
GROUP_SIZE = 2048


def moe_template(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    t = {
        "router": ParamSpec((d, e), ("embed", None), fan_in_axis=0,
                            dtype="float32"),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "moe_ff"), fan_in_axis=1),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "moe_ff"), fan_in_axis=1),
        "wo": ParamSpec((e, f, d), ("experts", "moe_ff", "embed"), fan_in_axis=1),
    }
    if cfg.n_shared_experts:
        t["shared"] = mlp_template(cfg, cfg.n_shared_experts * cfg.moe_d_ff)
    return t


def _grouping(total_tokens: int) -> Tuple[int, int]:
    g = math.gcd(total_tokens, 32)
    while total_tokens // g > GROUP_SIZE and total_tokens % (g * 2) == 0:
        g *= 2
    return g, total_tokens // g


def _route(cfg: ModelConfig, p, xt):
    """xt: (G,Tg,D) -> (probs, gate_vals, idx) with top-k renormalized."""
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    return probs, gate_vals, idx


def _aux_loss(cfg: ModelConfig, probs, idx):
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)
    f_e = jnp.mean(onehot.sum(-2), axis=tuple(range(onehot.ndim - 2)))
    p_e = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return cfg.router_aux_weight * cfg.n_experts * jnp.sum(f_e * p_e)


def _capacity(cfg: ModelConfig, Tg: int) -> int:
    K, E = cfg.experts_per_token, cfg.n_experts
    return max(int(math.ceil(Tg * K / E * CAPACITY_FACTOR)), min(Tg, 4))


# ---------------------------------------------------------------------------
# Sort-based dispatch (default)
# ---------------------------------------------------------------------------

def moe(cfg: ModelConfig, p, x, rules):
    """x: (B,S,D) -> (y, aux_loss). Grouped sort-based dispatch."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_token
    G, Tg = _grouping(T)
    C = _capacity(cfg, Tg)

    xt = x.reshape(G, Tg, D)
    xt = constrain(xt, rules, "act_batch", None, None)
    probs, gate_vals, idx = _route(cfg, p, xt)        # (G,Tg,K)

    def dispatch_one(xg, idxg):
        """xg: (Tg,D); idxg: (Tg,K) -> (xin (E,C,D), slot (Tg,K), keep)."""
        flat_e = idxg.reshape(-1)                      # (Tg*K,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        pos_sorted = jnp.arange(Tg * K, dtype=jnp.int32) - starts[sorted_e]
        keep_sorted = pos_sorted < C
        slot_sorted = jnp.where(keep_sorted, sorted_e * C + pos_sorted, E * C)
        # unsort the slot assignment back to (Tg,K)
        slot = jnp.zeros((Tg * K,), jnp.int32).at[order].set(slot_sorted)
        keep = jnp.zeros((Tg * K,), bool).at[order].set(keep_sorted)
        tok_sorted = order // K
        token_for_slot = jnp.full((E * C + 1,), 0, jnp.int32).at[
            slot_sorted].set(jnp.where(keep_sorted, tok_sorted, 0))
        valid = jnp.zeros((E * C + 1,), bool).at[slot_sorted].set(keep_sorted)
        xin = xg[token_for_slot[:-1]] * valid[:-1, None].astype(xg.dtype)
        return xin.reshape(E, C, D), slot.reshape(Tg, K), keep.reshape(Tg, K)

    xin, slot, keep = jax.vmap(dispatch_one)(xt, idx)  # (G,E,C,D)
    xin = constrain(xin, rules, "act_moe_group", "act_experts", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wi_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xin, p["wi_up"])
    h = constrain(h, rules, "act_moe_group", "act_experts", None, "act_moe_ff")
    yexp = jnp.einsum("gecf,efd->gecd", h, p["wo"])    # (G,E,C,D)
    yexp = constrain(yexp, rules, "act_moe_group", "act_experts", None, None)

    def combine_one(yg, slotg, keepg, gateg):
        yflat = yg.reshape(E * C, D)
        rows = yflat[jnp.minimum(slotg.reshape(-1), E * C - 1)]
        rows = rows * keepg.reshape(-1, 1).astype(yg.dtype)
        rows = rows.reshape(Tg, K, D)
        return jnp.sum(rows * gateg[..., None].astype(yg.dtype), axis=1)

    y = jax.vmap(combine_one)(yexp, slot, keep, gate_vals)  # (G,Tg,D)

    if cfg.n_shared_experts:
        y = y + mlp(cfg, p["shared"], xt, rules)
    return y.reshape(B, S, D), _aux_loss(cfg, probs, idx)


# ---------------------------------------------------------------------------
# Manual expert parallelism: explicit all-to-all (the deepseek-scale path)
# ---------------------------------------------------------------------------

def moe_manual_ep(cfg: ModelConfig, p, x, rules):
    """Sort dispatch + *explicit* expert all-to-all via shard_map.

    Under auto-SPMD, gathers into an expert-sharded capacity buffer become
    full all-gathers (measured: 10x worse than baseline on deepseek-v3 —
    EXPERIMENTS.md §Perf). Wrapping just the expert computation in a
    partial-manual shard_map over (data, model) forces the real all-to-all:
    each device sends its groups' per-expert slices, computes its resident
    experts (E/256 each), and sends results back. Token routing, capacity
    assignment and combine stay in the auto region unchanged.

    Falls back to :func:`moe` when the mesh/expert counts don't divide.
    """
    from repro.parallel.sharding import get_abstract_mesh
    from jax.sharding import PartitionSpec as P

    mesh = get_abstract_mesh()
    E, K = cfg.n_experts, cfg.experts_per_token
    ep_axes = tuple(ax for ax in ("data", "model")
                    if mesh is not None and ax in mesh.shape)
    n_ep = 1
    for ax in ep_axes:
        n_ep *= mesh.shape[ax]
    B, S, D = x.shape
    G, Tg = _grouping(B * S)
    if mesh is None or n_ep == 1 or E % n_ep or G % n_ep:
        return moe(cfg, p, x, rules)
    E_loc, G_loc = E // n_ep, G // n_ep
    C = _capacity(cfg, Tg)

    xt = x.reshape(G, Tg, D)
    xt = constrain(xt, rules, "act_moe_group", None, None)
    probs, gate_vals, idx = _route(cfg, p, xt)

    def dispatch_one(xg, idxg):
        flat_e = idxg.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        pos_sorted = jnp.arange(Tg * K, dtype=jnp.int32) - starts[sorted_e]
        keep_sorted = pos_sorted < C
        slot_sorted = jnp.where(keep_sorted, sorted_e * C + pos_sorted, E * C)
        slot = jnp.zeros((Tg * K,), jnp.int32).at[order].set(slot_sorted)
        keep = jnp.zeros((Tg * K,), bool).at[order].set(keep_sorted)
        tok_sorted = order // K
        token_for_slot = jnp.full((E * C + 1,), 0, jnp.int32).at[
            slot_sorted].set(jnp.where(keep_sorted, tok_sorted, 0))
        valid = jnp.zeros((E * C + 1,), bool).at[slot_sorted].set(keep_sorted)
        xin = xg[token_for_slot[:-1]] * valid[:-1, None].astype(xg.dtype)
        return xin.reshape(E, C, D), slot.reshape(Tg, K), keep.reshape(Tg, K)

    xin, slot, keep = jax.vmap(dispatch_one)(xt, idx)      # (G,E,C,D)

    def expert_compute(xin_loc, wg, wu, wo):
        """Manual region. xin_loc: (G_loc,E,C,D); w*: (E_loc,...)."""
        z = xin_loc.reshape(G_loc, n_ep, E_loc, C, D)
        z = jnp.moveaxis(z, 1, 0)                          # (n_ep,G_loc,...)
        z = jax.lax.all_to_all(z, ep_axes, split_axis=0, concat_axis=0,
                               tiled=True)                 # src-major
        z = z.reshape(n_ep * G_loc, E_loc, C, D)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", z, wg))
        h = h * jnp.einsum("gecd,edf->gecf", z, wu)
        yz = jnp.einsum("gecf,efd->gecd", h, wo)
        yz = yz.reshape(n_ep, G_loc, E_loc, C, D)
        yz = jax.lax.all_to_all(yz, ep_axes, split_axis=0, concat_axis=0,
                                tiled=True)
        yz = jnp.moveaxis(yz, 0, 1)                        # (G_loc,n_ep,...)
        return yz.reshape(G_loc, E, C, D)

    w_spec = P(ep_axes)
    yexp = jax.shard_map(
        expert_compute, mesh=mesh,
        in_specs=(P(ep_axes), w_spec, w_spec, w_spec),
        out_specs=P(ep_axes),
        axis_names=set(ep_axes), check_vma=False)(
            xin, p["wi_gate"], p["wi_up"], p["wo"])        # (G,E,C,D)

    def combine_one(yg, slotg, keepg, gateg):
        yflat = yg.reshape(E * C, D)
        rows = yflat[jnp.minimum(slotg.reshape(-1), E * C - 1)]
        rows = rows * keepg.reshape(-1, 1).astype(yg.dtype)
        rows = rows.reshape(Tg, K, D)
        return jnp.sum(rows * gateg[..., None].astype(yg.dtype), axis=1)

    y = jax.vmap(combine_one)(yexp, slot, keep, gate_vals)
    if cfg.n_shared_experts:
        y = y + mlp(cfg, p["shared"], xt, rules)
    return y.reshape(B, S, D), _aux_loss(cfg, probs, idx)


# ---------------------------------------------------------------------------
# GShard one-hot einsum dispatch (reference)
# ---------------------------------------------------------------------------

def moe_gshard(cfg: ModelConfig, p, x, rules):
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_token
    G, Tg = _grouping(T)
    C = _capacity(cfg, Tg)

    xt = x.reshape(G, Tg, D)
    probs, gate_vals, idx = _route(cfg, p, xt)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # (G,Tg,K,E)
    flat = onehot.reshape(G, Tg * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.einsum("gne,gne->gn", pos, flat).reshape(G, Tg, K)
    keep = (pos < C).astype(jnp.float32)
    gate_kept = gate_vals * keep
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot, pos_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, pos_oh, gate_kept)

    xin = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), xt)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wi_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xin, p["wi_up"])
    yexp = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    y = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), yexp)

    if cfg.n_shared_experts:
        y = y + mlp(cfg, p["shared"], xt, rules)
    return y.reshape(B, S, D), _aux_loss(cfg, probs, idx)
