"""Common layers: norms, RoPE, SwiGLU MLP, embeddings, loss.

All layer functions are pure: ``f(cfg, params, x, *, rules) -> y``.
Params come from templates in the sibling ``*_template`` functions so that
shapes / logical axes / init live in exactly one place.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain


def adt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_template(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, dim: int, positions):
    """positions: (...,) int32 -> cos,sin of shape (..., dim//2), f32."""
    half = dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., dim); cos/sin broadcastable to (..., dim//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_template(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "ff"), fan_in_axis=0),
        "wi_up": ParamSpec((d, f), ("embed", "ff"), fan_in_axis=0),
        "wo": ParamSpec((f, d), ("ff", "embed"), fan_in_axis=0),
    }


def mlp(cfg: ModelConfig, p, x, rules):
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    h = constrain(h, rules, "act_batch", None, "act_ff")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding + LM head + loss (vocab padded; padded logits masked to -inf)
# ---------------------------------------------------------------------------

def embed_template(cfg: ModelConfig) -> dict:
    t = {"embedding": ParamSpec((cfg.vocab_padded, cfg.d_model),
                                ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_padded),
                                 ("embed", "vocab"), fan_in_axis=0)
    return t


def embed(cfg: ModelConfig, p, tokens, rules):
    x = jnp.take(p["embedding"], tokens, axis=0).astype(adt(cfg))
    return constrain(x, rules, "act_batch", None, None)


def lm_logits(cfg: ModelConfig, p, x, rules):
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (x @ w).astype(jnp.float32)
    return constrain(logits, rules, "act_batch", None, "act_vocab")


def xent_loss(cfg: ModelConfig, logits, labels, mask=None):
    """Cross-entropy with padded-vocab masking; logits f32 (..., vocab_padded)."""
    vp, v = cfg.vocab_padded, cfg.vocab_size
    if vp != v:
        neg = jnp.full((vp - v,), -1e30, logits.dtype)
        logits = logits.at[..., v:].set(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
