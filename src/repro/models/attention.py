"""Attention: GQA (with sharding-driven head padding) and MLA.

Two execution modes:
  * ``full``   — train / prefill over a whole sequence (uses the flash
    attention kernel path via ``repro.kernels.flash_attention.ops``).
  * ``decode`` — one token against a preallocated KV cache whose *sequence*
    dim is sharded (flash-decoding layout; see DESIGN.md §5).

Head padding: q-heads are zero-padded to ``cfg.heads_padded`` (multiple of
the model axis) and kv-heads to the smallest divisor of that count. Padded
heads are live parameters — the model is the assigned arch plus a few extra
heads; the MODEL_FLOPS/HLO_FLOPs roofline ratio accounts for the waste.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import adt, apply_rope, rmsnorm, rmsnorm_template, rope_freqs
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# Shared attention math (grouped einsum; no KV expansion).
# ---------------------------------------------------------------------------

def attend(q, k, v, *, q_pos, kv_len: int, scale: float, rules, causal=True):
    """q: (B,Sq,H,dq) k: (B,Skv,KV,dq) v: (B,Skv,KV,dv) -> (B,Sq,H,dv).

    ``q_pos``: (Sq,) absolute positions of queries; keys occupy [0, Skv) and
    only positions ``<= q_pos`` and ``< kv_len`` are visible.
    """
    B, Sq, H, dq = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dq)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(k.shape[1])
    ok = k_pos[None, :] < kv_len
    if causal:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskv->bqkgv", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, -1)


def _flash_or_ref(cfg, q, k, v, scale, rules, causal=True):
    """Full-sequence attention; Pallas flash kernel on TPU."""
    from repro.kernels.flash_attention import ops as fops
    return fops.flash_attention(q, k, v, scale=scale, causal=causal)


# ===========================================================================
# GQA
# ===========================================================================

def gqa_template(cfg: ModelConfig) -> dict:
    d, hp, kvp, hd = cfg.d_model, cfg.heads_padded, cfg.kv_heads_padded, cfg.hdim
    t = {
        "wq": ParamSpec((d, hp, hd), ("embed", "heads", "head_dim"), fan_in_axis=0),
        "wk": ParamSpec((d, kvp, hd), ("embed", "kv_heads", "head_dim"), fan_in_axis=0),
        "wv": ParamSpec((d, kvp, hd), ("embed", "kv_heads", "head_dim"), fan_in_axis=0),
        "wo": ParamSpec((hp, hd, d), ("heads", "head_dim", "embed"), fan_in_axis=1),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((hp, hd), ("heads", "head_dim"), init="zeros")
        t["bk"] = ParamSpec((kvp, hd), ("kv_heads", "head_dim"), init="zeros")
        t["bv"] = ParamSpec((kvp, hd), ("kv_heads", "head_dim"), init="zeros")
    return t


def _qkv(cfg, p, x, positions, rules):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    cos, sin = rope_freqs(cfg, cfg.hdim, positions)   # (..., hd/2)
    q = apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
    k = apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
    q = constrain(q, rules, "act_batch", None, "act_heads", None)
    k = constrain(k, rules, "act_batch", None, "act_kv_heads", None)
    return q, k, v


def gqa_full(cfg: ModelConfig, p, x, rules, *, cache: Optional[dict] = None,
             causal: bool = True):
    """Train / prefill. If ``cache`` is given it is filled (prefill)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = _qkv(cfg, p, x, positions, rules)
    scale = cfg.hdim ** -0.5
    o = _flash_or_ref(cfg, q, k, v, scale, rules, causal=causal)
    o = constrain(o, rules, "act_batch", None, "act_heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cache is not None:
        Sc = cache["k"].shape[1]
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        cache = dict(cache, k=kc, v=vc, pos=jnp.int32(S))
    return out, cache


def gqa_decode(cfg: ModelConfig, p, x, cache, rules):
    """x: (B,1,D); cache k/v: (B,Scache,KV,hd) seq-sharded; pos scalar."""
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k, v = _qkv(cfg, p, x, positions, rules)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    kc = constrain(kc, rules, "act_batch", "act_kv_seq", "act_kv_heads", None)
    vc = constrain(vc, rules, "act_batch", "act_kv_seq", "act_kv_heads", None)
    o = attend(q, kc.astype(q.dtype), vc.astype(q.dtype),
               q_pos=pos[None], kv_len=pos + 1, scale=cfg.hdim ** -0.5,
               rules=rules)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, dict(cache, k=kc, v=vc, pos=pos + 1)


def gqa_cache_spec(cfg: ModelConfig, batch: int, seq: int):
    """Abstract KV-cache entry + logical axes (for sharding + allocation)."""
    kvp, hd = cfg.kv_heads_padded, cfg.hdim
    dt = jnp.dtype(cfg.dtype)
    val = {
        "k": jax.ShapeDtypeStruct((batch, seq, kvp, hd), dt),
        "v": jax.ShapeDtypeStruct((batch, seq, kvp, hd), dt),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    axes = {
        "k": ("act_batch", "act_kv_seq", "act_kv_heads", None),
        "v": ("act_batch", "act_kv_seq", "act_kv_heads", None),
        "pos": (),
    }
    return val, axes


# ===========================================================================
# MLA (minicpm3, deepseek-v3)
# ===========================================================================

def mla_template(cfg: ModelConfig) -> dict:
    d, hp = cfg.d_model, cfg.heads_padded
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wdq": ParamSpec((d, ql), ("embed", "q_lora"), fan_in_axis=0),
        "q_norm": rmsnorm_template(ql),
        "wuq": ParamSpec((ql, hp, dn + dr), ("q_lora", "heads", "head_dim"), fan_in_axis=0),
        "wdkv": ParamSpec((d, kl + dr), ("embed", "kv_lora"), fan_in_axis=0),
        "kv_norm": rmsnorm_template(kl),
        "wuk": ParamSpec((kl, hp, dn), ("kv_lora", "heads", "head_dim"), fan_in_axis=0),
        "wuv": ParamSpec((kl, hp, dv), ("kv_lora", "heads", "head_dim"), fan_in_axis=0),
        "wo": ParamSpec((hp, dv, d), ("heads", "head_dim", "embed"), fan_in_axis=1),
    }


def _mla_q(cfg, p, x, positions, rules):
    cq = rmsnorm(cfg, p["q_norm"], x @ p["wdq"])
    qh = jnp.einsum("bsl,lhk->bshk", cq, p["wuq"])
    qn, qr = qh[..., : cfg.qk_nope_head_dim], qh[..., cfg.qk_nope_head_dim :]
    cos, sin = rope_freqs(cfg, cfg.qk_rope_head_dim, positions)
    qr = apply_rope(qr, cos[:, :, None, :], sin[:, :, None, :])
    return constrain(qn, rules, "act_batch", None, "act_heads", None), \
           constrain(qr, rules, "act_batch", None, "act_heads", None)


def _mla_kv_latent(cfg, p, x, positions):
    kl = cfg.kv_lora_rank
    dkv = x @ p["wdkv"]
    ckv = rmsnorm(cfg, p["kv_norm"], dkv[..., :kl])
    kr = dkv[..., kl:]
    cos, sin = rope_freqs(cfg, cfg.qk_rope_head_dim, positions)
    kr = apply_rope(kr, cos, sin)
    return ckv, kr


def mla_full(cfg: ModelConfig, p, x, rules, *, cache: Optional[dict] = None):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    qn, qr = _mla_q(cfg, p, x, positions, rules)
    ckv, kr = _mla_kv_latent(cfg, p, x, positions)
    # expand k, v from the latent (train/prefill path)
    kn = jnp.einsum("bsl,lhk->bshk", ckv, p["wuk"])
    v = jnp.einsum("bsl,lhv->bshv", ckv, p["wuv"])
    hp = cfg.heads_padded
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :], kn.shape[:3] + (cfg.qk_rope_head_dim,))], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    o = _flash_or_ref(cfg, q, k, v, scale, rules)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    if cache is not None:
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], kr.astype(cache["krope"].dtype), 0, axis=1)
        cache = dict(cache, ckv=ckv_c, krope=kr_c, pos=jnp.int32(S))
    return out, cache


def mla_decode(cfg: ModelConfig, p, x, cache, rules):
    """Absorbed MLA decode: attention in latent space, O(kv_lora) cache."""
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    qn, qr = _mla_q(cfg, p, x, positions, rules)           # (B,1,H,*)
    ckv_t, kr_t = _mla_kv_latent(cfg, p, x, positions)     # (B,1,kl),(B,1,dr)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_t.astype(cache["ckv"].dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], kr_t.astype(cache["krope"].dtype), pos, axis=1)
    ckv = constrain(ckv, rules, "act_batch", "act_kv_seq", None)
    krope = constrain(krope, rules, "act_batch", "act_kv_seq", None)
    # absorb W_uk into q:  (B,H,kl)
    q_abs = jnp.einsum("bhn,lhn->bhl", qn[:, 0], p["wuk"])
    s = jnp.einsum("bhl,bsl->bhs", q_abs, ckv.astype(q_abs.dtype),
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", qr[:, 0].astype(jnp.float32),
                       krope.astype(jnp.float32))
    s = s * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    k_pos = jnp.arange(ckv.shape[1])
    s = jnp.where((k_pos <= pos)[None, None, :], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", a.astype(ckv.dtype), ckv)
    o = jnp.einsum("bhl,lhv->bhv", o_lat, p["wuv"])
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])[:, None, :]
    return out, dict(cache, ckv=ckv, krope=krope, pos=pos + 1)


def mla_cache_spec(cfg: ModelConfig, batch: int, seq: int):
    dt = jnp.dtype(cfg.dtype)
    val = {
        "ckv": jax.ShapeDtypeStruct((batch, seq, cfg.kv_lora_rank), dt),
        "krope": jax.ShapeDtypeStruct((batch, seq, cfg.qk_rope_head_dim), dt),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    axes = {
        "ckv": ("act_batch", "act_kv_seq", None),
        "krope": ("act_batch", "act_kv_seq", None),
        "pos": (),
    }
    return val, axes


# ---------------------------------------------------------------------------
# Dispatch helpers used by the block assembler.
# ---------------------------------------------------------------------------

def attn_template(cfg: ModelConfig) -> dict:
    return mla_template(cfg) if cfg.attn_type == "mla" else gqa_template(cfg)


def attn_full(cfg, p, x, rules, cache=None, causal=True):
    if cfg.attn_type == "mla":
        assert causal, "MLA archs are decoder-only here"
        return mla_full(cfg, p, x, rules, cache=cache)
    return gqa_full(cfg, p, x, rules, cache=cache, causal=causal)


def attn_decode(cfg, p, x, cache, rules):
    if cfg.attn_type == "mla":
        return mla_decode(cfg, p, x, cache, rules)
    return gqa_decode(cfg, p, x, cache, rules)


def attn_cache_spec(cfg, batch, seq):
    if cfg.attn_type == "mla":
        return mla_cache_spec(cfg, batch, seq)
    return gqa_cache_spec(cfg, batch, seq)
