"""Parameter templates: single source of truth for shapes, init and sharding.

A model declares its parameters once as a pytree of :class:`ParamSpec`.
From the template we derive:
  * ``init_params``      — real arrays (smoke tests, examples, training)
  * ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins (dry-run lowering)
  * ``logical_axes``     — pytree of logical-dim-name tuples consumed by
    ``parallel.sharding`` to produce ``PartitionSpec`` trees.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical dim names, len == ndim
    init: str = "normal"                # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 1.0                  # stddev multiplier for "normal"
    fan_in_axis: Optional[int] = None   # axis whose size sets 1/sqrt(fan_in)
    dtype: Optional[str] = None         # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(f, template):
    return jax.tree.map(f, template, is_leaf=_is_spec)


def abstract_params(template, param_dtype: str):
    def mk(s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or param_dtype))
    return tree_map_specs(mk, template)


def logical_axes(template):
    return tree_map_specs(lambda s: s.axes, template)


def init_params(template, key, param_dtype: str):
    leaves, treedef = jax.tree.flatten(template, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        dt = jnp.dtype(s.dtype or param_dtype)
        if s.init == "zeros":
            v = jnp.zeros(s.shape, dt)
        elif s.init == "ones":
            v = jnp.ones(s.shape, dt)
        elif s.init == "ssm_a":
            # mamba1 A_log init: log(1..N) broadcast over channels
            n = s.shape[-1]
            v = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), s.shape).astype(dt)
        elif s.init == "ssm_dt":
            # dt bias ~ softplus^-1(uniform(1e-3, 1e-1))
            u = jax.random.uniform(k, s.shape, jnp.float32,
                                   math.log(1e-3), math.log(1e-1))
            dtv = jnp.exp(u)
            v = (dtv + jnp.log(-jnp.expm1(-dtv))).astype(dt)
        elif s.init == "normal":
            fan_in = s.shape[s.fan_in_axis] if s.fan_in_axis is not None else None
            std = s.scale * (1.0 / math.sqrt(fan_in) if fan_in else 0.02)
            v = (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt)
        else:
            raise ValueError(f"unknown init {s.init}")
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def count_params(template) -> int:
    leaves = jax.tree.leaves(template, is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scanned) leading dim to every spec in a tree."""
    def st(s: ParamSpec):
        fan = None if s.fan_in_axis is None else s.fan_in_axis + 1
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale,
                         fan, s.dtype)
    return tree_map_specs(st, spec_tree)
