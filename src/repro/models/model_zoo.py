"""Model API hub: config -> templates, shardings, jit-able step functions.

Everything the launcher / dry-run / trainer / server needs:

    model = Model(run_config)
    model.abstract_params()                  # ShapeDtypeStruct tree
    model.init_params(key)                   # real arrays
    model.param_shardings(mesh)              # NamedSharding tree
    model.train_step                         # (params, opt, batch) -> ...
    model.prefill / model.decode_step        # serving
    model.dryrun_case(kind, mesh)            # (fn, args, in/out shardings)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeProfile
from repro.data.pipeline import batch_logical_axes, make_batch_specs
from repro.models import transformer as tfm
from repro.models.params import (abstract_params, init_params, logical_axes)
from repro.optim.optimizers import (clip_by_global_norm, make_optimizer,
                                    opt_state_axes)
from repro.optim.schedules import cosine_schedule
from repro.parallel.sharding import get_rules, tree_pspecs, tree_shardings


def _axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


@dataclass
class Model:
    run: RunConfig

    def __post_init__(self):
        self.cfg = self.run.model
        self.rules = get_rules(self.run.sharding_preset, self.run.rule_overrides)
        self.template = tfm.model_template(self.cfg)
        self.param_axes = logical_axes(self.template)
        self.opt_init, self.opt_update = make_optimizer(
            self.run.optimizer, state_dtype=self.run.opt_state_dtype,
            weight_decay=self.run.weight_decay)
        self.schedule = cosine_schedule(self.run.learning_rate)

    # ------------------------------------------------------------ parameters
    def abstract_params(self):
        return abstract_params(self.template, self.cfg.param_dtype)

    def init_params(self, key):
        return init_params(self.template, key, self.cfg.param_dtype)

    def abstract_opt_state(self):
        return jax.eval_shape(self.opt_init, self.abstract_params())

    def param_pspecs(self, mesh: Mesh):
        return tree_pspecs(self.rules, self.param_axes, self.abstract_params(), mesh)

    def param_shardings(self, mesh: Mesh):
        return tree_shardings(self.rules, self.param_axes, self.abstract_params(), mesh)

    def opt_axes(self):
        return opt_state_axes(self.run.optimizer, self.param_axes)

    def opt_shardings(self, mesh: Mesh):
        return tree_shardings(self.rules, self.opt_axes(),
                              self.abstract_opt_state(), mesh)

    # ----------------------------------------------------------------- batch
    def abstract_batch(self):
        return make_batch_specs(self.cfg, self.run.shape)

    def batch_shardings(self, mesh: Mesh):
        return tree_shardings(self.rules, batch_logical_axes(self.cfg, self.run.shape),
                              self.abstract_batch(), mesh)

    # ----------------------------------------------------------------- cache
    def cache_spec(self):
        sp = self.run.shape
        enc_len = sp.seq_len if self.cfg.is_encoder_decoder else 0
        return tfm.cache_spec(self.cfg, sp.global_batch, sp.seq_len, enc_len)

    def abstract_cache(self):
        return self.cache_spec()[0]

    def init_cache(self):
        sp = self.run.shape
        enc_len = sp.seq_len if self.cfg.is_encoder_decoder else 0
        return tfm.init_cache(self.cfg, sp.global_batch, sp.seq_len, enc_len)

    def cache_shardings(self, mesh: Mesh):
        val, axes = self.cache_spec()
        return tree_shardings(self.rules, axes, val, mesh)

    # ------------------------------------------------------------ step fns
    @property
    def train_step(self) -> Callable:
        cfg, run, rules = self.cfg, self.run, self.rules
        opt_update, schedule = self.opt_update, self.schedule

        def grads_of(params, batch):
            def loss_fn(p):
                return tfm.forward_train(cfg, run, p, batch, rules)
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            return grads, metrics

        def step(params, opt_state, batch):
            if run.grad_accum > 1:
                # microbatch accumulation: split the global batch's leading
                # dim; equal-size means average exactly to the full-batch
                # gradient. Peak activation memory drops ~grad_accum x.
                n = run.grad_accum
                micro = jax.tree.map(
                    lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                    batch)

                def acc(carry, mb):
                    g, m = grads_of(params, mb)
                    return (jax.tree.map(jnp.add, carry[0], g),
                            jax.tree.map(jnp.add, carry[1], m)), None

                zero_g = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                g0, m0 = grads_of(params, jax.tree.map(lambda x: x[0], micro))
                (gsum, msum), _ = jax.lax.scan(
                    acc, (jax.tree.map(lambda a, b: a.astype(jnp.float32) + b,
                                       g0, zero_g), m0),
                    jax.tree.map(lambda x: x[1:], micro))
                grads = jax.tree.map(lambda g, p: (g / n).astype(p.dtype),
                                     gsum, params)
                metrics = jax.tree.map(lambda m: m / n, msum)
            else:
                grads, metrics = grads_of(params, batch)
            grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
            lr = schedule(opt_state["step"] + 1)   # step counter is 0-based
            params, opt_state = opt_update(params, grads, opt_state, lr=lr)
            metrics = dict(metrics, grad_norm=gnorm, lr=lr)
            return params, opt_state, metrics

        return step

    @property
    def eval_loss(self) -> Callable:
        cfg, run, rules = self.cfg, self.run, self.rules

        def fn(params, batch):
            loss, metrics = tfm.forward_train(cfg, run, params, batch, rules)
            return metrics

        return fn

    @property
    def prefill(self) -> Callable:
        cfg, run, rules = self.cfg, self.run, self.rules

        def fn(params, batch, cache):
            return tfm.forward_prefill(cfg, run, params, batch, cache, rules)

        return fn

    @property
    def decode_step(self) -> Callable:
        cfg, run, rules = self.cfg, self.run, self.rules

        def fn(params, tokens, cache):
            return tfm.forward_decode(cfg, run, params, tokens, cache, rules)

        return fn

    # ------------------------------------------------------------- dry-run
    def dryrun_case(self, mesh: Mesh):
        """(fn, abstract args, in_shardings, out_shardings) for this cell."""
        kind = self.run.shape.kind
        ps = self.param_shardings(mesh)
        repl = NamedSharding(mesh, P())
        metrics_sh = repl  # scalars
        if kind == "train":
            os_ = self.opt_shardings(mesh)
            bs = self.batch_shardings(mesh)
            args = (self.abstract_params(), self.abstract_opt_state(),
                    self.abstract_batch())
            in_sh = (ps, os_, bs)
            out_sh = (ps, os_, None)
            return self.train_step, args, in_sh, out_sh
        if kind == "prefill":
            cs = self.cache_shardings(mesh)
            bs = self.batch_shardings(mesh)
            abatch = self.abstract_batch()
            abatch.pop("labels", None)
            bs = {k: v for k, v in bs.items() if k in abatch}
            args = (self.abstract_params(), abatch, self.abstract_cache())
            return self.prefill, args, (ps, bs, cs), (None, cs)
        # decode
        B = self.run.shape.global_batch
        cs = self.cache_shardings(mesh)
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
        daxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        tok_sh = NamedSharding(
            mesh, P(daxes) if daxes and B % _databatch(mesh) == 0 else P())
        args = (self.abstract_params(), tok, self.abstract_cache())
        return self.decode_step, args, (ps, tok_sh, cs), (None, cs)


def _databatch(mesh: Mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        n *= mesh.shape.get(ax, 1)
    return n
