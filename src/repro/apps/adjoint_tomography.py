"""Adjoint Tomography — the paper's evaluation application (§4), for real.

A 3D acoustic wave-equation solver (2nd-order leapfrog finite differences,
``lax.scan`` over timesteps with rematerialization) plus the four AT steps
from the paper:

  1. build starting model, compute synthetic seismograms       (local)
  2. misfit between synthetics and observations                (remotable)
  3. Fréchet kernel — gradient of misfit w.r.t. the model      (remotable)
     (the "adjoint" computation; here literally the adjoint-state method
     obtained by reverse-mode AD through the wave solver)
  4. model update                                              (remotable)

Steps 2–4 carry the paper's ``remotable`` annotation; iterating the
workflow "until the seismograms match wiggle by wiggle" is the driver loop
in ``examples/adjoint_tomography.py``. Mesh sizes used by the paper's
figures — 104x23x24 (Fig 11) and 208x44x46 (Fig 12) — are both supported;
benchmarks default to scaled-down time axes so CPU runs stay snappy.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.workflow import Workflow


@dataclass(frozen=True)
class ATConfig:
    nx: int = 104
    ny: int = 23
    nz: int = 24
    nt: int = 200
    dx: float = 100.0          # m
    dt: float = 0.008          # s  (CFL: c*dt/dx <= 1/sqrt(3))
    c0: float = 3000.0         # background velocity m/s
    f0: float = 4.0            # Ricker peak frequency, Hz
    n_receivers: int = 16
    lr: float = 0.4            # model-update step (normalized gradient)

    @property
    def mesh_name(self) -> str:
        return f"{self.nx}x{self.ny}x{self.nz}"


FIG11 = ATConfig(nx=104, ny=23, nz=24)
FIG12 = ATConfig(nx=208, ny=44, nz=46)


# ---------------------------------------------------------------------------
# Wave physics
# ---------------------------------------------------------------------------

def _shift(u: jnp.ndarray, axis: int, d: int) -> jnp.ndarray:
    """Shift with zero boundaries (Dirichlet), no wraparound."""
    pad = [(0, 0)] * u.ndim
    pad[axis] = (max(d, 0), max(-d, 0))
    up = jnp.pad(u, pad)
    idx = [slice(None)] * u.ndim
    idx[axis] = slice(max(-d, 0), up.shape[axis] - max(d, 0))
    return up[tuple(idx)]


def _laplacian(u: jnp.ndarray, dx: float) -> jnp.ndarray:
    """7-point 3D Laplacian, zero (Dirichlet) boundaries."""
    lap = -6.0 * u
    for axis in range(3):
        lap = lap + _shift(u, axis, 1) + _shift(u, axis, -1)
    return lap / (dx * dx)


def _ricker(cfg: ATConfig) -> jnp.ndarray:
    t = jnp.arange(cfg.nt) * cfg.dt - 1.0 / cfg.f0
    a = (math.pi * cfg.f0) ** 2 * t ** 2
    return (1 - 2 * a) * jnp.exp(-a)


def _receiver_idx(cfg: ATConfig) -> Tuple[jnp.ndarray, int, int]:
    xs = jnp.linspace(4, cfg.nx - 5, cfg.n_receivers).astype(jnp.int32)
    return xs, cfg.ny // 2, 2


@partial(jax.jit, static_argnums=(1,))
def simulate(c: jnp.ndarray, cfg: ATConfig) -> jnp.ndarray:
    """Leapfrog acoustic FD; returns seismograms (nt, n_receivers)."""
    src = _ricker(cfg)
    sx, sy, sz = cfg.nx // 2, cfg.ny // 2, 2
    rx, ry, rz = _receiver_idx(cfg)
    c2dt2 = (c * cfg.dt) ** 2

    def step(carry, s_t):
        u_prev, u = carry
        lap = _laplacian(u, cfg.dx)
        u_next = 2 * u - u_prev + c2dt2 * lap
        u_next = u_next.at[sx, sy, sz].add(c2dt2[sx, sy, sz] * s_t)
        rec = u_next[rx, ry, rz]
        return (u, u_next), rec

    u0 = jnp.zeros((cfg.nx, cfg.ny, cfg.nz))
    step = jax.checkpoint(step)
    (_, _), seis = jax.lax.scan(step, (u0, u0), src)
    return seis


def starting_model(cfg: ATConfig) -> jnp.ndarray:
    return jnp.full((cfg.nx, cfg.ny, cfg.nz), cfg.c0)


def true_model(cfg: ATConfig) -> jnp.ndarray:
    """Twin-experiment target: background + two gaussian velocity anomalies."""
    x, y, z = jnp.meshgrid(jnp.arange(cfg.nx), jnp.arange(cfg.ny),
                           jnp.arange(cfg.nz), indexing="ij")

    def blob(cx, cy, cz, r, amp):
        d2 = ((x - cx) ** 2 + (y - cy) ** 2 + (z - cz) ** 2) / r ** 2
        return amp * jnp.exp(-d2)

    c = starting_model(cfg)
    c = c + blob(cfg.nx * 0.35, cfg.ny * 0.5, cfg.nz * 0.5, cfg.nx * 0.08, 250.0)
    c = c - blob(cfg.nx * 0.7, cfg.ny * 0.4, cfg.nz * 0.6, cfg.nx * 0.06, 200.0)
    return c


# ---------------------------------------------------------------------------
# The four AT steps (paper §4), as workflow step functions.
# ---------------------------------------------------------------------------

def step_forward(cfg: ATConfig):
    def fn(model):
        return {"syn": simulate(model, cfg)}
    return fn


def step_misfit(cfg: ATConfig):
    def fn(syn, obs):
        r = syn - obs
        return {"chi": 0.5 * jnp.sum(r * r)}
    return fn


def step_kernel(cfg: ATConfig):
    def fn(model, obs):
        def chi_of(m):
            r = simulate(m, cfg) - obs
            return 0.5 * jnp.sum(r * r)
        return {"grad": jax.grad(chi_of)(model)}
    return fn


def step_update(cfg: ATConfig):
    def fn(model, grad):
        g = grad / (jnp.max(jnp.abs(grad)) + 1e-20)
        return {"model": model - cfg.lr * g * 20.0}
    return fn


def _sim_flops(cfg: ATConfig) -> float:
    return float(cfg.nx * cfg.ny * cfg.nz) * cfg.nt * 15.0


def build_workflow(cfg: ATConfig, *, remotable=(2, 3, 4)) -> Workflow:
    """One AT iteration as an Emerald workflow (paper: steps 2–4 remotable)."""
    wf = Workflow(f"AT-{cfg.mesh_name}")
    wf.var("model").var("obs")
    n = cfg.nx * cfg.ny * cfg.nz
    wf.step("forward", step_forward(cfg), inputs=("model",), outputs=("syn",),
            remotable=1 in remotable, flops_hint=_sim_flops(cfg),
            bytes_hint=8.0 * n)
    wf.step("misfit", step_misfit(cfg), inputs=("syn", "obs"),
            outputs=("chi",), remotable=2 in remotable,
            flops_hint=3.0 * cfg.nt * cfg.n_receivers, bytes_hint=8.0)
    wf.step("kernel", step_kernel(cfg), inputs=("model", "obs"),
            outputs=("grad",), remotable=3 in remotable,
            flops_hint=3.0 * _sim_flops(cfg), bytes_hint=8.0 * n)
    wf.step("update", step_update(cfg), inputs=("model", "grad"),
            outputs=("model",), remotable=4 in remotable,
            flops_hint=4.0 * n, bytes_hint=8.0 * n)
    return wf


def make_observations(cfg: ATConfig) -> jnp.ndarray:
    return simulate(true_model(cfg), cfg)
