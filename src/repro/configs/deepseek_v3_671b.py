"""deepseek-v3-671b — MoE with MLA + MTP [arXiv:2412.19437].

61L d_model=7168 128H (MLA) vocab=129280. MoE: 1 shared + 256 routed
top-8, expert d_ff=2048 (dense d_ff=18432 on the first 3 layers).
MLA: q_lora 1536, kv_lora 512, qk nope/rope 128/64, v 128. MTP head.

Default run config uses adafactor + bf16 state (fp32 Adam for 671B params
exceeds 256x16GB; see EXPERIMENTS.md §Dry-run notes).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "deepseek-v3-671b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,              # dense layers (first 3)
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    moe_layer_period=1,
    first_dense_layers=3,
    mtp=True,
    pad_multiple=16,
)

RUN_OVERRIDES = dict(optimizer="adafactor", opt_state_dtype="bfloat16")
