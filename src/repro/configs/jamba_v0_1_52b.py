"""jamba-v0.1-52b — hybrid Mamba+attention MoE [arXiv:2403.19887].

32L d_model=4096; attention every 8th layer (offset 4, 32H GQA kv=8,
head_dim 128); MoE every 2nd layer (offset 1): 16 experts top-2,
d_ff=14336; mamba elsewhere (d_inner 8192, state 16, dt_rank 256).
``long_500k`` RUNS (hybrid: 28/32 layers are linear-time).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "jamba-v0.1-52b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_layer_period=8,
    attn_layer_offset=4,
    n_experts=16,
    n_shared_experts=0,
    experts_per_token=2,
    moe_d_ff=14336,
    moe_layer_period=2,
    moe_layer_offset=1,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dt_rank=256,
    pad_multiple=16,
)
