"""tinyllama-1.1b — llama2-arch small dense LM [arXiv:2401.02385].

22L d_model=2048 32H (GQA kv=4, head_dim 64) d_ff=5632 vocab=32000.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "tinyllama-1.1b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10000.0,
    pad_multiple=16,
)
