"""llama3.2-3b — small llama3 dense LM [hf:meta-llama/Llama-3.2-3B].

28L d_model=3072 24H (GQA kv=8, head_dim 128) d_ff=8192 vocab=128256.
24 q-heads pad to 32 for the 16-way model axis (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama3.2-3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    pad_multiple=16,
)
