"""qwen1.5-32b — dense LM with QKV bias [hf:Qwen/Qwen1.5-32B].

64L d_model=5120 40H (kv=40, head_dim 128) d_ff=27392 vocab=152064.
40 heads pad to 48 for the 16-way model axis.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen1.5-32b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    pad_multiple=16,
)
