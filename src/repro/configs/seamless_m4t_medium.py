"""seamless-m4t-medium — encoder-decoder multimodal [arXiv:2308.11596].

12L encoder + 12L decoder, d_model=1024 16H (kv=16, head_dim 64)
d_ff=4096 vocab=256206 (padded to 256208). The speech frontend is a STUB
per the assignment spec: ``input_specs()`` provides precomputed frame
embeddings for the encoder.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "seamless-m4t-medium"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    is_encoder_decoder=True,
    n_encoder_layers=12,
    frontend="speech_stub",
    pad_multiple=16,
)
