"""Config system for Emerald-JAX.

Two layers of config:
  * ``ModelConfig``  — architecture hyperparameters (one per assigned arch).
  * ``ShapeProfile`` — (seq_len, global_batch, kind) input-shape cells.
  * ``RunConfig``    — model + shape + parallelism/optimizer/runtime knobs.

Everything is a frozen dataclass so configs hash and can key compile caches.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Block types for the layer-pattern system (see models/transformer.py).
# ---------------------------------------------------------------------------
ATTN_DENSE = "attn_dense"      # attention + dense MLP
ATTN_MOE = "attn_moe"          # attention + MoE
MAMBA_DENSE = "mamba_dense"    # mamba mixer + dense MLP
MAMBA_MOE = "mamba_moe"        # mamba mixer + MoE
MAMBA_ONLY = "mamba_only"      # pure mamba block (no MLP; mamba1 archs)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters. Field defaults are no-ops."""

    name: str
    family: str                      # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: int = 0              # 0 -> = n_heads
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention flavour ---------------------------------------------------
    attn_type: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # --- MLA (minicpm3 / deepseek-v3) ---------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1        # MoE applied when layer % period == offset
    moe_layer_offset: int = 0
    first_dense_layers: int = 0      # leading layers forced dense (deepseek: 3)
    router_aux_weight: float = 0.001

    # --- SSM / Mamba-1 --------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model / 16)

    # --- hybrid (jamba) -------------------------------------------------------
    attn_layer_period: int = 0       # attention when layer % period == offset
    attn_layer_offset: int = 0

    # --- encoder-decoder (seamless) ------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality frontends (STUBS per assignment spec) ----------------------
    frontend: str = ""               # "" | vit_stub | speech_stub
    frontend_tokens: int = 0         # prefix positions supplied as embeddings

    # --- misc ----------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp: bool = False                # deepseek multi-token prediction
    mtp_loss_weight: float = 0.3
    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "bfloat16"
    # sharding-driven padding (16 = production model-axis; 1 = smoke configs).
    pad_multiple: int = 1

    # ------------------------------------------------------------------ derived
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def attn_free(self) -> bool:
        return self.attn_type == "none"

    # --- sharding-driven padding (see DESIGN.md §5) ---------------------------
    @property
    def vocab_padded(self) -> int:
        return pad_to_multiple(self.vocab_size, self.pad_multiple)

    @property
    def heads_padded(self) -> int:
        """Q-heads zero-padded so the head dim shards over the model axis."""
        return pad_to_multiple(self.n_heads, self.pad_multiple)

    @property
    def kv_heads_padded(self) -> int:
        """Smallest kv-head count >= kv_heads that divides heads_padded."""
        hp = self.heads_padded
        for kv in range(self.kv_heads, hp + 1):
            if hp % kv == 0:
                return kv
        return hp

    @property
    def q_group(self) -> int:
        return self.heads_padded // self.kv_heads_padded

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid archs only."""
        return self.family in ("ssm", "hybrid")

    # --- layer-pattern construction ------------------------------------------
    def block_type(self, i: int) -> str:
        """Block type of decoder layer ``i``."""
        if self.family == "ssm":
            return MAMBA_ONLY
        is_moe = (
            self.n_experts > 0
            and i >= self.first_dense_layers
            and i % self.moe_layer_period == self.moe_layer_offset
        )
        is_attn = True
        if self.attn_layer_period:  # hybrid: attention only on some layers
            is_attn = i % self.attn_layer_period == self.attn_layer_offset
        if is_attn:
            return ATTN_MOE if is_moe else ATTN_DENSE
        return MAMBA_MOE if is_moe else MAMBA_DENSE

    def stages(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Compress the per-layer block types into (pattern, repeats) stages.

        A stage repeats a short pattern; stacking params along a leading
        ``repeats`` axis lets us ``lax.scan`` over it with compact HLO.
        """
        types = [self.block_type(i) for i in range(self.n_layers)]
        # greedy: longest truly-repeating (period, repeats>=2) run; isolated
        # layers become (pattern=1, repeats=1) stages (counted unrolled).
        out = []
        i = 0
        while i < len(types):
            best = (1, 1)  # (period, repeats)
            for p in range(1, min(16, (len(types) - i) // 2) + 1):
                reps = 1
                while (
                    i + (reps + 1) * p <= len(types)
                    and types[i + reps * p : i + (reps + 1) * p] == types[i : i + p]
                ):
                    reps += 1
                if reps >= 2 and (reps * p > best[0] * best[1] or (
                        reps * p == best[0] * best[1] and p < best[0])):
                    best = (p, reps)
            p, reps = best
            out.append((tuple(types[i : i + p]), reps))
            i += p * reps
        # merge adjacent single-rep stages of identical 1-patterns
        merged = []
        for pat, reps in out:
            if merged and merged[-1][0] == pat:
                merged[-1] = (pat, merged[-1][1] + reps)
            else:
                merged.append((pat, reps))
        return tuple(merged)


# ---------------------------------------------------------------------------
# Input-shape cells (assigned shapes; identical for every LM arch).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeProfile:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeProfile("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeProfile("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeProfile("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeProfile("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeProfile) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; reason if not.

    long_500k needs sub-quadratic attention -> SSM/hybrid only (see DESIGN.md).
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""


# ---------------------------------------------------------------------------
# Run config: model x shape x parallelism/runtime knobs.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeProfile
    # parallelism
    sharding_preset: str = "fsdp"      # dp_tp | fsdp | + per-run overrides
    rule_overrides: Tuple[Tuple[str, Tuple[str, ...]], ...] = ()
    remat: str = "full"                # none | full | dots_saveable
    scan_unroll: int = 1               # layer-scan unroll (all stages)
    # dry-run cost extrapolation: unroll ONE stage by `unroll_factor` so the
    # per-layer cost slope of that stage can be measured (see launch/dryrun).
    unroll_stage: str = ""
    unroll_factor: int = 2
    ssm_chunk: int = 512               # mamba within-chunk size
    ssm_scan_dtype: str = "float32"    # scan-pair materialization dtype
    moe_impl: str = "sort"             # sort | manual_ep | gshard
    # optimizer
    optimizer: str = "adamw"           # adamw | adafactor
    opt_state_dtype: str = "float32"   # float32 | bfloat16
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_accum: int = 1
    grad_compression: str = "none"     # none | bf16 | int8  (cross-pod axis)
    # serving
    max_decode_len: int = 0            # 0 -> shape.seq_len

    def with_(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def pad_to_multiple(x: int, m: int) -> int:
    return -(-x // m) * m


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.n_experts:
        kw.update(n_experts=min(cfg.n_experts, 8), moe_d_ff=64)
    if cfg.q_lora_rank:
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16)
    if cfg.ssm_state:
        kw.update(ssm_state=8, dt_rank=8)
    if cfg.is_encoder_decoder:
        kw.update(n_encoder_layers=2)
    if cfg.frontend:
        kw.update(frontend_tokens=8)
    if cfg.first_dense_layers:
        kw.update(first_dense_layers=1)
    if cfg.attn_layer_period:
        kw.update(n_layers=8)
    kw.update(param_dtype="float32", dtype="float32")
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
