"""internvl2-1b — VLM: InternViT frontend (STUB) + Qwen2-0.5B-class backbone
[arXiv:2404.16821].

Backbone: 24L d_model=896 14H (GQA kv=2, head_dim 64) d_ff=4864
vocab=151655. The ViT frontend is a stub per the assignment spec:
``input_specs()`` provides 256 precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "internvl2-1b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    rope_theta=1000000.0,
    frontend="vit_stub",
    frontend_tokens=256,
    pad_multiple=16,
)
