"""falcon-mamba-7b — attention-free Mamba-1 LM [arXiv:2410.05355].

64L d_model=4096, d_inner=8192 (expand 2), ssm_state=16, conv 4,
dt_rank=256, vocab 65024. ``long_500k`` RUNS (linear-time SSM).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "falcon-mamba-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,           # unused (attention-free)
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    attn_type="none",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dt_rank=256,
    pad_multiple=16,
)
