"""Config registry: ``--arch <id>`` -> ModelConfig (+ run-config defaults)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.configs.base import (ModelConfig, RunConfig, ShapeProfile, SHAPES,
                                reduced, shape_applicable)

_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llama3.2-3b": "llama3_2_3b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen1.5-32b": "qwen1_5_32b",
    "minicpm3-4b": "minicpm3_4b",
    "internvl2-1b": "internvl2_1b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def run_overrides(arch: str) -> dict:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return dict(getattr(mod, "RUN_OVERRIDES", {}))


def make_run(arch: str, shape: str, **overrides) -> RunConfig:
    cfg = get_config(arch)
    sp = SHAPES[shape]
    ok, why = shape_applicable(cfg, sp)
    if not ok:
        raise ValueError(f"{arch} x {shape}: {why}")
    kw = run_overrides(arch)
    # SSM chunking scales with sequence so the unrolled chunk loop stays
    # compact in HLO while the per-chunk working set stays VMEM/HBM-sane.
    kw.setdefault("ssm_chunk", 512 if sp.seq_len <= 4096 else 2048)
    kw.update(overrides)
    return RunConfig(model=cfg, shape=sp, **kw)


def all_cells(include_inapplicable: bool = False):
    """Every assigned (arch, shape) cell (40 total; 8 long_500k are skips)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, sp in SHAPES.items():
            ok, why = shape_applicable(cfg, sp)
            if ok or include_inapplicable:
                out.append((arch, sname, ok, why))
    return out
