"""qwen2-moe-a2.7b — MoE LM [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16, head_dim 128) vocab=151936.
MoE every layer: 60 routed top-4 + shared expert (4x1408=5632 wide).
60 % 16 != 0 -> no EP; TP inside experts (moe_ff 1408/16; DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-moe-a2.7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    n_experts=60,
    n_shared_experts=4,
    experts_per_token=4,
    moe_d_ff=1408,
    moe_layer_period=1,
    pad_multiple=16,
)
