"""minicpm3-4b — dense LM with MLA [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448 (padded to 73472 for 16-way TP).
MLA: q_lora 768, kv_lora 256, qk nope/rope 64/32, v 64.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "minicpm3-4b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    pad_multiple=16,
)
