"""Checkpointing: pytree <-> npz with topology metadata, async save,
MDSS-versioned URIs, and elastic restore onto a different mesh.

Design points for 1000+-node deployments (adapted to this single-process
container; see DESIGN.md §6):

  * every save records the step + a content digest + the mesh topology it
    was sharded for; restore re-shards (``jax.device_put`` with the target
    sharding) so a checkpoint written on one mesh restores onto another
    (elastic scaling),
  * saves go through MDSS URIs (``ckpt://<name>/<step>``) so residency /
    versioning between tiers is tracked exactly like workflow data — a
    restart on the "cloud" tier reuses the cloud copy without a transfer,
  * async mode hands serialization to a background thread; the training
    loop never blocks on disk,
  * atomic rename-on-complete so a crash mid-save never corrupts the latest
    checkpoint (restart skips partial files).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(template, arrays: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, *, mdss=None, async_save: bool = False):
        self.dir = directory
        self.mdss = mdss
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, name: str, step: int, tree, *, topology: Dict[str, Any]):
        arrays = _flatten_with_paths(tree)   # device -> host copy happens here
        if self.async_save:
            self.wait()
            t = threading.Thread(
                target=self._write, args=(name, step, arrays, topology))
            t.start()
            self._pending = t
        else:
            self._write(name, step, arrays, topology)

    def _write(self, name, step, arrays, topology):
        path = os.path.join(self.dir, f"{name}-{step:08d}.npz")
        tmp = path + ".tmp.npz"   # .npz suffix so np.savez writes exactly here
        meta = dict(topology=topology, step=step, time=time.time())
        np.savez(tmp, __meta__=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8), **arrays)
        os.replace(tmp, path)
        with open(os.path.join(self.dir, f"{name}-latest"), "w") as f:
            f.write(str(step))
        if self.mdss is not None:
            self.mdss.put(f"ckpt://{name}/latest", {"path": path, "step": step},
                          tier="local")

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def latest_step(self, name: str) -> Optional[int]:
        p = os.path.join(self.dir, f"{name}-latest")
        if not os.path.exists(p):
            return None
        return int(open(p).read().strip())

    def restore(self, name: str, template, *, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict[str, Any]]:
        """Restore onto ``shardings`` (possibly a *different* mesh — elastic)."""
        self.wait()
        if step is None:
            step = self.latest_step(name)
            if step is None:
                raise FileNotFoundError(f"no checkpoint for {name} in {self.dir}")
        path = os.path.join(self.dir, f"{name}-{step:08d}.npz")
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            arrays = {k: z[k] for k in z.files if k != "__meta__"}
        tree = _unflatten_like(template, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s, t: jax.device_put(x.astype(t.dtype), s),
                tree, shardings, template)
        else:
            tree = jax.tree.map(
                lambda x, t: jax.numpy.asarray(x, t.dtype), tree, template)
        return tree, meta
