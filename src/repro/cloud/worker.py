"""Fabric worker process: ``python -m repro.cloud.worker --connect ...``.

Connects back to the broker, announces itself with a hello message, then
serves tasks one at a time over the socket:

  * ``task``  — resolve the step fn (registry name or pickled function),
    execute with decoded kwargs, reply ``result`` or ``error``;
  * ``ship``  — echo the payload back (the RPCTransport byte-movement
    primitive: the value really crosses the process boundary both ways —
    though with chunk dedup the echo direction is typically metadata-only,
    the broker having just sent those very chunks);
  * ``shutdown`` — exit cleanly.

The socket carries the content-addressed chunk stream (wire.py): unless
started with ``--no-dedup`` the worker keeps a :class:`ChannelStore`
mirroring the broker's, so repeated payload chunks (the same params in
every task's kwargs) arrive as digest references. Each reply also
carries ``req_recv_s`` (how long the request took to stream in) and
``work_s`` (execution time), letting the broker attribute the round
trip per direction — the feed for asymmetric-link bandwidth estimates.

A daemon thread emits heartbeats on an interval so the broker can tell a
hung or SIGKILLed worker from a slow one. Imports are numpy + stdlib
only; a pickled jax step would import jax lazily, but registry steps
keep worker cold-start in the ~100 ms range.
"""
from __future__ import annotations

import argparse
import importlib
import os
import pickle
import socket
import threading
import time
import traceback

from repro.cloud import tasklib
from repro.cloud.wire import ChannelStore, WireError, recv_msg, send_msg


def serve(host: str, port: int, worker_id: str, init_modules, heartbeat_s: float,
          dedup: bool = True):
    for mod in init_modules:
        if mod:
            importlib.import_module(mod)
    sock = socket.create_connection((host, port))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    store = ChannelStore() if dedup else None
    send_lock = threading.Lock()
    with send_lock:
        send_msg(sock, {"op": "hello", "worker_id": worker_id,
                        "pid": os.getpid()}, store)

    stop = threading.Event()

    def heartbeats():
        while not stop.wait(heartbeat_s):
            try:
                with send_lock:
                    send_msg(sock, {"op": "heartbeat",
                                    "worker_id": worker_id}, store)
            except OSError:
                return

    threading.Thread(target=heartbeats, daemon=True).start()

    try:
        while True:
            stats: dict = {}
            try:
                msg, _ = recv_msg(sock, store, stats=stats)
            except (EOFError, OSError, WireError):
                # WireError: corrupted frame / desynced stores — the
                # stream is unrecoverable; exiting lets the broker's
                # death path requeue the in-flight task cleanly
                break
            op = msg.get("op")
            if op == "shutdown":
                break
            t0 = time.perf_counter()
            if op == "ship":
                reply = {"op": "result", "task_id": msg["task_id"],
                         "value": msg.get("value")}
            elif op == "task":
                reply = _run_task(msg)
            else:
                reply = {"op": "error", "task_id": msg.get("task_id", -1),
                         "error": f"unknown op {op!r}"}
            reply["req_recv_s"] = stats.get("recv_s", 0.0)
            reply["work_s"] = time.perf_counter() - t0
            if msg.get("trace") is not None:
                # span context arrived in the task frame header: report
                # this task's phases as (wall t0, duration) dicts — the
                # broker re-materialises them as child spans of the
                # driver-side span identified by msg["trace"]. Wall clock
                # on purpose: it is the one clock both processes share.
                wall1 = time.time()
                work_s = reply["work_s"]
                recv_s = reply["req_recv_s"]
                reply["trace"] = msg["trace"]
                reply["spans"] = [
                    {"name": "recv", "t0": wall1 - work_s - recv_s,
                     "dur": recv_s},
                    {"name": "exec", "t0": wall1 - work_s, "dur": work_s},
                ]
            try:
                with send_lock:
                    send_msg(sock, reply, store)
            except OSError:
                break
    finally:
        stop.set()
        sock.close()


def _run_task(msg) -> dict:
    task_id = msg["task_id"]
    try:
        if msg.get("step"):
            fn = tasklib.resolve(msg["step"])
        else:
            fn = pickle.loads(msg["fn"])
        out = fn(**(msg.get("kwargs") or {}))
        return {"op": "result", "task_id": task_id, "value": out}
    except BaseException as e:  # report everything short of os._exit
        return {"op": "error", "task_id": task_id, "error": repr(e),
                "traceback": traceback.format_exc()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--connect", required=True, help="broker host:port")
    ap.add_argument("--worker-id", required=True)
    ap.add_argument("--init", default="repro.cloud.tasklib",
                    help="comma-separated modules to import at startup")
    ap.add_argument("--heartbeat", type=float, default=0.25)
    ap.add_argument("--no-dedup", action="store_true",
                    help="disable chunk dedup (must match the broker)")
    args = ap.parse_args(argv)
    host, port = args.connect.rsplit(":", 1)
    serve(host, int(port), args.worker_id, args.init.split(","),
          args.heartbeat, dedup=not args.no_dedup)


if __name__ == "__main__":
    main()
