"""Elastic worker autoscaling: queue depth + cost model, warm-pool reuse.

``tick()`` is a pure control step (call it from a loop, a timer, or a
test) that compares the broker's observed state against two signals:

  * **queue pressure** — more than ``queue_high`` queued tasks per live
    worker means we are under-provisioned; scale up proportionally.
  * **drain-time estimate** — when the broker has a task-duration EMA,
    size the pool so the current backlog drains within
    ``target_drain_s`` (the cost-model signal: seconds of queued work,
    not just task count).

Under the multi-tenant runtime, pressure is **aggregate across runs**:
the broker queue already pools every tenant's submitted tasks, and an
optional ``backlog_fn`` adds work the runtime is still holding in its
per-run ready heaps (steps admitted but not yet granted a lane), so a
burst of concurrent submissions scales the pool before the broker queue
alone would show it — and a nonzero runtime backlog blocks scale-down.

A third signal is **residency churn**: ``churn_fn`` reads the MDSS's
cumulative evicted-byte counter, and a churn *rate* above
``churn_high_bytes_per_s`` means tenants are thrashing their residency
budgets — evicting warm data only to re-stage it. Growing the pool (and
with it the working capacity per tenant) is the productive response;
while churn is nonzero, scale-down is also held off.

Scale-down is deliberately slower than scale-up (classic asymmetric
policy): only after the pool has been fully idle with an empty queue for
``idle_scale_down_s`` does one worker retire per tick — and retiring
parks the process in the broker's *warm pool* rather than killing it, so
a traffic burst right after a lull revives the same PID in microseconds
instead of paying process cold-start. ``reap_warm`` finally kills warm
workers older than ``warm_ttl_s``.
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.cloud.broker import Broker


@dataclass
class AutoscalerConfig:
    min_workers: int = 1
    max_workers: int = 4
    queue_high: float = 2.0         # queued tasks per worker that trip scale-up
    target_drain_s: float = 1.0     # desired backlog drain time (cost signal)
    idle_scale_down_s: float = 2.0  # full-idle dwell before retiring a worker
    warm_ttl_s: float = 30.0        # warm worker lifetime before real kill
    churn_high_bytes_per_s: float = 32e6   # eviction churn that means thrash


class Autoscaler:
    def __init__(self, broker: Broker, config: Optional[AutoscalerConfig] = None,
                 backlog_fn: Optional[Callable[[], int]] = None,
                 churn_fn: Optional[Callable[[], int]] = None):
        self.broker = broker
        self.config = config or AutoscalerConfig()
        # aggregate pressure beyond the broker queue: e.g. the multi-tenant
        # runtime's cross-run count of ready-but-unlaned offload steps
        self.backlog_fn = backlog_fn
        # cumulative evicted-bytes counter (MDSS residency budgets); the
        # tick differentiates it into a churn rate
        self.churn_fn = churn_fn
        self._churn_mark: tuple = (None, 0.0)     # (t, cumulative bytes)
        self._idle_since: Optional[float] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.scale_ups = 0
        self.scale_downs = 0
        self.ticks = 0
        self.last_action: dict = {}

    def register_metrics(self, registry):
        registry.gauge("autoscaler.scale_ups", lambda: self.scale_ups)
        registry.gauge("autoscaler.scale_downs", lambda: self.scale_downs)
        registry.gauge("autoscaler.ticks", lambda: self.ticks)
        registry.gauge("autoscaler.desired_workers", self.desired_workers)

    def _backlog(self) -> int:
        if self.backlog_fn is None:
            return 0
        try:
            return max(0, int(self.backlog_fn()))
        except Exception:
            return 0   # runtime mid-shutdown

    def _churn_rate(self, now: float) -> float:
        """Evicted bytes/s since the previous tick (0 with no feed)."""
        if self.churn_fn is None:
            return 0.0
        try:
            total = float(self.churn_fn())
        except Exception:
            return 0.0   # store mid-shutdown
        prev_t, prev_total = self._churn_mark
        self._churn_mark = (now, total)
        if prev_t is None or now <= prev_t:
            return 0.0
        return max(0.0, (total - prev_total) / (now - prev_t))

    # ----------------------------------------------------------------- tick
    def desired_workers(self) -> int:
        cfg = self.config
        depth = self.broker.queue_depth() + self._backlog()
        n = max(1, self.broker.num_workers())
        desired = self.broker.num_workers()
        if depth / n > cfg.queue_high:
            # enough workers that queued-tasks-per-worker <= queue_high,
            # always at least one more than now
            desired = max(desired, math.ceil(depth / cfg.queue_high), n + 1)
        task_s = self.broker.avg_task_seconds()
        if task_s and depth:
            # workers needed to drain `depth` tasks in target_drain_s
            desired = max(desired, math.ceil(depth * task_s / cfg.target_drain_s))
        return max(cfg.min_workers, min(cfg.max_workers, desired))

    def tick(self, now: Optional[float] = None) -> dict:
        """One control step; returns a summary of what it did."""
        cfg = self.config
        now = time.monotonic() if now is None else now
        n = self.broker.num_workers()
        depth = self.broker.queue_depth() + self._backlog()
        busy = self.broker.inflight()
        churn = self._churn_rate(now)
        action = {"workers": n, "queue": depth, "added": 0, "retired": 0,
                  "reaped": 0, "churn_bps": churn}

        desired = self.desired_workers()
        if churn > cfg.churn_high_bytes_per_s:
            # residency thrash: tenants are evicting warm bytes only to
            # re-stage them — grow the pool instead of grinding the wire
            desired = max(desired, min(cfg.max_workers, n + 1))
        if desired > n:
            for _ in range(desired - n):
                self.broker.add_worker()
                self.scale_ups += 1
                action["added"] += 1
            self._idle_since = None
        elif depth == 0 and busy == 0 and churn == 0.0 \
                and n > cfg.min_workers:
            if self._idle_since is None:
                self._idle_since = now
            elif now - self._idle_since >= cfg.idle_scale_down_s:
                if self.broker.retire_worker():
                    self.scale_downs += 1
                    action["retired"] = 1
                self._idle_since = now   # at most one retire per dwell period
        else:
            self._idle_since = None
        action["reaped"] = self.broker.reap_warm(cfg.warm_ttl_s)
        action["workers"] = self.broker.num_workers()
        self.ticks += 1
        self.last_action = action
        return action

    # ----------------------------------------------------- background drive
    def start(self, interval_s: float = 0.5):
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.tick()
                except Exception:
                    pass   # broker mid-shutdown

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="fabric-autoscale")
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
