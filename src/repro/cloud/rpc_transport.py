"""MDSS transport that ships bytes through the offload fabric.

The seed's default ``Transport.transfer`` is a no-op — MDSS *accounted*
movement that never happened. ``RPCTransport`` makes it real: when
either endpoint tier is fabric-backed (``tier.worker_pool`` set), the
value is wire-encoded, round-tripped through a worker process, and
decoded — so ``ensure`` / ``stale_bytes`` accounting now reflects bytes
that genuinely crossed an OS process boundary.

Each ship also yields a bandwidth sample that is fed into
``CostModel.observe_bandwidth``, replacing the static ``DCN_BW``
constant in offload decisions with measured wire throughput (the
scheduler's ``CostModelPolicy`` picks this up via
``CostModel.transfer_time``).

Known cost: for a step that is itself dispatched remotely, staging a
stale input via ``ensure`` round-trips the value through a worker and
the task dispatch ships it once more — the driver process remains the
data plane. A worker-side URI cache (workers holding tier replicas so
``ensure`` targets them directly) is the natural next step and would
also make repeat offloads code-only over the wire.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from repro.core.mdss import Transport


class RPCTransport(Transport):
    def __init__(self, fabric, tiers=None, cost_model=None,
                 ship_timeout_s: float = 60.0):
        super().__init__(tiers)
        self.fabric = fabric
        self.cost_model = cost_model
        self.ship_timeout_s = ship_timeout_s
        # MDSS calls transfer() with no lock held (transfers overlap
        # compute), so the accounting needs its own
        self._lock = threading.Lock()
        self.bytes_shipped: Dict[Tuple[str, str], int] = {}
        self.ship_events: list = []

    def _fabric_backed(self, name: str) -> bool:
        tier = self.tiers.get(name)
        return tier is not None and getattr(tier, "worker_pool", None) is not None

    def transfer(self, value, src: str, dst: str):
        if not (self._fabric_backed(src) or self._fabric_backed(dst)):
            return super().transfer(value, src, dst)
        task = self.fabric.ship(value, timeout=self.ship_timeout_s)
        key = (src, dst)
        with self._lock:
            self.bytes_shipped[key] = self.bytes_shipped.get(key, 0) \
                + task.bytes_sent
            self.ship_events.append((src, dst, task.bytes_sent, task.seconds))
            if self.cost_model is not None and task.seconds > 0:
                self.cost_model.observe_bandwidth(
                    src, dst, task.bytes_sent + task.bytes_received,
                    task.seconds)
        return task.value

    def total_bytes_shipped(self) -> int:
        with self._lock:
            return sum(self.bytes_shipped.values())
