"""MDSS transport that ships bytes through the offload fabric.

The seed's default ``Transport.transfer`` is a no-op — MDSS *accounted*
movement that never happened. ``RPCTransport`` makes it real: when
either endpoint tier is fabric-backed (``tier.worker_pool`` set), the
value is wire-encoded, round-tripped through a worker process, and
decoded — so ``ensure`` / ``stale_bytes`` accounting now reflects bytes
that genuinely crossed an OS process boundary.

Content addressing (``transfer_ex``): MDSS hands over the value's chunk
manifest and how many of those bytes are *not* already resident at the
destination tier. A fully-resident value ships as a **metadata-only
round trip** (just the digests cross the fabric); anything else ships
the value, where the socket-level chunk stores (wire.py) independently
dedup whatever previously crossed that worker's connection. The
returned byte count is the dedup-aware obligation MDSS accounts.

Each ship also yields bandwidth samples fed into
``CostModel.observe_bandwidth``. Workers report how long the request
took to stream in (``req_recv_s``) and how long they computed, so large
ships produce **per-direction** samples — ``(src, dst)`` from the
request leg, ``(dst, src)`` from the reply leg — letting the locality
scorer track asymmetric up/down links; small ships fall back to one
combined sample (a tiny frame measures latency, not bandwidth).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from repro.core.mdss import Transport, nbytes_of

# below this, a leg's timing is latency-dominated: keep feeding the
# combined round-trip sample instead of two noisy directional ones
DIRECTIONAL_MIN_BYTES = 1 << 16


class RPCTransport(Transport):
    def __init__(self, fabric, tiers=None, cost_model=None,
                 ship_timeout_s: float = 60.0):
        super().__init__(tiers)
        self.fabric = fabric
        self.cost_model = cost_model
        self.ship_timeout_s = ship_timeout_s
        # MDSS calls transfer() with no lock held (transfers overlap
        # compute), so the accounting needs its own
        self._lock = threading.Lock()
        self.bytes_shipped: Dict[Tuple[str, str], int] = {}
        self.ship_events: list = []
        self.metadata_only_ships = 0

    def _fabric_backed(self, name: str) -> bool:
        tier = self.tiers.get(name)
        return tier is not None and getattr(tier, "worker_pool", None) is not None

    def transfer(self, value, src: str, dst: str):
        return self.transfer_ex(value, src, dst)[0]

    def transfer_ex(self, value, src: str, dst: str, chunks=None,
                    missing_bytes: Optional[int] = None):
        """Move ``value`` src->dst; returns ``(value, owed_bytes)`` where
        ``owed_bytes`` is the dedup-aware transfer obligation MDSS
        accounts (0 for a metadata-only round trip)."""
        logical = nbytes_of(value)
        owed = logical if missing_bytes is None else missing_bytes
        if not (self._fabric_backed(src) or self._fabric_backed(dst)):
            return super().transfer(value, src, dst), owed
        if chunks is not None and missing_bytes == 0:
            # every chunk already resident at dst: offer digests only —
            # the warm-params staging path collapses to metadata
            task = self.fabric.ship({"digests": [d for d, _ in chunks]},
                                    timeout=self.ship_timeout_s)
            out, observe = value, False
        else:
            task = self.fabric.ship(value, timeout=self.ship_timeout_s)
            out, observe = task.value, True
        key = (src, dst)
        with self._lock:
            self.bytes_shipped[key] = self.bytes_shipped.get(key, 0) \
                + task.bytes_sent
            self.ship_events.append((src, dst, task.bytes_sent, task.seconds))
            if not observe:
                self.metadata_only_ships += 1
            elif self.cost_model is not None:
                directional = False
                if task.up_s > 0 and task.bytes_sent >= DIRECTIONAL_MIN_BYTES:
                    self.cost_model.observe_bandwidth(
                        src, dst, task.bytes_sent, task.up_s)
                    directional = True
                if task.down_s > 0 and \
                        task.bytes_received >= DIRECTIONAL_MIN_BYTES:
                    self.cost_model.observe_bandwidth(
                        dst, src, task.bytes_received, task.down_s)
                    directional = True
                wire_total = task.bytes_sent + task.bytes_received
                if not directional and task.seconds > 0 \
                        and wire_total >= logical:
                    # combined round-trip sample — but only when the
                    # payload genuinely crossed: a dedup-shrunken ship
                    # (refs instead of bytes) measures latency, not
                    # bandwidth, and would poison the EMA
                    self.cost_model.observe_bandwidth(
                        src, dst, wire_total, task.seconds)
        return out, owed

    def total_bytes_shipped(self) -> int:
        with self._lock:
            return sum(self.bytes_shipped.values())
