"""Registered step functions executable inside fabric workers.

Workers cannot unpickle closures or lambdas, so the fabric's primary
dispatch currency is a *registry name*: a step declares
``remote_impl="matmul"`` and every worker resolves it here at task time
(workers import this module — and any extra ``--init`` modules — at
startup). Functions take the step's input URIs as kwargs and return a
dict keyed by output URI, same contract as an in-process step fn, so the
MigrationManager can run the identical function locally as a fallback
tier.

numpy-only on purpose: this module is imported by every worker process
and must not drag jax in.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Optional

import numpy as np

STEP_REGISTRY: Dict[str, Callable] = {}

# Set in the worker process environment by pool.spawn; lets a task know it
# is running inside a fabric worker (used by fault-injection steps that
# must be lethal remotely but harmless when re-run in-process).
WORKER_ENV = "EMERALD_WORKER_ID"


def register_step(name: Optional[str] = None):
    def wrap(fn):
        STEP_REGISTRY[name or fn.__name__] = fn
        return fn
    return wrap


def resolve(name: str) -> Callable:
    try:
        return STEP_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"step {name!r} not registered; known: {sorted(STEP_REGISTRY)}")


def in_worker() -> bool:
    return bool(os.environ.get(WORKER_ENV))


# ------------------------------------------------------------ demo steps
@register_step("echo")
def echo(**kw):
    return kw


@register_step("pid")
def pid(**kw):
    return {"pid": np.int64(os.getpid())}


@register_step("add_one")
def add_one(x=0.0, **kw):
    return {"y": np.asarray(x, dtype=np.float64) + 1.0}


@register_step("matmul")
def matmul(a=None, b=None, **kw):
    return {"c": np.asarray(a) @ np.asarray(b)}


@register_step("sleep")
def sleep(seconds=0.05, **kw):
    time.sleep(float(np.asarray(seconds)))
    return {"slept": np.float64(seconds)}


@register_step("spin")
def spin(seconds=0.05, **kw):
    """Busy-wait — holds a whole worker process, unlike ``sleep``."""
    end = time.perf_counter() + float(np.asarray(seconds))
    x = 0.0
    while time.perf_counter() < end:
        x += 1.0
    return {"spun": np.float64(seconds)}


# ----------------------------------------------------- fault injection
def _bump_counter(path: str) -> int:
    """File-based counter so fault schedules survive worker crashes."""
    try:
        with open(path) as f:
            count = int(f.read() or 0)
    except FileNotFoundError:
        count = 0
    with open(path, "w") as f:
        f.write(str(count + 1))
    return count


@register_step("crash_n_times")
def crash_n_times(counter_file="", n_crashes=1, x=0.0, **kw):
    """Hard-kill the hosting worker for the first ``n_crashes`` calls, then
    succeed — deterministic across processes via ``counter_file``."""
    n = int(np.asarray(n_crashes))
    if _bump_counter(str(counter_file)) < n:
        os._exit(17)
    return {"y": np.asarray(x, dtype=np.float64) + 1.0}


@register_step("fail_n_times")
def fail_n_times(counter_file="", n_fails=1, x=0.0, **kw):
    """Raise (clean remote error, worker survives) for the first
    ``n_fails`` calls, then succeed."""
    n = int(np.asarray(n_fails))
    if _bump_counter(str(counter_file)) < n:
        raise RuntimeError("injected step failure")
    return {"y": np.asarray(x, dtype=np.float64) + 1.0}


@register_step("crash_in_worker")
def crash_in_worker(x=0.0, **kw):
    """Kill the process when running inside a fabric worker; succeed when
    re-run in-process — exercises the executor's tier-fallback path."""
    if in_worker():
        os._exit(17)
    return {"y": np.asarray(x, dtype=np.float64) * 10.0}
