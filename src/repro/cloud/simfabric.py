"""SimFabric — a deterministic, virtual-clock stand-in for the fabric.

The real fabric (broker + worker pool) resolves its nondeterminism with
wall-clock threads: whichever worker's reply frame hits its reader
thread first completes first, crashes land whenever the OS kills a
process, and ship timeouts fire on real seconds. ``emcheck``'s
schedule-space explorer (``repro.analysis.explorer``) needs those same
decision points made *explicit and replayable* instead: every "which
in-flight completion lands first / which worker crashes / which ship
times out" choice is a value an explorer picks, not an accident of
thread timing.

``SimFabric`` is that seam. It models exactly the fabric state the
runtime's scheduler can observe — lane slot occupancy, the in-flight
task set, per-task attempt counts, bounded fault budgets — on a virtual
clock that advances one tick per decision. It executes nothing: the
explorer owns step semantics (stores, memo, events) and calls
``dispatch`` / ``complete`` / ``crash`` / ``timeout`` / ``preempt`` in
whatever order its schedule dictates. Identical decision sequences
therefore produce identical states, which is what makes a recorded
``Schedule`` a deterministic reproducer.

Fault semantics mirror the broker's: a ``crash`` burns one of the
task's retry attempts (the broker requeues in-flight work on worker
death and the runtime's lane retries internally, so no new ``dispatch``
event is observed); a ``timeout``/``preempt`` requeues without burning
an attempt (the ``ShipTimeout``-harvest / spot-reclaim shape). A task
whose attempts exceed its budget is the fabric's ``WorkerLostError``:
the step fails.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

OFFLOAD = "offload"
LOCAL = "local"


class SimClock:
    """Virtual time: one tick per scheduler decision. Monotonic and
    identical across replays of the same decision sequence."""

    def __init__(self):
        self.t = 0.0

    def tick(self) -> float:
        self.t += 1.0
        return self.t

    def now(self) -> float:
        return self.t


@dataclass
class SimTask:
    """One in-flight (run, step) occupying a lane slot."""
    run_id: str
    step: str
    lane: str                        # OFFLOAD | LOCAL
    retries: int                     # crash budget before the step fails
    attempts: int = 0                # crashes absorbed so far
    dispatched_t: float = 0.0
    # memoization linkage (maintained by the explorer): a waiter's
    # completion is gated on its owner's completion
    wait_key: Optional[str] = None
    memo_hit: bool = False

    @property
    def key(self) -> Tuple[str, str]:
        return (self.run_id, self.step)


class SimFabric:
    """Deterministic lane + in-flight bookkeeping for the explorer.

    ``offload_slots``/``local_slots`` mirror the runtime's two lane
    pools. ``max_crashes``/``max_timeouts``/``max_preempts`` bound the
    fault-injection decision space (0 = that fault kind is never an
    enabled decision), keeping exhaustive exploration finite.
    """

    def __init__(self, clock: SimClock, *, offload_slots: int = 2,
                 local_slots: int = 1, max_crashes: int = 0,
                 max_timeouts: int = 0, max_preempts: int = 0):
        self.clock = clock
        self.slots = {OFFLOAD: offload_slots, LOCAL: local_slots}
        self.busy = {OFFLOAD: 0, LOCAL: 0}
        self.crashes_left = max_crashes
        self.timeouts_left = max_timeouts
        self.preempts_left = max_preempts
        # dispatch order == completion-decision enumeration order; a
        # dict keyed by (run, step) keeps lookups O(1) and iteration
        # deterministic (insertion order)
        self._inflight: Dict[Tuple[str, str], SimTask] = {}

    # ------------------------------------------------------------- queries
    def free(self, lane: str) -> int:
        return self.slots[lane] - self.busy[lane]

    def inflight(self) -> List[SimTask]:
        return list(self._inflight.values())

    def task(self, run_id: str, step: str) -> Optional[SimTask]:
        return self._inflight.get((run_id, step))

    def idle(self) -> bool:
        return not self._inflight

    # ------------------------------------------------------------ mutation
    def dispatch(self, run_id: str, step: str, lane: str,
                 retries: int = 2) -> SimTask:
        assert self.free(lane) > 0, f"no free {lane} slot"
        t = SimTask(run_id, step, lane, retries,
                    dispatched_t=self.clock.now())
        self._inflight[t.key] = t
        self.busy[lane] += 1
        return t

    def complete(self, run_id: str, step: str) -> SimTask:
        t = self._inflight.pop((run_id, step))
        self.busy[t.lane] -= 1
        return t

    def crash(self, run_id: str, step: str) -> bool:
        """Worker death under the task. Returns True when the broker's
        requeue absorbs it (attempt burned, task still in flight) and
        False when the attempt budget is exhausted (the step fails and
        leaves the fabric)."""
        assert self.crashes_left > 0
        self.crashes_left -= 1
        t = self._inflight[(run_id, step)]
        t.attempts += 1
        if t.attempts <= t.retries:
            return True
        self._inflight.pop(t.key)
        self.busy[t.lane] -= 1
        return False

    def timeout(self, run_id: str, step: str) -> None:
        """Ship timeout: the task is harvested and retried in place —
        no attempt burned (the broker cancelled a queued ship or kept
        the in-flight one harvestable)."""
        assert self.timeouts_left > 0
        self.timeouts_left -= 1

    def preempt(self, run_id: str, step: str) -> None:
        """Spot-style reclaim of the worker under the task; the lease
        revocation requeues the step without burning an attempt."""
        assert self.preempts_left > 0
        self.preempts_left -= 1

    def drop_run(self, run_id: str) -> List[SimTask]:
        """A failing run drains: its in-flight tasks leave the fabric
        without completing (their dones are legitimately lost)."""
        dropped = [t for t in self._inflight.values()
                   if t.run_id == run_id]
        for t in dropped:
            self._inflight.pop(t.key)
            self.busy[t.lane] -= 1
        return dropped

    # ----------------------------------------------------------- identity
    def state_key(self) -> tuple:
        """Canonical hashable fabric state (time-independent) for the
        explorer's visited-state dedup."""
        return (tuple(sorted(
                    (k, t.attempts, t.wait_key, t.memo_hit)
                    for k, t in self._inflight.items())),
                self.busy[OFFLOAD], self.busy[LOCAL],
                self.crashes_left, self.timeouts_left, self.preempts_left)
