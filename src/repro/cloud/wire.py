"""Content-addressed streaming wire format for the offload fabric.

Workers must start fast, so this module imports only numpy + stdlib.
A value is flattened by structural recursion (dict / list / tuple /
namedtuple); array leaves — numpy arrays and anything array-protocol
shaped such as ``jax.Array`` — are lifted out as raw contiguous byte
buffers, and the remaining skeleton (containers, scalars, strings,
``None``) is pickled.

The v1 format shipped each message as one monolithic frame that both
ends held end-to-end (encode copied every buffer, the receiver read the
whole payload into one blob, then copied it again into arrays). v2 is a
**chunked, content-addressed stream**:

  * every buffer is split into ``CHUNK_BYTES`` windows, each tagged with
    a truncated SHA-256 digest;
  * the header frame (skeleton pickle + per-buffer chunk manifest) goes
    first, then each chunk streams as its own wire unit — the receiver
    allocates the destination buffer up front and ``recv_into``s chunks
    directly, so decode/install overlaps the remaining transfer and no
    whole-payload intermediate copy ever exists;
  * with a :class:`ChannelStore`, chunks the peer is known to hold are
    sent as **digest references** instead of bytes — repeated payloads
    (warm params, re-staged observations) become metadata-only;
  * a reference to a digest the receiver does not hold, a digest
    mismatch on an inline chunk, or a malformed header raise
    :class:`WireError` immediately instead of desynchronising or
    hanging the stream (callers treat it like a dead connection).

Dedup bookkeeping never negotiates: each direction of a socket is an
ordered stream, so the sender's record of what it has sent (``sent``)
and the receiver's cache of what it has received (``received``) see the
same chunk insertions in the same order and evict FIFO at the same cap —
the sender's copy is an exact mirror of the receiver's, and a chunk is
referenced only when the mirror still holds it. Cross-direction
references (echoing back a value just received) resolve against the
opposite store pair. A connection whose send was interrupted mid-plan
must discard its stores (the broker kills the worker instead).

``send_msg`` / ``recv_msg`` return the framed byte count so every
cross-process movement is accounted — these counts are what
``RPCTransport`` feeds back into the cost model as observed wire
bandwidth, and with dedup they reflect the bytes that *actually*
crossed, not the logical payload size.
"""
from __future__ import annotations

import hashlib
import pickle
import struct
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"EMW2"
_HEAD = struct.Struct("!4sQ")        # magic + header pickle length
_LEN = struct.Struct("!Q")

CHUNK_BYTES = 1 << 20                # transfer/dedup granularity
DIGEST_BYTES = 16                    # truncated sha256
STORE_BYTES = 128 << 20              # per-direction chunk cache cap
_MAX_HEADER = 1 << 31

_INLINE, _REF = 0, 1


class WireError(ValueError):
    pass


def digest_of(data) -> bytes:
    """Truncated SHA-256 of a bytes-like (OpenSSL-accelerated)."""
    return hashlib.sha256(data).digest()[:DIGEST_BYTES]


# ------------------------------------------------------------- chunk stores
class ChunkStore:
    """One direction's content-addressed chunk cache.

    Mirrored FIFO: both endpoints of a socket direction insert the same
    chunks in the same (stream) order and evict oldest-first at the same
    byte cap, so a sender's ``sent`` store is an exact model of the
    receiver's ``received`` store — a sender never references a chunk
    the receiver has already evicted. Insertions never reorder (no LRU
    touch), which is what keeps the two copies in lockstep.
    """

    def __init__(self, max_bytes: int = STORE_BYTES):
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self._chunks: "OrderedDict[bytes, bytes]" = OrderedDict()
        self.bytes_held = 0
        self.evicted = 0

    def has(self, d: bytes) -> bool:
        with self._lock:
            return d in self._chunks

    def get(self, d: bytes) -> Optional[bytes]:
        with self._lock:
            return self._chunks.get(d)

    def add(self, d: bytes, data: bytes):
        with self._lock:
            if d in self._chunks:
                return
            self._chunks[d] = data
            self.bytes_held += len(data)
            while self.bytes_held > self.max_bytes and self._chunks:
                _, old = self._chunks.popitem(last=False)
                self.bytes_held -= len(old)
                self.evicted += 1

    def __len__(self):
        with self._lock:
            return len(self._chunks)


class ChannelStore:
    """Per-connection dedup state (one per socket endpoint).

    ``sent`` mirrors what the peer has received from us; ``received``
    holds what we received (and mirrors the peer's ``sent``). A sender
    may reference any chunk present in either — the peer's pair holds
    it — and a receiver resolves references against both.
    """

    def __init__(self, max_bytes: int = STORE_BYTES):
        self.sent = ChunkStore(max_bytes)
        self.received = ChunkStore(max_bytes)
        self.dedup_chunks = 0        # chunks sent as refs
        self.saved_bytes = 0         # payload bytes dedup kept off the wire

    def known(self, d: bytes) -> bool:
        return self.sent.has(d) or self.received.has(d)

    def lookup(self, d: bytes) -> Optional[bytes]:
        got = self.received.get(d)
        return got if got is not None else self.sent.get(d)

    def stats(self) -> dict:
        """Dedup effectiveness + cache occupancy for this connection."""
        return {
            "dedup_chunks": self.dedup_chunks,
            "saved_bytes": self.saved_bytes,
            "sent_chunks": len(self.sent),
            "sent_bytes_held": self.sent.bytes_held,
            "received_chunks": len(self.received),
            "received_bytes_held": self.received.bytes_held,
            "evicted": self.sent.evicted + self.received.evicted,
        }


# ------------------------------------------------------------- tree <-> wire
@dataclass(frozen=True)
class _Buf:
    """Skeleton placeholder for an array leaf lifted into ``buffers``."""
    idx: int
    dtype: str
    shape: Tuple[int, ...]


def _is_foreign_array(obj) -> bool:
    """Array-protocol object that is not numpy (e.g. jax.Array) — detected
    without importing jax so workers never pay its import cost."""
    return (not isinstance(obj, (np.ndarray, np.generic))
            and hasattr(obj, "__array__")
            and hasattr(obj, "dtype")
            and hasattr(obj, "shape"))


def _as_bytes_view(a: np.ndarray) -> memoryview:
    """Flat byte view of a contiguous array — no copy on the happy path."""
    try:
        return memoryview(a.reshape(-1)).cast("B")
    except (TypeError, ValueError):
        return memoryview(a.tobytes())


def _strip(obj, buffers: List[memoryview]):
    if isinstance(obj, np.ndarray) and obj.dtype != object:
        a = np.ascontiguousarray(obj)
        buffers.append(_as_bytes_view(a))
        return _Buf(len(buffers) - 1, a.dtype.str, a.shape)
    if _is_foreign_array(obj):
        return _strip(np.asarray(obj), buffers)
    if isinstance(obj, dict):
        return {k: _strip(v, buffers) for k, v in obj.items()}
    if isinstance(obj, tuple):
        vals = [_strip(v, buffers) for v in obj]
        return type(obj)(*vals) if hasattr(obj, "_fields") else tuple(vals)
    if isinstance(obj, list):
        return [_strip(v, buffers) for v in obj]
    return obj


def _fill(obj, buffers: List[Any]):
    if isinstance(obj, _Buf):
        try:
            arr = np.frombuffer(buffers[obj.idx], dtype=np.dtype(obj.dtype))
            return arr.reshape(obj.shape)     # bytearray-backed -> writable
        except (ValueError, TypeError) as e:
            raise WireError(f"buffer {obj.idx} does not fit "
                            f"{obj.dtype}{obj.shape}: {e}") from e
    if isinstance(obj, dict):
        return {k: _fill(v, buffers) for k, v in obj.items()}
    if isinstance(obj, tuple):
        vals = [_fill(v, buffers) for v in obj]
        return type(obj)(*vals) if hasattr(obj, "_fields") else tuple(vals)
    if isinstance(obj, list):
        return [_fill(v, buffers) for v in obj]
    return obj


# ------------------------------------------------------------ send planning
@dataclass
class MsgPlan:
    """A fully planned message: wire parts + byte accounting.

    Planning marks referenced/sent chunks in the store, so a plan MUST be
    sent (or the connection's stores discarded) — the broker plans, stamps
    its byte counters, then streams, and kills the worker on any error.
    """
    parts: List[Any]                 # bytes / memoryview, sendall in order
    nbytes: int                      # bytes that will cross the wire
    payload_bytes: int               # logical size (before dedup)
    saved_bytes: int                 # payload bytes elided as refs
    _keepalive: List[Any] = field(default_factory=list)

    def send(self, sock):
        for p in self.parts:
            sock.sendall(p)


def plan_msg(value: Any, store: Optional[ChannelStore] = None,
             chunk_bytes: int = CHUNK_BYTES) -> MsgPlan:
    buffers: List[memoryview] = []
    skeleton = _strip(value, buffers)
    manifests: List[List[Tuple[Optional[bytes], int, int]]] = []
    chunk_parts: List[memoryview] = []
    saved = 0
    for mv in buffers:
        entries: List[Tuple[Optional[bytes], int, int]] = []
        n = mv.nbytes
        for off in range(0, n, chunk_bytes):
            piece = mv[off:off + chunk_bytes]
            if store is not None:
                d = digest_of(piece)
                if store.known(d):
                    entries.append((d, len(piece), _REF))
                    saved += len(piece)
                    continue
                store.sent.add(d, bytes(piece))
                entries.append((d, len(piece), _INLINE))
            else:
                entries.append((None, len(piece), _INLINE))
            chunk_parts.append(piece)
        manifests.append(entries)
    header = pickle.dumps(
        {"skel": skeleton, "chunks": manifests, "dedup": store is not None},
        protocol=pickle.HIGHEST_PROTOCOL)
    parts: List[Any] = [_HEAD.pack(MAGIC, len(header)), header]
    parts.extend(chunk_parts)
    inline = sum(len(p) for p in chunk_parts)
    payload = _HEAD.size + len(header) + inline + saved
    if store is not None and saved:
        store.dedup_chunks += sum(1 for ents in manifests
                                  for (_, _, m) in ents if m == _REF)
        store.saved_bytes += saved
    return MsgPlan(parts, _HEAD.size + len(header) + inline, payload, saved,
                   _keepalive=buffers)


def send_msg(sock, value: Any, store: Optional[ChannelStore] = None) -> int:
    """Stream ``value`` as header + chunk frames; returns wire bytes."""
    plan = plan_msg(value, store)
    plan.send(sock)
    return plan.nbytes


def encode(value: Any, store: Optional[ChannelStore] = None,
           chunk_bytes: int = CHUNK_BYTES) -> bytes:
    """One-shot encode (the full wire stream as a single bytes)."""
    plan = plan_msg(value, store, chunk_bytes)
    return b"".join(bytes(p) if not isinstance(p, bytes) else p
                    for p in plan.parts)


# ----------------------------------------------------------------- receiving
def _recvall(sock, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise EOFError("socket closed mid-frame")
        buf += chunk
    return bytes(buf)


def _recvall_into(sock, mv: memoryview):
    while len(mv):
        r = sock.recv_into(mv)
        if r == 0:
            raise EOFError("socket closed mid-chunk")
        mv = mv[r:]


class _BytesSource:
    """Adapter so decode-from-bytes shares the streaming parser."""

    def __init__(self, data):
        self.data = memoryview(data)
        self.off = 0

    def take(self, n: int) -> bytes:
        if self.off + n > len(self.data):
            raise WireError(f"short frame: wanted {n} more bytes")
        out = bytes(self.data[self.off:self.off + n])
        self.off += n
        return out

    def take_into(self, mv: memoryview):
        n = len(mv)
        if self.off + n > len(self.data):
            raise WireError(f"short frame: wanted {n} more bytes")
        mv[:] = self.data[self.off:self.off + n]
        self.off += n


class _SockSource:
    def __init__(self, sock):
        self.sock = sock

    def take(self, n: int) -> bytes:
        return _recvall(self.sock, n)

    def take_into(self, mv: memoryview):
        _recvall_into(self.sock, mv)


def _read_msg(src, store: Optional[ChannelStore]) -> Tuple[Any, int]:
    return _read_body(src.take(_HEAD.size), src, store)


def _read_body(head: bytes, src, store: Optional[ChannelStore]
               ) -> Tuple[Any, int]:
    magic, hlen = _HEAD.unpack(head)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r}")
    if hlen > _MAX_HEADER:
        raise WireError(f"implausible header length {hlen}")
    try:
        meta = pickle.loads(src.take(hlen))
        skeleton = meta["skel"]
        manifests = meta["chunks"]
        dedup = bool(meta.get("dedup"))
    except WireError:
        raise
    except Exception as e:
        raise WireError(f"undecodable header: {e!r}") from e
    nread = _HEAD.size + hlen
    buffers: List[bytearray] = []
    for entries in manifests:
        total = sum(ln for _, ln, _ in entries)
        buf = bytearray(total)
        mv = memoryview(buf)
        off = 0
        for d, ln, mode in entries:
            dest = mv[off:off + ln]
            if mode == _INLINE:
                src.take_into(dest)
                nread += ln
                if d is not None:
                    if digest_of(dest) != d:
                        raise WireError(
                            f"chunk digest mismatch at offset {off} "
                            f"({ln} bytes): corrupted frame")
                    if dedup and store is not None:
                        store.received.add(d, bytes(dest))
            elif mode == _REF:
                data = store.lookup(d) if store is not None else None
                if data is None or len(data) != ln:
                    raise WireError(
                        f"reference to unknown chunk digest {d!r:.20} "
                        f"({ln} bytes): peer/receiver stores desynced")
                dest[:] = data
            else:
                raise WireError(f"unknown chunk mode {mode!r}")
            off += ln
        buffers.append(buf)
    return _fill(skeleton, buffers), nread


def recv_msg(sock, store: Optional[ChannelStore] = None,
             stats: Optional[Dict[str, float]] = None) -> Tuple[Any, int]:
    """Receive one message; returns ``(value, wire_bytes_read)``.

    With ``stats`` (a dict), fills ``recv_s`` — the wall time from the
    header's arrival to the last chunk, i.e. transfer time excluding the
    idle wait for the message to start. Workers report it back so the
    broker can attribute round-trip time per direction.
    """
    src = _SockSource(sock)
    head = src.take(_HEAD.size)       # blocks idle until a message starts
    t0 = time.perf_counter()
    value, nread = _read_body(head, src, store)
    if stats is not None:
        stats["recv_s"] = time.perf_counter() - t0
        stats["wire_bytes"] = nread
    return value, nread


def decode(data, store: Optional[ChannelStore] = None) -> Any:
    value, _ = _read_msg(_BytesSource(data), store)
    return value


# --------------------------------------------------------------- manifests
def manifest_of(value: Any, chunk_bytes: int = CHUNK_BYTES
                ) -> Tuple[bytes, List[Tuple[bytes, int]]]:
    """``(content_digest, [(chunk_digest, length), ...])`` of a value.

    The chunk list is what a content-addressed store indexes (which
    chunks are resident where); the content digest — skeleton pickle +
    chunk digests — identifies the whole value for step memoization.
    """
    buffers: List[memoryview] = []
    skeleton = _strip(value, buffers)
    h = hashlib.sha256(pickle.dumps(skeleton,
                                    protocol=pickle.HIGHEST_PROTOCOL))
    chunks: List[Tuple[bytes, int]] = []
    for mv in buffers:
        n = mv.nbytes
        for off in range(0, n, chunk_bytes):
            piece = mv[off:off + chunk_bytes]
            d = digest_of(piece)
            chunks.append((d, len(piece)))
            h.update(d)
    return h.digest()[:DIGEST_BYTES], chunks


def content_digest(value: Any) -> bytes:
    """Digest identifying a value's full content (structure + bytes)."""
    return manifest_of(value)[0]
