"""Pytree wire format + socket framing for the offload fabric.

Workers must start fast, so this module imports only numpy + stdlib.
A value is flattened by structural recursion (dict / list / tuple /
namedtuple); array leaves — numpy arrays and anything array-protocol
shaped such as ``jax.Array`` — are lifted out as raw contiguous byte
buffers, and the remaining skeleton (containers, scalars, strings,
``None``) is pickled. Frame layout:

    !4s  magic  b"EMW1"
    !Q   skeleton pickle length
    !I   buffer count
    skeleton pickle
    per buffer: !Q length + raw bytes

``send_msg`` / ``recv_msg`` add an outer ``!Q`` length prefix so one
socket carries a stream of self-delimiting frames. Both return the
framed byte count so every cross-process movement is accounted — these
counts are what ``RPCTransport`` feeds back into the cost model as
observed wire bandwidth.
"""
from __future__ import annotations

import pickle
import struct
from dataclasses import dataclass
from typing import Any, List, Tuple

import numpy as np

MAGIC = b"EMW1"
_HEAD = struct.Struct("!4sQI")
_LEN = struct.Struct("!Q")


class WireError(ValueError):
    pass


@dataclass(frozen=True)
class _Buf:
    """Skeleton placeholder for an array leaf lifted into ``buffers``."""
    idx: int
    dtype: str
    shape: Tuple[int, ...]


def _is_foreign_array(obj) -> bool:
    """Array-protocol object that is not numpy (e.g. jax.Array) — detected
    without importing jax so workers never pay its import cost."""
    return (not isinstance(obj, (np.ndarray, np.generic))
            and hasattr(obj, "__array__")
            and hasattr(obj, "dtype")
            and hasattr(obj, "shape"))


def _strip(obj, buffers: List[bytes]):
    if isinstance(obj, np.ndarray) and obj.dtype != object:
        a = np.ascontiguousarray(obj)
        buffers.append(a.tobytes())
        return _Buf(len(buffers) - 1, a.dtype.str, a.shape)
    if _is_foreign_array(obj):
        return _strip(np.asarray(obj), buffers)
    if isinstance(obj, dict):
        return {k: _strip(v, buffers) for k, v in obj.items()}
    if isinstance(obj, tuple):
        vals = [_strip(v, buffers) for v in obj]
        return type(obj)(*vals) if hasattr(obj, "_fields") else tuple(vals)
    if isinstance(obj, list):
        return [_strip(v, buffers) for v in obj]
    return obj


def _fill(obj, buffers: List[bytes]):
    if isinstance(obj, _Buf):
        arr = np.frombuffer(buffers[obj.idx], dtype=np.dtype(obj.dtype))
        return arr.reshape(obj.shape).copy()   # copy -> writable
    if isinstance(obj, dict):
        return {k: _fill(v, buffers) for k, v in obj.items()}
    if isinstance(obj, tuple):
        vals = [_fill(v, buffers) for v in obj]
        return type(obj)(*vals) if hasattr(obj, "_fields") else tuple(vals)
    if isinstance(obj, list):
        return [_fill(v, buffers) for v in obj]
    return obj


def encode(value: Any) -> bytes:
    buffers: List[bytes] = []
    skeleton = _strip(value, buffers)
    meta = pickle.dumps(skeleton, protocol=pickle.HIGHEST_PROTOCOL)
    parts = [_HEAD.pack(MAGIC, len(meta), len(buffers)), meta]
    for b in buffers:
        parts.append(_LEN.pack(len(b)))
        parts.append(b)
    return b"".join(parts)


def decode(data: bytes) -> Any:
    if len(data) < _HEAD.size:
        raise WireError(f"short frame: {len(data)} bytes")
    magic, meta_len, nbuf = _HEAD.unpack_from(data, 0)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r}")
    off = _HEAD.size
    skeleton = pickle.loads(data[off:off + meta_len])
    off += meta_len
    buffers: List[bytes] = []
    for _ in range(nbuf):
        (blen,) = _LEN.unpack_from(data, off)
        off += _LEN.size
        buffers.append(data[off:off + blen])
        off += blen
    return _fill(skeleton, buffers)


# ------------------------------------------------------------------ sockets
def _recvall(sock, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise EOFError("socket closed mid-frame")
        buf += chunk
    return bytes(buf)


def frame(value: Any) -> bytes:
    """Encode ``value`` with the outer length prefix, ready to sendall."""
    data = encode(value)
    return _LEN.pack(len(data)) + data


def send_msg(sock, value: Any) -> int:
    """Frame + send ``value``; returns total bytes put on the wire."""
    data = frame(value)
    sock.sendall(data)
    return len(data)


def recv_msg(sock) -> Tuple[Any, int]:
    """Receive one frame; returns ``(value, total_bytes_read)``."""
    (n,) = _LEN.unpack(_recvall(sock, _LEN.size))
    data = _recvall(sock, n)
    return decode(data), _LEN.size + n
