"""Worker pool: spawns and reaps fabric worker subprocesses.

The pool owns the listening socket workers dial back into and the
process lifecycle (spawn, hello handshake, kill). Scheduling state —
idle / busy / warm, heartbeats, in-flight tasks — lives on the
``WorkerHandle`` but is driven by the broker, which also runs the
per-worker reader threads. Warm-pool policy (retiring a worker without
killing it so a later scale-up reuses the live process) is the broker /
autoscaler's business; the pool only ever spawns fresh processes and
kills dead ones.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import socket

from repro.cloud import tasklib
from repro.cloud.wire import WireError, recv_msg

_SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


class SpawnError(RuntimeError):
    pass


@dataclass
class WorkerHandle:
    worker_id: str
    proc: subprocess.Popen
    sock: socket.socket
    pid: int
    state: str = "idle"                 # idle | busy | warm | dead
    current: Optional[object] = None    # in-flight Task (broker-owned)
    last_heartbeat: float = field(default_factory=time.monotonic)
    warm_since: float = 0.0
    reader: Optional[threading.Thread] = None
    store: Optional[object] = None      # wire.ChannelStore (broker-owned)


class WorkerPool:
    def __init__(self, *, init_modules: Sequence[str] = ("repro.cloud.tasklib",),
                 heartbeat_s: float = 0.25, spawn_timeout_s: float = 30.0,
                 python: str = sys.executable, dedup: bool = True):
        self.init_modules = tuple(init_modules)
        self.heartbeat_s = heartbeat_s
        self.dedup = dedup          # workers must match the broker's setting
        self.spawn_timeout_s = spawn_timeout_s
        self.python = python
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(32)
        self._port = self._listener.getsockname()[1]
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._counter = 0
        self._closed = False
        self._pending: dict = {}   # worker_id -> (sock, pid) awaiting pickup
        self.spawned_total = 0
        # hellos are collected by a dedicated accept thread so concurrent
        # spawns overlap (worker cold-start is the dominant cost)
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          daemon=True, name="fabric-accept")
        self._acceptor.start()

    # ------------------------------------------------------------ lifecycle
    def _accept_loop(self):
        while True:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return   # listener closed
            conn.settimeout(self.spawn_timeout_s)
            try:
                hello, _ = recv_msg(conn)
            except (EOFError, OSError, WireError, socket.timeout):
                conn.close()
                continue
            if hello.get("op") != "hello":
                conn.close()
                continue
            conn.settimeout(None)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._cond:
                self._pending[hello["worker_id"]] = (conn, int(hello["pid"]))
                self._cond.notify_all()

    def spawn(self) -> WorkerHandle:
        """Launch a fresh worker process and complete the hello handshake.
        Safe to call from several threads at once — cold-starts overlap."""
        with self._lock:
            if self._closed:
                raise SpawnError("pool closed")
            self._counter += 1
            wid = f"w{self._counter}"
        env = os.environ.copy()
        env[tasklib.WORKER_ENV] = wid
        path = env.get("PYTHONPATH", "")
        if _SRC_DIR not in path.split(os.pathsep):
            env["PYTHONPATH"] = (_SRC_DIR + os.pathsep + path) if path \
                else _SRC_DIR
        cmd = [self.python, "-m", "repro.cloud.worker",
               "--connect", f"127.0.0.1:{self._port}",
               "--worker-id", wid,
               "--init", ",".join(self.init_modules),
               "--heartbeat", str(self.heartbeat_s)]
        if not self.dedup:
            cmd.append("--no-dedup")
        proc = subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL)
        deadline = time.monotonic() + self.spawn_timeout_s
        with self._cond:
            while wid not in self._pending:
                if proc.poll() is not None:
                    raise SpawnError(f"worker {wid} exited "
                                     f"rc={proc.returncode} before connecting")
                if self._closed or time.monotonic() >= deadline:
                    proc.kill()
                    raise SpawnError(f"worker {wid} hello timed out")
                self._cond.wait(0.1)
            sock, pid = self._pending.pop(wid)
            self.spawned_total += 1
        return WorkerHandle(wid, proc, sock, pid)

    def register_metrics(self, registry):
        registry.gauge("pool.spawned_total", lambda: self.spawned_total)
        registry.gauge("pool.pending_hellos", lambda: len(self._pending))

    def kill(self, h: WorkerHandle, grace_s: float = 2.0):
        h.state = "dead"
        try:
            h.sock.close()
        except OSError:
            pass
        if h.proc.poll() is None:
            h.proc.terminate()
            try:
                h.proc.wait(timeout=grace_s)
            except subprocess.TimeoutExpired:
                h.proc.kill()
                h.proc.wait(timeout=grace_s)

    def close(self):
        with self._lock:
            self._closed = True
            try:
                self._listener.close()
            except OSError:
                pass
