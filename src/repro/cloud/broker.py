"""Task broker: queues offloads, dispatches to workers, survives crashes.

The broker is the cloud-side "service" of the paper's Emerald: it owns a
priority task queue (higher ``priority`` classes dispatch first, FIFO
within a class — an interactive run's tasks overtake a batch run's), a
dispatcher thread that pairs queued tasks with idle workers the moment
either appears (condition-variable driven, no polling), one reader
thread per worker socket, and a monitor thread that watches heartbeats /
process liveness. Failure semantics:

  * a worker that dies (socket EOF, process exit, stale heartbeat) has
    its in-flight task **requeued at the front** with the dead worker
    excluded, up to ``max_attempts`` total placements — after that the
    task's future gets ``WorkerLostError``;
  * a clean remote exception comes back as ``RemoteStepError`` (the
    worker survives and returns to the idle set);
  * dead workers are replaced by default so capacity holds steady; the
    autoscaler owns deliberate scale-up/down on top of that.

Byte accounting: every framed message in either direction is counted,
and ``ship`` round-trips (pure data movement, no compute) produce
bandwidth samples — the observed-wire-bandwidth feed for the cost model.
"""
from __future__ import annotations

import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

from concurrent.futures import Future

from repro.cloud.pool import WorkerHandle, WorkerPool
from repro.cloud.wire import (ChannelStore, WireError, plan_msg, recv_msg,
                              send_msg)
from repro.obs.tracing import Tracer, wall_now


class FabricError(RuntimeError):
    """Base class for fabric-side task failures."""


class RemoteStepError(FabricError):
    """The step fn raised inside the worker (worker survived)."""


class WorkerLostError(FabricError):
    """The task's worker died and the requeue budget is exhausted."""


class ShipTimeout(FabricError):
    """``ship`` did not complete within its timeout. ``task`` carries the
    handle the old API swallowed: when the ship was still queued it has
    been cancelled (removed from the queue, future failed with
    ``FabricError``); when already in flight the worker will still reply,
    and ``task.result()`` / ``task.done()`` harvest it — the result no
    longer lands in a dead inbox."""

    def __init__(self, msg: str, task: "Task"):
        super().__init__(msg)
        self.task = task


@dataclass
class Task:
    task_id: int
    kind: str                       # "task" | "ship"
    step: Optional[str] = None      # registry name
    fn_bytes: Optional[bytes] = None
    kwargs: Optional[dict] = None
    value: Any = None               # ship payload
    priority: int = 0               # dispatch class; higher preempts queue
    trace_ctx: Any = None           # (trace_id, span_id) to propagate over
                                    # the wire; worker phases parent to it
    max_attempts: int = 3
    attempts: int = 0               # placements so far
    # the serving front door may checkpoint-abort this task in flight
    # (worker killed, task requeued attempt-free) to protect an
    # interactive tenant's SLO; only long batch steps should opt in
    preemptible: bool = False
    preempted: int = 0              # times aborted-and-requeued for SLO
    exclude: Set[str] = field(default_factory=set)
    future: Future = field(default_factory=Future)
    # filled in by dispatch/completion
    bytes_sent: int = 0
    bytes_received: int = 0
    seconds: float = 0.0
    worker_pid: int = 0
    # per-direction split of ``seconds`` (worker-reported request receive
    # time vs the remainder after compute) — feeds asymmetric-link
    # bandwidth observation; 0.0 when the worker predates the field
    up_s: float = 0.0
    down_s: float = 0.0
    _send_t: float = 0.0

    def result(self, timeout: Optional[float] = None):
        return self.future.result(timeout)

    # non-blocking harvest for completion-queue consumers (benchmark
    # drivers, autoscaler probes, bulk submitters): poll or subscribe
    # instead of parking a thread per task. The executor's offload lanes
    # deliberately stay blocking — each lane owns one step's retry /
    # speculation lifecycle end to end.
    def done(self) -> bool:
        return self.future.done()

    def add_done_callback(self, fn):
        """``fn(task)`` runs as soon as the task resolves (result OR
        error), on the broker's reader thread — keep it short."""
        self.future.add_done_callback(lambda _f: fn(self))


class Broker:
    #: failsafe re-check interval for the dispatch loop's condition
    #: wait — bounds how long a lost wakeup can delay noticing
    #: ``_closed`` (teardown), without putting a polling floor under
    #: normal dispatch latency (every real state change still notifies)
    _FAILSAFE_WAKEUP_S = 1.0

    def __init__(self, pool: WorkerPool, *, max_attempts: int = 3,
                 heartbeat_timeout_s: float = 5.0, replace_dead: bool = True,
                 dedup: bool = True):
        self.pool = pool
        self.max_attempts = max_attempts
        # content-addressed dedup on every worker socket: repeated chunks
        # (warm params staged again, echoed ship payloads) cross as digest
        # references. Must match the pool's worker-side setting.
        self.dedup = dedup
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.replace_dead = replace_dead
        self._cond = threading.Condition()
        self._queue: List[Task] = []
        self._workers: Dict[str, WorkerHandle] = {}
        self._inflight: Dict[str, Task] = {}
        self._task_counter = 0
        self._closed = False
        # counters (all mutated under self._cond)
        self.tasks_done = 0
        self.tasks_requeued = 0
        self.tasks_cancelled = 0
        self.tasks_preempted = 0
        self.workers_lost = 0
        self.warm_hits = 0
        self.bytes_sent = 0
        self.bytes_received = 0
        self._bw_ema: Optional[float] = None       # bytes/s from ship ops
        self._task_s_ema: Optional[float] = None   # seconds per task
        # disabled by default; a runtime's attach_fabric swaps in its
        # live tracer so worker-reported phases become spans
        self.tracer = Tracer(enabled=False)
        self._threads: List[threading.Thread] = []
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            daemon=True, name="fabric-dispatch")
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True, name="fabric-monitor")
        self._dispatcher.start()
        self._monitor.start()

    # ----------------------------------------------------------- submission
    def submit(self, *, step: Optional[str] = None,
               fn_bytes: Optional[bytes] = None, kwargs: Optional[dict] = None,
               value: Any = None, kind: str = "task",
               max_attempts: Optional[int] = None, priority: int = 0,
               trace_ctx=None, preemptible: bool = False) -> Task:
        if kind == "task" and not step and fn_bytes is None:
            raise FabricError("task needs a registry step name or fn_bytes")
        with self._cond:
            if self._closed:   # checked under the lock: a task enqueued
                raise FabricError("broker is shut down")   # mid-shutdown
            self._task_counter += 1
            t = Task(self._task_counter, kind, step=step, fn_bytes=fn_bytes,
                     kwargs=kwargs, value=value, priority=priority,
                     trace_ctx=trace_ctx, preemptible=preemptible,
                     max_attempts=max_attempts or self.max_attempts)
            self._queue.append(t)
            self._cond.notify_all()
        return t

    def ship(self, value, timeout: Optional[float] = 60.0) -> Task:
        """Round-trip ``value`` through a worker; returns the completed
        task (``.value`` result, ``.bytes_sent/received``, ``.seconds``).

        On timeout the task is handled explicitly instead of silently
        swallowed: a still-queued ship is **cancelled** (no worker ever
        wastes a slot on it), an in-flight ship stays harvestable via the
        :class:`ShipTimeout` exception's ``task`` — either way no orphan
        result can land in a dead inbox.
        """
        from concurrent.futures import TimeoutError as _FutTimeout
        t = self.submit(kind="ship", value=value)
        try:
            t.value = t.result(timeout)
        except (_FutTimeout, TimeoutError):
            if self.cancel(t):
                raise ShipTimeout(
                    f"ship {t.task_id} timed out after {timeout}s while "
                    "queued; cancelled", t) from None
            raise ShipTimeout(
                f"ship {t.task_id} timed out after {timeout}s in flight; "
                "harvest .task.result() when the worker replies", t) \
                from None
        return t

    def cancel(self, task: Task) -> bool:
        """Withdraw a still-queued task (its future fails with
        ``FabricError``). Returns False when the task already dispatched
        to a worker (or finished) — in-flight work is not interrupted."""
        with self._cond:
            if task not in self._queue:
                return False
            self._queue.remove(task)
            self.tasks_cancelled += 1
        task.future.set_exception(
            FabricError(f"task {task.task_id} cancelled"))
        return True

    def preempt_longest(self) -> Optional[Task]:
        """Checkpoint-abort the longest-running preemptible in-flight
        task: its worker is killed (the spot-reclaim shape the requeue
        machinery already survives) and the task returns to the **front**
        of the queue with its placement attempt refunded — preemption is
        an SLO decision, not a task failure, so it must never consume the
        retry budget (H126). Returns the preempted task, or None when
        nothing in flight is preemptible."""
        with self._cond:
            victims = [(wid, t) for wid, t in self._inflight.items()
                       if t.preemptible and t.kind == "task"]
            if not victims:
                return None
            wid, task = min(victims, key=lambda wt: wt[1]._send_t)
            h = self._workers.get(wid)
            if h is None:
                return None
            # take the worker out of the tables here so the reader
            # thread's exit path (_on_worker_death) early-returns instead
            # of double-requeueing the task or burning its attempt
            h.state = "dead"
            del self._workers[wid]
            del self._inflight[wid]
            task.attempts -= 1          # refund the dispatch-time burn
            task.preempted += 1
            task.exclude.discard(wid)
            self.tasks_preempted += 1
            self.tasks_requeued += 1
            self._queue.insert(0, task)
            replace = self.replace_dead and not self._closed
            self._cond.notify_all()
        self.pool.kill(h)
        if replace:
            try:
                self.add_worker()
            except Exception:
                pass   # pool closed mid-shutdown
        return task

    # -------------------------------------------------------------- workers
    def add_worker(self) -> str:
        """Revive a warm worker if one exists, else spawn a fresh process."""
        with self._cond:
            warm = [h for h in self._workers.values() if h.state == "warm"]
            if warm:
                h = min(warm, key=lambda w: w.warm_since)
                h.state = "idle"
                self.warm_hits += 1
                self._cond.notify_all()
                return h.worker_id
        h = self.pool.spawn()
        h.store = ChannelStore() if self.dedup else None
        h.reader = threading.Thread(target=self._reader_loop, args=(h,),
                                    daemon=True, name=f"fabric-read-{h.worker_id}")
        with self._cond:
            self._workers[h.worker_id] = h
            self._cond.notify_all()
        h.reader.start()
        return h.worker_id

    def start_workers(self, n: int):
        """Bring up ``n`` workers; cold-starts run concurrently."""
        if n <= 0:
            return
        if n == 1:
            self.add_worker()
            return
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=n) as tp:
            list(tp.map(lambda _: self.add_worker(), range(n)))

    def retire_worker(self) -> Optional[str]:
        """Park one idle worker as warm (not dispatched to, process kept
        alive for cheap revival). Returns its id, or None if none idle."""
        with self._cond:
            for h in self._workers.values():
                if h.state == "idle":
                    h.state = "warm"
                    h.warm_since = time.monotonic()
                    return h.worker_id
        return None

    def reap_warm(self, ttl_s: float) -> int:
        """Kill warm workers parked longer than ``ttl_s``; returns count."""
        now = time.monotonic()
        with self._cond:
            doomed = [h for h in self._workers.values()
                      if h.state == "warm" and now - h.warm_since >= ttl_s]
            for h in doomed:
                h.state = "dead"
                del self._workers[h.worker_id]
        for h in doomed:
            self.pool.kill(h)
        return len(doomed)

    # ---------------------------------------------------------------- stats
    def queue_depth(self) -> int:
        with self._cond:
            return len(self._queue)

    def num_workers(self, include_warm: bool = False) -> int:
        with self._cond:
            return sum(1 for h in self._workers.values()
                       if h.state in ("idle", "busy")
                       or (include_warm and h.state == "warm"))

    def idle_workers(self) -> int:
        with self._cond:
            return sum(1 for h in self._workers.values() if h.state == "idle")

    def inflight(self) -> int:
        with self._cond:
            return len(self._inflight)

    def worker_pids(self) -> List[int]:
        with self._cond:
            return [h.pid for h in self._workers.values()
                    if h.state != "dead"]

    def harvest(self, tasks) -> tuple:
        """Non-blocking completion sweep: partition ``tasks`` into
        (finished, pending) without waiting on any of them."""
        finished, pending = [], []
        for t in tasks:
            (finished if t.done() else pending).append(t)
        return finished, pending

    def dedup_stats(self) -> dict:
        """Aggregate chunk-dedup effectiveness across live worker
        channels (dead workers' per-connection stores are gone with
        their sockets)."""
        agg = {"dedup_chunks": 0, "saved_bytes": 0, "sent_bytes_held": 0,
               "received_bytes_held": 0, "evicted": 0}
        with self._cond:
            stores = [h.store for h in self._workers.values()
                      if h.store is not None]
        for st in stores:
            s = st.stats()
            for k in agg:
                agg[k] += s[k]
        return agg

    def register_metrics(self, registry):
        """Expose every broker counter — including the previously
        orphaned ``tasks_cancelled`` — plus live queue/worker gauges and
        wire dedup effectiveness in a metrics registry."""
        registry.gauge("broker.queue_depth", self.queue_depth)
        registry.gauge("broker.inflight", self.inflight)
        registry.gauge("broker.num_workers", self.num_workers)
        registry.gauge("broker.num_workers_with_warm",
                       lambda: self.num_workers(include_warm=True))
        registry.gauge("broker.idle_workers", self.idle_workers)
        registry.gauge("broker.tasks_done", lambda: self.tasks_done)
        registry.gauge("broker.tasks_requeued", lambda: self.tasks_requeued)
        registry.gauge("broker.tasks_cancelled",
                       lambda: self.tasks_cancelled)
        registry.gauge("broker.tasks_preempted",
                       lambda: self.tasks_preempted)
        registry.gauge("broker.workers_lost", lambda: self.workers_lost)
        registry.gauge("broker.warm_hits", lambda: self.warm_hits)
        registry.gauge("wire.bytes_sent", lambda: self.bytes_sent)
        registry.gauge("wire.bytes_received", lambda: self.bytes_received)
        registry.gauge("wire.dedup_saved_bytes",
                       lambda: self.dedup_stats()["saved_bytes"])
        registry.gauge("wire.dedup_chunks",
                       lambda: self.dedup_stats()["dedup_chunks"])
        registry.gauge("wire.dedup_hit_rate", self._dedup_hit_rate)

    def _dedup_hit_rate(self) -> Optional[float]:
        """Fraction of logical payload bytes dedup kept off the wire."""
        saved = self.dedup_stats()["saved_bytes"]
        with self._cond:
            sent = self.bytes_sent
        total = sent + saved
        return (saved / total) if total else None

    def observed_bandwidth(self) -> Optional[float]:
        """EMA bytes/sec from ship round-trips; None before any sample."""
        return self._bw_ema

    def avg_task_seconds(self) -> Optional[float]:
        return self._task_s_ema

    # ------------------------------------------------------------- dispatch
    def _dispatch_loop(self):
        while True:
            with self._cond:
                task = worker = None
                while not self._closed:
                    idle = [h for h in self._workers.values()
                            if h.state == "idle"]
                    if self._queue and idle:
                        # highest priority class first, FIFO within a
                        # class (requeued tasks sit at the queue front of
                        # their class); skip tasks whose only candidates
                        # are excluded (dead-worker history). The scan
                        # stops at the first placeable task of the top
                        # class present, so a deep single-class queue
                        # dispatches in O(1) candidate checks, not O(n).
                        best = None
                        top = max(t.priority for t in self._queue)
                        for i, t in enumerate(self._queue):
                            cands = [h for h in idle
                                     if h.worker_id not in t.exclude]
                            if cands and (best is None
                                          or t.priority > best[1].priority):
                                best = (i, t, cands[0])
                                if t.priority >= top:
                                    break
                        if best is not None:
                            task, worker = best[1], best[2]
                            del self._queue[best[0]]
                    if task is not None:
                        break
                    # every state change that could make work
                    # dispatchable (submit, worker idle/added, death,
                    # shutdown) notify_alls this condition, so the
                    # timeout is a shutdown failsafe only: if a wakeup
                    # is ever lost, the predicate is re-checked at 1 Hz
                    # instead of wedging close() forever — dispatch
                    # latency still has no polling floor
                    self._cond.wait(timeout=self._FAILSAFE_WAKEUP_S)
                if self._closed:
                    return
                worker.state = "busy"
                worker.current = task
                self._inflight[worker.worker_id] = task
                task.attempts += 1
            msg = {"op": task.kind, "task_id": task.task_id}
            if task.trace_ctx is not None and self.tracer.enabled:
                # span context rides the task frame header — the worker
                # echoes it back with its phase timings
                msg["trace"] = tuple(task.trace_ctx)
            if task.kind == "ship":
                msg["value"] = task.value
            else:
                msg["step"] = task.step
                msg["fn"] = task.fn_bytes
                msg["kwargs"] = task.kwargs
            plan = plan_msg(msg, worker.store)
            # stamp BEFORE sending: a fast loopback reply may reach the
            # reader thread while sendall is still returning. plan_msg has
            # already marked its chunks in the worker's store, so a failed
            # send MUST kill the worker (mirrored stores would desync).
            with self._cond:
                task.bytes_sent = plan.nbytes
                self.bytes_sent += plan.nbytes
            task._send_t = time.perf_counter()
            try:
                plan.send(worker.sock)
            except OSError:
                self._on_worker_death(worker)

    # --------------------------------------------------------------- reader
    def _reader_loop(self, h: WorkerHandle):
        while True:
            try:
                msg, n = recv_msg(h.sock, h.store)
            except (EOFError, OSError, WireError):
                # WireError = corrupted frame or desynced dedup stores:
                # the stream is unrecoverable, treat it as a dead worker
                # (in-flight task requeues elsewhere)
                break
            op = msg.get("op")
            if op == "heartbeat":
                h.last_heartbeat = time.monotonic()
                continue
            if op not in ("result", "error"):
                continue
            h.last_heartbeat = time.monotonic()
            with self._cond:
                task = self._inflight.pop(h.worker_id, None)
                h.current = None
                if h.state == "busy":
                    h.state = "idle"
                self.bytes_received += n
                if task is not None:
                    task.bytes_received = n
                    task.seconds = time.perf_counter() - task._send_t
                    task.worker_pid = h.pid
                    # per-direction attribution: the worker measured how
                    # long the request took to arrive and how long it
                    # computed; the remainder is the reply's transfer
                    task.up_s = float(msg.get("req_recv_s") or 0.0)
                    work_s = float(msg.get("work_s") or 0.0)
                    task.down_s = max(task.seconds - task.up_s - work_s, 0.0)
                    if op == "result":
                        self.tasks_done += 1
                        if task.kind == "ship" and task.seconds > 0:
                            bw = ((task.bytes_sent + n) / task.seconds)
                            self._bw_ema = bw if self._bw_ema is None else \
                                0.5 * bw + 0.5 * self._bw_ema
                        elif task.kind == "task":
                            s = task.seconds
                            self._task_s_ema = s if self._task_s_ema is None \
                                else 0.5 * s + 0.5 * self._task_s_ema
                self._cond.notify_all()
            if task is not None:
                self._materialize_worker_spans(task, msg, h)
                if op == "result":
                    task.future.set_result(msg.get("value"))
                else:
                    task.future.set_exception(RemoteStepError(
                        msg.get("traceback") or msg.get("error", "remote error")))
        if not self._closed:
            self._on_worker_death(h)

    def _materialize_worker_spans(self, task: Task, msg: dict,
                                  h: WorkerHandle):
        """Turn the worker's reported phase timings into spans parented
        under the driver-side span whose ctx rode the request frame,
        plus a synthesized ``send`` span for the reply transfer (measured
        driver-side as ``down_s``). Worker wall clocks place the phases
        on the shared epoch timeline; their durations are monotonic."""
        if task.trace_ctx is None or not self.tracer.enabled:
            return
        trace_id, parent_id = task.trace_ctx
        track = f"worker:{h.pid}"
        for ph in msg.get("spans") or ():
            try:
                self.tracer.add_span(
                    trace_id, str(ph["name"]), float(ph["t0"]),
                    float(ph["dur"]), parent_id=parent_id, cat="worker",
                    track=track, pid=h.pid, task_id=task.task_id,
                    step=task.step or "")
            except (KeyError, TypeError, ValueError):
                continue    # malformed phase from an old/foreign worker
        if task.down_s > 0:
            self.tracer.add_span(
                trace_id, "send", wall_now() - task.down_s, task.down_s,
                parent_id=parent_id, cat="worker", track=track, pid=h.pid,
                task_id=task.task_id)

    # ---------------------------------------------------------------- death
    def _on_worker_death(self, h: WorkerHandle):
        with self._cond:
            if h.state == "dead" or h.worker_id not in self._workers:
                return
            h.state = "dead"
            del self._workers[h.worker_id]
            self.workers_lost += 1
            task = self._inflight.pop(h.worker_id, None)
            failed = None
            if task is not None:
                task.exclude.add(h.worker_id)
                if task.attempts >= task.max_attempts:
                    failed = task
                else:
                    self.tasks_requeued += 1
                    self._queue.insert(0, task)
            replace = self.replace_dead and not self._closed
            self._cond.notify_all()
        self.pool.kill(h)
        if failed is not None:
            failed.future.set_exception(WorkerLostError(
                f"worker pid={h.pid} died running task {failed.task_id} "
                f"(attempt {failed.attempts}/{failed.max_attempts})"))
        if replace:
            try:
                self.add_worker()
            except Exception:
                pass   # pool closed mid-shutdown

    # -------------------------------------------------------------- monitor
    def _monitor_loop(self):
        while not self._closed:
            time.sleep(min(0.25, self.heartbeat_timeout_s / 4))
            now = time.monotonic()
            with self._cond:
                handles = list(self._workers.values())
            for h in handles:
                if h.state == "dead":
                    continue
                if h.proc.poll() is not None or \
                        now - h.last_heartbeat > self.heartbeat_timeout_s:
                    self._on_worker_death(h)

    # ------------------------------------------------------------- shutdown
    def shutdown(self):
        with self._cond:
            if self._closed:
                return
            self._closed = True
            pending = list(self._queue) + list(self._inflight.values())
            self._queue.clear()
            self._inflight.clear()
            handles = list(self._workers.values())
            self._workers.clear()
            self._cond.notify_all()
        for t in pending:
            if not t.future.done():
                t.future.set_exception(FabricError("broker shut down"))
        for h in handles:
            try:
                send_msg(h.sock, {"op": "shutdown"})
            except OSError:
                pass
            self.pool.kill(h)
        self.pool.close()
