"""Emerald offload fabric: process-separated broker + worker pool.

The seed reproduced the paper's *semantics* (partitioner, MDSS,
migration points) but every offload was an in-process call. This
package is the missing client/cloud-service split:

    Workflow -> Executor -> MigrationManager
                                 |  tier.worker_pool (Fabric)
                                 v
       Broker --(length-prefixed pytree frames over loopback TCP)--> N
       worker subprocesses, heartbeat-monitored, crash-requeued,
       elastically autoscaled with warm-pool reuse.

``Fabric`` is the one-stop facade: it owns the pool, broker, autoscaler
and hands out the MDSS ``RPCTransport``. Attach it to a tier with
``attach(tiers, fabric)`` and the MigrationManager dispatches remotable
registry steps (``Step.remote_impl``) through real OS processes.
"""
from __future__ import annotations

import pickle
from typing import Optional, Sequence

from repro.cloud.autoscaler import Autoscaler, AutoscalerConfig  # noqa: F401
from repro.cloud.broker import (Broker, FabricError, RemoteStepError,  # noqa: F401
                                ShipTimeout, Task, WorkerLostError)
from repro.cloud.pool import SpawnError, WorkerHandle, WorkerPool  # noqa: F401
from repro.cloud.tasklib import STEP_REGISTRY, register_step, resolve  # noqa: F401
from repro.cloud.wire import (ChannelStore, ChunkStore, WireError,  # noqa: F401
                              content_digest, decode, encode, manifest_of,
                              recv_msg, send_msg)


def __getattr__(name):
    # RPCTransport pulls in repro.core (jax); loaded lazily so worker
    # subprocesses importing this package stay numpy-only and spawn fast.
    if name == "RPCTransport":
        from repro.cloud.rpc_transport import RPCTransport
        return RPCTransport
    raise AttributeError(name)


class Fabric:
    """Pool + broker + autoscaler bundle, usable as a context manager."""

    def __init__(self, workers: int = 2, *,
                 init_modules: Sequence[str] = ("repro.cloud.tasklib",),
                 max_attempts: int = 3, heartbeat_s: float = 0.25,
                 heartbeat_timeout_s: float = 5.0, replace_dead: bool = True,
                 autoscaler: Optional[AutoscalerConfig] = None,
                 dedup: bool = True):
        # dedup: content-addressed chunk dedup on every worker socket —
        # repeated payloads (warm params in task kwargs, ship echoes)
        # cross as digest references instead of bytes
        self.pool = WorkerPool(init_modules=init_modules,
                               heartbeat_s=heartbeat_s, dedup=dedup)
        self.broker = Broker(self.pool, max_attempts=max_attempts,
                             heartbeat_timeout_s=heartbeat_timeout_s,
                             replace_dead=replace_dead, dedup=dedup)
        self.autoscaler = Autoscaler(self.broker, autoscaler) \
            if autoscaler is not None else None
        self.broker.start_workers(workers)

    # ------------------------------------------------------ step dispatch
    def can_run(self, step) -> bool:
        """True if ``step`` can execute in a worker: a registry name, or a
        plain (non-jax, picklable) function. jax steps stay in-process —
        their point is mesh-placed execution, not process separation."""
        if getattr(step, "remote_impl", None):
            return True
        if getattr(step, "jax_step", True) or step.fn is None:
            return False
        try:
            pickle.dumps(step.fn)
            return True
        except Exception:
            return False

    def submit_step(self, step, kwargs: dict,
                    max_attempts: Optional[int] = None,
                    priority: int = 0, trace_ctx=None) -> Task:
        # trace_ctx: (trace_id, span_id) of the driver-side span — rides
        # the task frame header so the worker's recv/exec/send phases
        # come back as child spans (see broker/worker)
        preemptible = bool(getattr(step, "preemptible", False))
        if getattr(step, "remote_impl", None):
            return self.broker.submit(step=step.remote_impl, kwargs=kwargs,
                                      max_attempts=max_attempts,
                                      priority=priority, trace_ctx=trace_ctx,
                                      preemptible=preemptible)
        return self.broker.submit(fn_bytes=pickle.dumps(step.fn),
                                  kwargs=kwargs, max_attempts=max_attempts,
                                  priority=priority, trace_ctx=trace_ctx,
                                  preemptible=preemptible)

    def ship(self, value, timeout: Optional[float] = 60.0) -> Task:
        return self.broker.ship(value, timeout=timeout)

    # ------------------------------------------------------------ plumbing
    def transport(self, tiers=None, cost_model=None):
        from repro.cloud.rpc_transport import RPCTransport
        return RPCTransport(self, tiers=tiers, cost_model=cost_model)

    def shutdown(self):
        if self.autoscaler is not None:
            self.autoscaler.stop()
        self.broker.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False


def attach(tiers, fabric: Fabric, tier_names: Sequence[str] = ("cloud",),
           mdss=None, cost_model=None):
    """Back ``tier_names`` with ``fabric`` and (optionally) swap the MDSS
    transport for the fabric's RPCTransport. Returns the transport."""
    for name in tier_names:
        tiers[name].worker_pool = fabric
    transport = fabric.transport(tiers=tiers, cost_model=cost_model)
    if mdss is not None:
        mdss.transport = transport
    return transport
