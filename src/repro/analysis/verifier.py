"""Static workflow verifier: lint a ``Workflow`` before execution.

:func:`verify` runs the rule catalogue (``W0xx`` in
``repro.analysis.findings``) over a workflow and returns structured
:class:`Finding`\\ s. Two contexts:

  * **static** (``provided=None``) — ``scripts/emlint.py`` over a module
    that merely builds the workflow. Explicitly declared variables
    (``wf.var``) are assumed to be provided at submit time, so only
    structurally certain defects fire (cycles through forward reads of
    step *outputs*, missing impls, signature mismatches, races...).
  * **submit** (``provided={...}``) — ``EmeraldRuntime.submit`` at
    admission, where the actual bound set (init_vars + namespace-resident
    URIs) is known, so unbound reads and feedback cycles are decidable.

Graph rules reason over :meth:`Workflow.dependencies(kinds=True)`: RAW
edges are true dataflow, WAR/WW edges are scheduler-inserted fences.
Two conflicting accesses ordered *only* by a fence are correct under the
current in-order driver but are one scheduler change away from a race —
the verifier flags them so the intent is written down as dataflow.
"""
from __future__ import annotations

import inspect
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis import findings as F
from repro.analysis.findings import Finding, finding
from repro.core.migration import fabric_runnable_reason, memo_unsafe_reasons
from repro.core.workflow import Step, Workflow, WorkflowError


class WorkflowRejected(WorkflowError):
    """``submit(validate="error")`` refused the workflow. Carries the
    full finding list; str() shows the blocking errors."""

    def __init__(self, workflow_name: str, all_findings: List[Finding]):
        self.workflow = workflow_name
        self.findings = list(all_findings)
        errors = [f for f in self.findings if f.severity == F.ERROR]
        lines = "\n  ".join(str(f) for f in errors)
        super().__init__(
            f"workflow {workflow_name!r} rejected by the verifier "
            f"({len(errors)} error(s); submit(validate=\"warn\"|\"off\") "
            f"to override):\n  {lines}")


def _is_device_array(v) -> bool:
    try:
        import jax
        return isinstance(v, jax.Array)
    except Exception:
        return False


def _captured_device_arrays(fn) -> List[str]:
    names = []
    cells = getattr(fn, "__closure__", None) or ()
    free = getattr(getattr(fn, "__code__", None), "co_freevars", ())
    for nm, cell in zip(free, cells):
        try:
            v = cell.cell_contents
        except ValueError:
            continue
        if _is_device_array(v):
            names.append(nm)
    for v in (getattr(fn, "__defaults__", None) or ()):
        if _is_device_array(v):
            names.append("<default>")
    return names


def verify(wf: Workflow, *, provided: Optional[Iterable[str]] = None,
           residency_budget: Optional[Dict[str, int]] = None,
           tiers=None, capacity_bytes: int = 0,
           registry=None) -> List[Finding]:
    """Run every verifier rule over ``wf``; returns findings (possibly
    empty), never raises on defective workflows.

    ``provided``: URIs bound at submission (init_vars + resident data);
    ``None`` = static context (see module doc). ``tiers`` /
    ``capacity_bytes`` ground the residency-budget feasibility check;
    ``registry`` overrides the fabric step registry for W004 (defaults
    to ``repro.cloud.tasklib.STEP_REGISTRY``).
    """
    out: List[Finding] = []
    top = wf.toplevel()
    names = [s.name for s in top]
    idx = {n: i for i, n in enumerate(names)}
    parents = {s.parent for s in wf.steps.values() if s.parent}
    kdeps = wf.dependencies(kinds=True)

    # RAW-ancestor bitmasks: raw_anc[s] has bit idx[d] set iff there is a
    # true-dataflow path d ~> s. Declaration order is a topological order
    # of the (fenced) DAG, so one forward sweep suffices; queries are O(1).
    raw_anc: Dict[str, int] = {}
    for n in names:
        m = 0
        for d, ks in kdeps[n].items():
            if "RAW" in ks:
                m |= raw_anc[d] | (1 << idx[d])
        raw_anc[n] = m

    def raw_path(a: str, b: str) -> bool:
        return bool((raw_anc[b] >> idx[a]) & 1)

    # Per-URI access scan (same sweep dependencies() does, but keeping
    # the var-level detail the graph rules need).
    writers: Dict[str, List[str]] = {}       # uri -> writers in order
    dead_writes = []                         # (prev_writer, overwriter, uri)
    war_pairs = []                           # (reader, overwriter, uri)
    last_writer: Dict[str, str] = {}
    readers_since: Dict[str, List[str]] = {}
    for s in top:
        for v in s.inputs:
            readers_since.setdefault(v, []).append(s.name)
        for v in s.outputs:
            prev = last_writer.get(v)
            live_readers = [r for r in readers_since.get(v, ())
                            if r != s.name]
            if prev is not None and prev != s.name and not live_readers:
                dead_writes.append((prev, s.name, v))
            for r in live_readers:
                war_pairs.append((r, s.name, v))
            writers.setdefault(v, []).append(s.name)
            readers_since[v] = []
            last_writer[v] = s.name

    provided_set: Optional[Set[str]] = \
        None if provided is None else set(provided)

    # ---------------------------------------------------- W001 cycle
    # Feedback edges: a read with no prior writer resolves at runtime to
    # submission-provided data — unless nothing provides it and a LATER
    # step writes it, in which case the author meant that step's output
    # and the "DAG" is a cycle the declaration order papered over.
    graph: Dict[str, Set[str]] = {n: set(kdeps[n]) for n in names}
    for s in top:
        for v in s.inputs:
            ws = writers.get(v, [])
            prior = [w for w in ws if idx[w] < idx[s.name]]
            later = [w for w in ws if idx[w] > idx[s.name]]
            if prior or not later:
                continue
            var = wf.variables.get(v)
            externally_bound = (
                provided_set is not None and v in provided_set
                or provided_set is None and var is not None
                and not var.implicit)
            if not externally_bound:
                graph[s.name].add(later[0])

    # Iterative coloured DFS (a 1k-step chain must not hit the Python
    # recursion limit); an edge n -> d reads "n awaits d".
    color: Dict[str, int] = {}
    path: List[str] = []
    cycles: List[List[str]] = []
    for root in names:
        if color.get(root, 0):
            continue
        todo = [(root, iter(sorted(graph[root], key=lambda x: idx[x])))]
        color[root] = 1
        path.append(root)
        while todo:
            n, it = todo[-1]
            for d in it:
                c = color.get(d, 0)
                if c == 0:
                    color[d] = 1
                    path.append(d)
                    todo.append(
                        (d, iter(sorted(graph[d], key=lambda x: idx[x]))))
                    break
                if c == 1:
                    cycles.append(path[path.index(d):] + [d])
            else:
                color[n] = 2
                path.pop()
                todo.pop()
    for cyc in cycles:
        witness = " -> ".join(cyc)
        out.append(finding(
            F.W001,
            f"dependency cycle: {witness} (each step awaits the next's "
            "output; no member can ever become ready)",
            steps=tuple(dict.fromkeys(cyc))))

    # ---------------------------------------------- W002 unbound-input
    if provided_set is not None:
        for s in top:
            for v in s.inputs:
                ws = writers.get(v, [])
                if any(idx[w] < idx[s.name] for w in ws):
                    continue
                if v in provided_set:
                    continue
                later = [w for w in ws if idx[w] > idx[s.name]]
                extra = (f"; {later[0]} writes it only later — provide "
                         "an initial value if this is a feedback loop"
                         ) if later else ""
                out.append(finding(
                    F.W002,
                    f"step {s.name} reads {v}, which nothing provides "
                    f"(not in init_vars, not resident, no prior "
                    f"writer){extra}",
                    steps=(s.name,), uri=v, where=s.defined_at))

    # ---------------------------------- per-step implementation rules
    for s in wf.steps.values():
        if s.name in parents:
            continue                     # container node: children execute
        if s.fn is None and not s.remote_impl:
            out.append(finding(
                F.W003,
                f"step {s.name} has neither fn nor remote_impl — it can "
                "execute nowhere",
                steps=(s.name,), where=s.defined_at))
        if s.remote_impl:
            reg = registry
            if reg is None:
                try:
                    from repro.cloud.tasklib import STEP_REGISTRY as reg
                except Exception:
                    reg = None
            if reg is not None and s.remote_impl not in reg:
                out.append(finding(
                    F.W004,
                    f"step {s.name} names remote_impl "
                    f"{s.remote_impl!r}, which is not in the fabric "
                    "step registry (workers may register more modules "
                    "at spawn; verify init_modules)",
                    steps=(s.name,), where=s.defined_at))
        out.extend(_signature_findings(s))
        if s.remotable and s.fn is not None \
                and not getattr(s, "jax_step", True):
            reason = fabric_runnable_reason(s)
            if reason:
                out.append(finding(
                    F.W020,
                    f"remotable step {s.name} cannot ship to fabric "
                    f"workers: {reason}",
                    steps=(s.name,), where=s.defined_at))
        if s.remotable and s.fn is not None:
            captured = _captured_device_arrays(s.fn)
            if captured:
                out.append(finding(
                    F.W021,
                    f"remotable step {s.name} captures device array(s) "
                    f"{', '.join(captured)} in its closure/defaults",
                    steps=(s.name,), where=s.defined_at))
        if s.memoizable is True:
            reasons = memo_unsafe_reasons(s)
            if reasons:
                out.append(finding(
                    F.W030,
                    f"memoizable step {s.name} reads state outside its "
                    f"memo key: {'; '.join(reasons)}",
                    steps=(s.name,), where=s.defined_at))
            if not s.outputs:
                out.append(finding(
                    F.W031,
                    f"memoizable step {s.name} declares no outputs, so "
                    "no execution is ever memoized",
                    steps=(s.name,), where=s.defined_at))
        if getattr(s, "slo_ms", None) is not None:
            # the coalescer keys fused batches on (code fingerprint,
            # shape) — only remotable, deterministic-by-declaration
            # steps can safely fuse across tenants
            why = []
            if not s.remotable:
                why.append("is not remotable")
            if s.memoizable is False:
                why.append("is declared memoizable=False (not "
                           "deterministic over its declared inputs)")
            if why:
                out.append(finding(
                    F.W070,
                    f"step {s.name} carries slo_ms={s.slo_ms} but "
                    f"{' and '.join(why)} — the serving front door "
                    "cannot coalesce it, so the SLO steers nothing",
                    steps=(s.name,), where=s.defined_at))

    # ------------------------------------------- W010/W011/W012 races
    for v, ws in writers.items():
        for w1, w2 in zip(ws, ws[1:]):
            if not raw_path(w1, w2):
                out.append(finding(
                    F.W010,
                    f"{w1} and {w2} both write {v} with no dataflow "
                    "path between them — their order (hence the final "
                    "version) rests only on a declaration-order fence",
                    steps=(w1, w2), uri=v))
    for r, w, v in war_pairs:
        if v in wf.steps[w].inputs:
            # read-modify-write: the overwriter consumes the version it
            # replaces (the canonical update-step idiom) — it extends
            # the version chain rather than clobbering a live read
            continue
        if not raw_path(r, w):
            out.append(finding(
                F.W011,
                f"{r} reads {v} and {w} later blindly overwrites it "
                "(never reading that version), ordered only by an "
                "anti-dependency fence, not dataflow",
                steps=(r, w), uri=v))
    for w1, w2, v in dead_writes:
        out.append(finding(
            F.W012,
            f"{w1}'s version of {v} is overwritten by {w2} before "
            "anything reads it",
            steps=(w1, w2), uri=v))

    # --------------------------------------------- W040/W041 budgets
    declared_bytes = sum(s.bytes_hint for s in top if s.outputs)
    for tier_name, budget in (residency_budget or {}).items():
        if tiers is not None and tier_name not in tiers:
            out.append(finding(
                F.W041,
                f"residency_budget names unknown tier {tier_name!r} "
                f"(known: {sorted(tiers)})", uri=tier_name))
            continue
        if capacity_bytes and budget > capacity_bytes:
            out.append(finding(
                F.W040,
                f"residency_budget[{tier_name!r}]={budget} exceeds the "
                f"store's capacity_bytes={capacity_bytes}",
                uri=tier_name))
        elif declared_bytes and budget < declared_bytes:
            out.append(finding(
                F.W040,
                f"residency_budget[{tier_name!r}]={budget} is below the "
                f"{declared_bytes:.0f} bytes the workflow declares it "
                "will materialise (sum of bytes_hint over writing "
                "steps)", uri=tier_name))

    # ------------------------------------------ W060..W063 fan-out
    out.extend(_fanout_findings(wf, top))

    # ----------------------------------------------- W050 dead-step
    live: Set[str] = {s.name for s in top if not s.outputs}
    live |= {ws[-1] for ws in writers.values()}
    for n in reversed(names):
        if n in live:
            for d, ks in kdeps[n].items():
                if "RAW" in ks:
                    live.add(d)
    for s in top:
        if s.name not in live:
            out.append(finding(
                F.W050,
                f"step {s.name} is dead: every output is overwritten "
                "before being read and nothing downstream consumes it",
                steps=(s.name,), where=s.defined_at))
    return out


def _unpicklable_reason(fn) -> str:
    """Why ``fn`` cannot ride a pickle (fabric ship / checkpoint), or ''."""
    import pickle
    if getattr(fn, "__name__", "") == "<lambda>":
        return "is a lambda — unpicklable"
    try:
        pickle.dumps(fn)
    except Exception as e:
        return f"is unpicklable ({type(e).__name__}: {e})"
    return ""


def _fanout_findings(wf: Workflow, top: List[Step]) -> List[Finding]:
    """W060–W063: fan-out legality.

    Runs over both forms the verifier can see: the *unexpanded* step
    (static lint, or a spec so broken the partitioner refused to expand
    it — W060/W061) and the *expanded* scatter/shard/gather triple the
    runtime admits (W061 on the closure carriers, W062/W063 on the
    shard-URI wiring of hand-built or mutated expansions).
    """
    from repro.core.mdss import shard_uri
    from repro.core.partitioner import _fanout_spec_errors
    out: List[Finding] = []
    shard_writers: Dict[str, Dict[str, str]] = {}   # parent -> uri -> shard
    preemptible_shards: Dict[str, List[str]] = {}   # parent -> shard names
    gather_parents: Set[str] = set()
    for s in top:
        spec = s.fanout
        if spec is not None and not s.fanout_role:
            for err in _fanout_spec_errors(s):
                out.append(finding(
                    F.W060,
                    f"step {s.name}'s fan-out spec {err}",
                    steps=(s.name,), where=s.defined_at))
        if spec is not None:
            carried = []
            if s.fanout_role in ("", "scatter") and spec.partition_fn:
                carried.append(("partition_fn", spec.partition_fn))
            if s.fanout_role in ("", "gather") and spec.combine_fn:
                carried.append(("combine_fn", spec.combine_fn))
            for label, fn in carried:
                reason = _unpicklable_reason(fn)
                if reason:
                    out.append(finding(
                        F.W061,
                        f"step {s.name}'s {label} {reason}; fabric "
                        "workers and checkpoints cannot carry it",
                        steps=(s.name,), where=s.defined_at))
        if s.fanout_role == "gather":
            gather_parents.add(s.fanout_parent)
        if s.fanout_role == "shard" and getattr(s, "preemptible", False):
            preemptible_shards.setdefault(
                s.fanout_parent, []).append(s.name)
        if s.fanout_role == "gather" and s.fanout_shards > 0:
            expected = {shard_uri(o, k)
                        for o in s.outputs for k in range(s.fanout_shards)}
            dropped = sorted(expected - set(s.inputs))
            if dropped:
                out.append(finding(
                    F.W062,
                    f"gather step {s.name} never reads sibling shard "
                    f"output(s) {', '.join(dropped)} — those shards' "
                    "results silently vanish from the combined value",
                    steps=(s.name,), uri=dropped[0], where=s.defined_at))
        if s.fanout_role == "shard":
            seen = shard_writers.setdefault(s.fanout_parent, {})
            for o in s.outputs:
                if o in seen and seen[o] != s.name:
                    out.append(finding(
                        F.W063,
                        f"sibling shards {seen[o]} and {s.name} of "
                        f"fan-out {s.fanout_parent} both write {o} — "
                        "the surviving version depends on completion "
                        "order",
                        steps=(seen[o], s.name), uri=o,
                        where=s.defined_at))
                else:
                    seen[o] = s.name
    for parent, shards in sorted(preemptible_shards.items()):
        if parent not in gather_parents:
            out.append(finding(
                F.W071,
                f"preemptible shard(s) {', '.join(sorted(shards))} of "
                f"fan-out {parent} have no sibling gather step — a "
                "preempted-and-requeued shard would re-publish its "
                "shard URI with no barrier fencing downstream readers",
                steps=tuple(sorted(shards)), uri=parent))
    return out


def _signature_findings(s: Step) -> List[Finding]:
    """W005: statically-certain call mismatches between the step's
    declared inputs and its fn's parameters (execution calls
    ``fn(**{input: staged value})``)."""
    fn = s.fn
    if fn is None:
        return []
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return []
    params = list(sig.parameters.values())
    if any(p.kind == p.VAR_KEYWORD for p in params):
        return []                         # **kw absorbs anything
    named = {p.name for p in params
             if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}
    required = {p.name for p in params
                if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)
                and p.default is p.empty}
    pos_only = [p.name for p in params
                if p.kind == p.POSITIONAL_ONLY and p.default is p.empty]
    out = []
    # staging calls fn(**{arg_names[i]: value_of(inputs[i])}) — the
    # declared parameter names are arg_names when set (fan-out shard
    # steps read uri#k but call the original fn by its own names)
    declared = set(s.arg_names) if s.arg_names else set(s.inputs)
    extra = sorted(declared - named)
    missing = sorted(required - declared)
    if extra:
        out.append(finding(
            F.W005,
            f"step {s.name} declares input(s) {', '.join(extra)} its fn "
            "does not accept — the staged call fn(**inputs) will raise "
            "TypeError",
            steps=(s.name,), where=s.defined_at))
    if missing:
        out.append(finding(
            F.W005,
            f"step {s.name}'s fn requires parameter(s) "
            f"{', '.join(missing)} absent from the step's declared "
            "inputs — the staged call will raise TypeError",
            steps=(s.name,), where=s.defined_at))
    if pos_only:
        out.append(finding(
            F.W005,
            f"step {s.name}'s fn takes positional-only parameter(s) "
            f"{', '.join(pos_only)}; staging passes inputs by keyword",
            steps=(s.name,), where=s.defined_at))
    return out
