"""Happens-before hazard sanitizer over the runtime's event stream.

The PR-6 observability layer gives every run an ordered event log and
the MDSS a replica install/eviction log. This module replays those logs
through a vector-clock-lite checker: per step it pairs ``dispatch``
(lane grant) with ``step_done`` (result committed); per ``(uri, tier,
namespace-epoch)`` it demands monotone replica versions and
install-before-evict ordering. Violations are the concurrency bugs the
runtime's guards exist to prevent — a clean production run must produce
zero findings, which is exactly what the opt-in pytest fixture
(``--sanitize`` / ``EMERALD_SANITIZE=1``, see ``tests/conftest.py``)
asserts over every fabric-backed tier-1 test.

Hazard classes (catalogue in ``repro.analysis.findings``):

  * H101 duplicate-done    — more completions than dispatches for a step
  * H102 orphan-completion — completion for a never-dispatched step
  * H103 lost-completion   — dispatch without completion in a run that
                             finished successfully
  * H110 install-regression — replica version decreased within one
                             ``(uri, tier, namespace epoch)``
  * H111 evict-install-race — eviction of a replica version never
                             installed on that tier
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

from repro.analysis import findings as F
from repro.analysis.findings import Finding, finding


def _field(e, name, default=None):
    if isinstance(e, dict):
        return e.get(name, default)
    return getattr(e, name, default)


def check(events: Iterable, *, completed_run: bool = True
          ) -> List[Finding]:
    """Replay a run's event log; return happens-before violations.

    ``events``: Event objects (or dicts) with ``kind``/``step``/``t``.
    The log may concatenate several sequential runs (the compat shim
    reuses one sink): pairing is by count, so N dispatches matched by N
    completions stay clean regardless of interleaving. Set
    ``completed_run=False`` for failed/cancelled runs, where a dispatch
    legitimately never reports done (H103 is skipped).
    """
    evs = sorted(events, key=lambda e: _field(e, "t", 0.0) or 0.0)
    dispatched: Dict[str, int] = {}     # step -> dispatches seen so far
    pending: Dict[str, int] = {}        # step -> dispatches awaiting done
    out: List[Finding] = []
    for e in evs:
        kind = _field(e, "kind")
        step = _field(e, "step", "")
        if kind == "dispatch":
            dispatched[step] = dispatched.get(step, 0) + 1
            pending[step] = pending.get(step, 0) + 1
        elif kind == "step_done":
            if pending.get(step, 0) > 0:
                pending[step] -= 1
            elif dispatched.get(step, 0) > 0:
                out.append(finding(
                    F.H101,
                    f"step {step} reported done more often than it was "
                    "dispatched (double completion)",
                    steps=(step,)))
            else:
                out.append(finding(
                    F.H102,
                    f"step {step} reported done but was never "
                    "dispatched", steps=(step,)))
    if completed_run:
        for step, n in sorted(pending.items()):
            if n > 0:
                out.append(finding(
                    F.H103,
                    f"step {step} was dispatched but never reported "
                    f"done ({n} completion(s) missing) in a run that "
                    "finished successfully", steps=(step,)))
    return out


def check_store(mdss_or_installs, evictions=None, *,
                complete: bool = True) -> List[Finding]:
    """Replay an MDSS replica log; return version-ordering violations.

    Pass an ``MDSS`` (its ``install_events`` / ``eviction_events`` /
    ``installs_total`` are read), or explicit row lists: installs
    ``(uri, tier, version, epoch, t)`` and evictions ``(uri, tier,
    bytes, version, epoch, t)``. ``complete=False`` (set automatically
    when the store's bounded log has been trimmed) skips H111, which
    needs the full install history to judge an eviction.
    """
    if evictions is None and hasattr(mdss_or_installs, "install_events"):
        m = mdss_or_installs
        installs = list(m.install_events)
        evictions = list(getattr(m, "eviction_events", ()))
        complete = complete and \
            getattr(m, "installs_total", len(installs)) == len(installs)
    else:
        installs = list(mdss_or_installs)
        evictions = list(evictions or ())

    out: List[Finding] = []
    # Merge both logs on t so "prior install" means prior in time.
    rows = [(r[4], 0, r) for r in installs] + \
           [(r[5], 1, r) for r in evictions]
    rows.sort(key=lambda x: (x[0], x[1]))
    high: Dict[Tuple[str, str, int], int] = {}   # (uri,tier,epoch) -> max v
    seen: set = set()                            # installed (uri,tier,v,ep)
    for _, which, r in rows:
        if which == 0:
            uri, tier, version, epoch = r[0], r[1], r[2], r[3]
            key = (uri, tier, epoch)
            prev = high.get(key)
            if prev is not None and version < prev:
                out.append(finding(
                    F.H110,
                    f"{uri} on tier {tier} regressed from version "
                    f"{prev} to {version} within namespace epoch "
                    f"{epoch} — a stale install overwrote a newer "
                    "write", uri=uri))
            if prev is None or version > prev:
                high[key] = version
            seen.add((uri, tier, version, epoch))
        else:
            uri, tier, version, epoch = r[0], r[1], r[3], r[4]
            if complete and (uri, tier, version, epoch) not in seen:
                out.append(finding(
                    F.H111,
                    f"{uri} version {version} was evicted from tier "
                    f"{tier} (epoch {epoch}) but that version was "
                    "never installed there — eviction raced an "
                    "in-flight install", uri=uri))
    return out


def check_runtime(runtime, handles) -> List[Finding]:
    """Convenience: sanitize finished ``handles`` of ``runtime`` plus
    its store's replica log. Failed/cancelled runs are checked too —
    duplicate dones (H101) and orphan completions (H102) are hazards on
    any run; only the lost-completion pairing (H103) is restricted to
    runs that finished successfully, since an aborted run legitimately
    drops dones."""
    out: List[Finding] = []
    for h in handles:
        state = getattr(h, "state", "done")
        out.extend(check(h.events, completed_run=(state == "done")))
    mdss = getattr(runtime, "mdss", None)
    if mdss is not None:
        out.extend(check_store(mdss))
    return out
