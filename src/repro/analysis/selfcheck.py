"""Source self-lint: keep emitted telemetry and its registries in sync.

Greps ``src/`` for telemetry call sites and checks each against its
registry — the contract that every event kind and metric name the code
can produce is documented:

  * L001 — ``emit(<kind literal>, ...)`` call sites vs
    ``repro.obs.events.EVENT_SCHEMA``
  * L002 — ``inc("name")`` / ``observe("name")`` / ``gauge("name")`` /
    ``set("name")`` call sites vs ``repro.obs.metrics.METRIC_CATALOG``.
    Metric names are dotted by convention; undotted string args to these
    methods (unrelated ``set(...)`` calls etc.) are ignored.

This is the PR-6 grep-lint test promoted to a proper rule: the pytest
wrapper in ``tests/test_obs.py`` and ``emlint --self`` both call
:func:`check_source`.
"""
from __future__ import annotations

import os
import re
from typing import List, Optional

from repro.analysis import findings as F
from repro.analysis.findings import Finding, finding

_EMIT_RE = re.compile(r"""\bemit\(\s*f?["']([a-z_]+)["']""")
_METRIC_RE = re.compile(
    r"""\b(?:inc|observe|gauge|set)\(\s*f?["']([A-Za-z0-9_.]+)["']""")


def default_src_dir() -> str:
    here = os.path.dirname(os.path.abspath(__file__))   # .../src/repro/analysis
    return os.path.dirname(os.path.dirname(here))       # .../src


def check_source(src_dir: Optional[str] = None) -> List[Finding]:
    """Lint every ``.py`` under ``src_dir`` (default: this tree's
    ``src/``); returns one finding per unregistered call site."""
    from repro.obs.events import EVENT_SCHEMA
    from repro.obs.metrics import METRIC_CATALOG

    src_dir = src_dir or default_src_dir()
    out: List[Finding] = []
    for root, _dirs, files in os.walk(src_dir):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(root, fname)
            rel = os.path.relpath(path, src_dir)
            with open(path, encoding="utf-8") as fh:
                for lineno, line in enumerate(fh, 1):
                    for m in _EMIT_RE.finditer(line):
                        kind = m.group(1)
                        if kind not in EVENT_SCHEMA:
                            out.append(finding(
                                F.L001,
                                f"emit({kind!r}) is not registered in "
                                "EVENT_SCHEMA",
                                uri=kind, where=f"{rel}:{lineno}"))
                    for m in _METRIC_RE.finditer(line):
                        name = m.group(1)
                        if "." not in name:
                            continue
                        if name not in METRIC_CATALOG:
                            out.append(finding(
                                F.L002,
                                f"metric {name!r} is not registered in "
                                "METRIC_CATALOG",
                                uri=name, where=f"{rel}:{lineno}"))
    return out
