"""Source self-lint: telemetry-registry drift + lock-discipline checks.

Parses every module under ``src/`` and checks two families of rules:

Telemetry drift — the contract that every event kind and metric name
the code can produce is documented:

  * L001 — ``emit(<kind>, ...)`` call sites vs
    ``repro.obs.events.EVENT_SCHEMA``
  * L002 — ``inc("name")`` / ``observe("name")`` / ``gauge("name")`` /
    ``set("name")`` call sites vs ``repro.obs.metrics.METRIC_CATALOG``.
    Metric names are dotted by convention; undotted string args to these
    methods (unrelated ``set(...)`` calls etc.) are ignored. Names built
    dynamically — f-strings (``f"fanout.{kind}_done"``) or literal
    concatenation (``"fanout." + kind``) — are checked as patterns: the
    literal fragments must match at least one registered name, so a
    renamed catalogue entry still fails the lint even when the call
    site interpolates.

Lock discipline — an AST pass over every ``with <lock>:`` site
(objects whose expression mentions ``lock``/``cond``/``mutex``/``sem``):

  * L010 — inconsistent lock-acquisition order: two code paths acquire
    the same pair of locks in opposite orders (ABBA deadlock); reported
    once per pair with both witness sites. Lock identity is the
    expression scoped to its class (``Broker::self._cond``), so
    same-named locks on different classes do not alias; re-entering the
    lock already held (RLock) is ignored.
  * L011 — blocking call while holding a lock: ``sleep``, socket
    ``recv``/``recv_into``/``recv_exact``/``accept``, ``pickle``
    dumps/loads, or an *untimed* ``.wait()`` on anything other than a
    held condition (a condition's own wait releases the lock; a foreign
    ``Event.wait()`` does not).
  * L012 — ``cond.wait()`` on a held condition with no enclosing
    ``while`` predicate loop: spurious wakeups and missed notifies are
    legal, so a bare ``if``-guarded wait is a latent hang.

The static pass is lexical and intra-function by design: it cannot see
aliasing or cross-function lock flows, so it is tuned to be quiet on
legitimate code (timed waits pass L011/L012's untimed rule; ``with a,
b:`` records the documented order). ``emlint --self`` and the pytest
wrapper both call :func:`check_source`; :func:`check_snippet` is the
defect-corpus entry point.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis import findings as F
from repro.analysis.findings import Finding, finding

_METRIC_FNS = ("inc", "observe", "gauge", "set")
_LOCKY_RE = re.compile(r"(lock|cond|mutex|sem)", re.I)
_BLOCKING_ATTRS = ("recv", "recv_into", "recv_exact", "accept", "sleep")
_PICKLE_FNS = ("dumps", "loads", "dump", "load")


def default_src_dir() -> str:
    here = os.path.dirname(os.path.abspath(__file__))   # .../src/repro/analysis
    return os.path.dirname(os.path.dirname(here))       # .../src


# ---------------------------------------------------------------- telemetry

def _name_pattern(node) -> Optional[Tuple[str, bool]]:
    """(regex, is_exact) for a string-building expression: a literal is
    exact; f-strings and ``+``-concatenation become patterns whose
    interpolated holes match anything. None for non-string shapes."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return re.escape(node.value), True
    if isinstance(node, ast.JoinedStr):
        parts, exact = [], True
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(re.escape(v.value))
            else:
                parts.append(".+")
                exact = False
        return "".join(parts), exact
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left = _name_pattern(node.left)
        right = _name_pattern(node.right)
        if left is None and right is None:
            return None
        lp = left[0] if left else ".+"
        rp = right[0] if right else ".+"
        return lp + rp, False
    return None


def _literal_part(pattern: str) -> str:
    """The escaped-literal content of a pattern (holes stripped), used
    to decide whether a name is 'dotted by convention'."""
    return re.sub(r"\.\+", "", pattern).replace("\\.", ".")


def _check_telemetry_call(node: ast.Call, rel: str, schema, catalog,
                          out: List[Finding]):
    fn = node.func
    name = (fn.attr if isinstance(fn, ast.Attribute)
            else fn.id if isinstance(fn, ast.Name) else None)
    if name is None or not node.args:
        return
    pat = _name_pattern(node.args[0])
    if pat is None:
        return
    pattern, exact = pat
    where = f"{rel}:{node.lineno}"
    if name == "emit":
        if exact:
            kind = node.args[0].value
            if kind not in schema:
                out.append(finding(
                    F.L001,
                    f"emit({kind!r}) is not registered in EVENT_SCHEMA",
                    uri=kind, where=where))
        elif not any(re.fullmatch(pattern, k) for k in schema):
            out.append(finding(
                F.L001,
                f"no EVENT_SCHEMA kind matches the dynamic emit "
                f"pattern {_literal_part(pattern) or '<any>'!r}",
                uri=_literal_part(pattern), where=where))
    elif name in _METRIC_FNS:
        if "." not in _literal_part(pattern):
            return   # undotted: not a metric-style name
        if exact:
            mname = node.args[0].value
            if mname not in catalog:
                out.append(finding(
                    F.L002,
                    f"metric {mname!r} is not registered in "
                    "METRIC_CATALOG",
                    uri=mname, where=where))
        elif not any(re.fullmatch(pattern, m) for m in catalog):
            out.append(finding(
                F.L002,
                f"no METRIC_CATALOG name matches the dynamic metric "
                f"pattern {_literal_part(pattern)!r}",
                uri=_literal_part(pattern), where=where))


# ------------------------------------------------------------ lock discipline

def _lock_id(expr, klass: List[str], rel: str) -> Optional[str]:
    """Stable identity for a lock-like ``with`` context expression, or
    None when the expression does not look like a lock. ``self.*``
    locks are scoped to their class so same-named locks on different
    classes do not alias."""
    if isinstance(expr, ast.Call):
        return None   # transient (with Lock():) — nothing to order
    try:
        text = ast.unparse(expr)
    except Exception:                                  # pragma: no cover
        return None
    if not _LOCKY_RE.search(text):
        return None
    if text.startswith("self.") and klass:
        return f"{klass[-1]}::{text}"
    return f"{rel}::{text}"


class _LockScan(ast.NodeVisitor):
    """Per-file lexical lock tracking: held-lock stack across ``with``
    bodies, ``while``-ancestor depth for L012, blocking calls for L011,
    and acquisition-order pairs for the cross-file L010 aggregation."""

    def __init__(self, rel: str, pairs: Dict[Tuple[str, str], str],
                 out: List[Finding]):
        self.rel = rel
        self.pairs = pairs       # (outer, inner) -> first witness site
        self.out = out
        self.klass: List[str] = []
        self.held: List[Tuple[str, str, int]] = []  # (id, site, whiledepth)
        self.while_depth = 0

    # --------------------------------------------------------- scope walls
    def visit_ClassDef(self, node):
        self.klass.append(node.name)
        self.generic_visit(node)
        self.klass.pop()

    def _visit_function(self, node):
        # a nested def/lambda body does not run under the enclosing
        # with; its lock context starts empty
        saved, self.held = self.held, []
        saved_w, self.while_depth = self.while_depth, 0
        self.generic_visit(node)
        self.held, self.while_depth = saved, saved_w

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function
    visit_Lambda = _visit_function

    def visit_While(self, node):
        self.while_depth += 1
        self.generic_visit(node)
        self.while_depth -= 1

    def visit_With(self, node):
        acquired = 0
        for item in node.items:
            self.visit(item.context_expr)
            lid = _lock_id(item.context_expr, self.klass, self.rel)
            if lid is None or any(h[0] == lid for h in self.held):
                continue   # not a lock, or RLock re-entry
            site = f"{self.rel}:{item.context_expr.lineno}"
            for held_id, _, _ in self.held:
                self.pairs.setdefault((held_id, lid), site)
            self.held.append((lid, site, self.while_depth))
            acquired += 1
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        for stmt in node.body:
            self.visit(stmt)
        del self.held[len(self.held) - acquired:]

    visit_AsyncWith = visit_With

    # ------------------------------------------------------ blocking calls
    def visit_Call(self, node):
        if self.held:
            self._check_blocking(node)
        self.generic_visit(node)

    def _check_blocking(self, node: ast.Call):
        fn = node.func
        where = f"{self.rel}:{node.lineno}"
        innermost = self.held[-1]
        if isinstance(fn, ast.Name):
            if fn.id == "sleep":
                self.out.append(finding(
                    F.L011,
                    f"sleep() while holding {innermost[0]} (acquired at "
                    f"{innermost[1]})", where=where))
            return
        if not isinstance(fn, ast.Attribute):
            return
        recv_id = _lock_id(fn.value, self.klass, self.rel)
        if fn.attr == "wait":
            held_entry = next(
                (h for h in self.held if recv_id and h[0] == recv_id),
                None)
            if held_entry is not None:
                # condition-style wait: releases its own lock, so not a
                # blocking call — but it needs a predicate loop (L012)
                if self.while_depth == 0:
                    self.out.append(finding(
                        F.L012,
                        f"{ast.unparse(fn.value)}.wait() outside a "
                        f"while-predicate loop (lock acquired at "
                        f"{held_entry[1]})", where=where))
            elif not node.args and not node.keywords:
                self.out.append(finding(
                    F.L011,
                    f"untimed {ast.unparse(fn.value)}.wait() while "
                    f"holding {innermost[0]} (acquired at "
                    f"{innermost[1]}) — the wait does not release that "
                    f"lock", where=where))
            return
        if fn.attr in _BLOCKING_ATTRS:
            self.out.append(finding(
                F.L011,
                f"{ast.unparse(fn.value)}.{fn.attr}(...) while holding "
                f"{innermost[0]} (acquired at {innermost[1]})",
                where=where))
        elif (fn.attr in _PICKLE_FNS
              and isinstance(fn.value, ast.Name)
              and fn.value.id == "pickle"):
            self.out.append(finding(
                F.L011,
                f"pickle.{fn.attr}(...) while holding {innermost[0]} "
                f"(acquired at {innermost[1]})", where=where))


def _emit_order_findings(pairs: Dict[Tuple[str, str], str],
                         out: List[Finding]):
    """L010: every (A then B) order paired with a (B then A) witness."""
    reported = set()
    for (a, b), site_ab in sorted(pairs.items()):
        site_ba = pairs.get((b, a))
        if site_ba is None:
            continue
        key = (a, b) if a < b else (b, a)
        if key in reported:
            continue
        reported.add(key)
        out.append(finding(
            F.L010,
            f"inconsistent lock order: {a} then {b} at {site_ab}, but "
            f"{b} then {a} at {site_ba}",
            where=site_ab))


# -------------------------------------------------------------- entry points

class _Scan:
    """One lint pass: telemetry drift per file, lock pairs across
    files."""

    def __init__(self):
        from repro.obs.events import EVENT_SCHEMA
        from repro.obs.metrics import METRIC_CATALOG
        self.schema = EVENT_SCHEMA
        self.catalog = METRIC_CATALOG
        self.pairs: Dict[Tuple[str, str], str] = {}
        self.out: List[Finding] = []

    def add_file(self, text: str, rel: str):
        tree = ast.parse(text, filename=rel)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                _check_telemetry_call(node, rel, self.schema,
                                      self.catalog, self.out)
        _LockScan(rel, self.pairs, self.out).visit(tree)

    def finish(self) -> List[Finding]:
        _emit_order_findings(self.pairs, self.out)
        return self.out


def check_source(src_dir: Optional[str] = None) -> List[Finding]:
    """Lint every ``.py`` under ``src_dir`` (default: this tree's
    ``src/``): telemetry drift (L001/L002) and lock discipline
    (L010–L012, with acquisition orders aggregated across the whole
    tree so cross-module inversions are caught)."""
    src_dir = src_dir or default_src_dir()
    scan = _Scan()
    for root, _dirs, files in os.walk(src_dir):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(root, fname)
            rel = os.path.relpath(path, src_dir)
            with open(path, encoding="utf-8") as fh:
                scan.add_file(fh.read(), rel)
    return scan.finish()


def check_snippet(text: str, filename: str = "<snippet>") -> List[Finding]:
    """Lint one source snippet (the ``tests/defects/`` corpus entry
    point): same rules as :func:`check_source`, lock orders aggregated
    within the snippet only."""
    scan = _Scan()
    scan.add_file(text, filename)
    return scan.finish()
