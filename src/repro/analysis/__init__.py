"""Emerald correctness tooling: static verifier + dynamic sanitizer +
schedule-space explorer.

Four entry points, one finding model (``repro.analysis.findings``):

  * :func:`verify` — rule-based static lint over a :class:`Workflow`
    (cycles with witness paths, dataflow races, offloadability,
    memo-safety, residency-budget feasibility, dead code). Runs at
    admission via ``EmeraldRuntime.submit(validate=...)`` and standalone
    via ``scripts/emlint.py``.
  * :mod:`sanitizer` — happens-before checker over a run's event log
    and the MDSS replica-install log (``sanitizer.check(events)``,
    ``sanitizer.check_store(mdss)``); the ``--sanitize`` pytest fixture
    turns the whole tier-1 suite into a race detector.
  * :mod:`selfcheck` — source lint keeping ``emit(`` kinds and metric
    names in lockstep with their registries, plus the AST lock-
    discipline pass (acquisition order, blocking-under-lock,
    predicate-loop waits) (``emlint --self``).
  * :mod:`explorer` — deterministic schedule-space model checking
    (``scripts/emcheck.py``): every explored interleaving replays
    through the sanitizer plus cross-schedule invariants (H120–H124),
    and hazardous schedules minimize to replayable reproducer files.

This package depends only on ``repro.core.workflow`` /
``repro.core.migration`` / ``repro.obs`` — never on the runtime — so the
runtime can import it for admission-time validation without a cycle.
"""
from repro.analysis import explorer, sanitizer, selfcheck  # noqa: F401
from repro.analysis.findings import (ERROR, INFO, RULES, WARNING,  # noqa: F401
                                     Finding, RuleInfo, max_severity)
from repro.analysis.verifier import WorkflowRejected, verify  # noqa: F401
