"""Finding model + rule catalogue for Emerald's correctness tooling.

Every check in this package — static verifier rules (``W``), dynamic
sanitizer hazards (``H``) and source self-lint rules (``L``) — is
registered here with a stable id, default severity, one-line title and a
fix hint. A check reports a :class:`Finding` referencing its rule id, so
consumers (``submit(validate=...)``, ``scripts/emlint.py``, the defect
corpus under ``tests/defects/``) can match on ids instead of message
text.

Severities:

  * ``error``   — the workflow/run is broken; ``submit(validate="error")``
                  rejects it at admission.
  * ``warning`` — almost certainly a bug (race, stale-memo risk) but the
                  run can proceed; surfaced, never blocking by default.
  * ``info``    — worth knowing (e.g. a remotable step that will fall
                  back in-process); never blocking.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

ERROR = "error"
WARNING = "warning"
INFO = "info"

_SEVERITIES = (ERROR, WARNING, INFO)


@dataclass(frozen=True)
class RuleInfo:
    rule: str        # stable id, e.g. "W001"
    severity: str    # default severity of findings from this rule
    title: str       # short kebab-ish name, e.g. "cycle"
    hint: str        # generic fix hint shown in the catalogue


@dataclass(frozen=True)
class Finding:
    rule: str                      # RuleInfo.rule
    severity: str                  # error | warning | info
    message: str                   # concrete, names the offending objects
    steps: Tuple[str, ...] = ()    # step names involved (order meaningful)
    uri: str = ""                  # offending variable/MDSS URI, if any
    hint: str = ""                 # fix hint (defaults to the rule's)
    where: str = ""                # file:line / source location, if known

    def __str__(self):
        loc = f" [{self.where}]" if self.where else ""
        steps = f" steps={','.join(self.steps)}" if self.steps else ""
        return (f"{self.rule} {self.severity}: {self.message}"
                f"{steps}{loc}" + (f"\n      hint: {self.hint}"
                                   if self.hint else ""))


#: rule id -> RuleInfo; populated by the ``_rule`` calls below.
RULES: Dict[str, RuleInfo] = {}


def _rule(rule: str, severity: str, title: str, hint: str) -> str:
    assert severity in _SEVERITIES and rule not in RULES
    RULES[rule] = RuleInfo(rule, severity, title, hint)
    return rule


def finding(rule: str, message: str, steps=(), uri: str = "",
            hint: str = "", where: str = "",
            severity: str = "") -> Finding:
    """Build a Finding for a registered rule (severity defaults to the
    rule's; hint defaults to the rule's catalogue hint)."""
    info = RULES[rule]
    return Finding(rule, severity or info.severity, message,
                   tuple(steps), uri, hint or info.hint, where)


# ----------------------------------------------------------------- verifier
W001 = _rule("W001", ERROR, "cycle",
             "break the dependency cycle: some step must consume an "
             "initial value (provide the variable at submit) instead of "
             "a later step's output")
W002 = _rule("W002", ERROR, "unbound-input",
             "pass the variable in init_vars, publish() it into the "
             "shared namespace, or add a step that writes it first")
W003 = _rule("W003", ERROR, "no-impl",
             "give the step a fn= callable or a remote_impl= registry "
             "name")
W004 = _rule("W004", WARNING, "unknown-remote-impl",
             "register the name with repro.cloud.tasklib.register_step "
             "(or list its module in Fabric(init_modules=...))")
W005 = _rule("W005", ERROR, "signature-mismatch",
             "make the fn's parameters match the step's declared inputs "
             "(staging calls fn(**{input: value}))")
W010 = _rule("W010", WARNING, "ww-hazard",
             "make the second writer read the first version (true "
             "dataflow), or drop one of the writes — the final version "
             "is otherwise ordered only by declaration-order fencing")
W011 = _rule("W011", WARNING, "rw-hazard",
             "make the overwriter consume the reader's output so the "
             "read-before-overwrite ordering is real dataflow, not just "
             "a scheduler fence")
W012 = _rule("W012", WARNING, "dead-write",
             "no step reads this version before it is overwritten — "
             "drop the write or route a reader to it")
W020 = _rule("W020", INFO, "not-fabric-runnable",
             "the step will fall back in-process on fabric-backed "
             "tiers; register a remote_impl or use a module-level "
             "picklable fn to ship it to workers")
W021 = _rule("W021", WARNING, "device-capture",
             "the fn closes over a device array; pass it as a declared "
             "input instead so staging manages placement and the "
             "closure stays shippable")
W030 = _rule("W030", WARNING, "memo-unsafe",
             "a memoizable step must read only its declared inputs; "
             "move captured state into inputs or set memoizable=False")
W031 = _rule("W031", WARNING, "memo-no-output",
             "memoization keys on output names — a step with no outputs "
             "is never memoized; declare outputs or drop memoizable")
W040 = _rule("W040", WARNING, "budget-infeasible",
             "declared residency_budget is smaller than the bytes the "
             "workflow declares it will materialise on that tier — the "
             "run will thrash the evictor; raise the budget or shrink "
             "bytes_hint")
W041 = _rule("W041", WARNING, "budget-unknown-tier",
             "residency_budget names a tier the runtime does not have; "
             "the budget will never be enforced")
W050 = _rule("W050", INFO, "dead-step",
             "no final output is reachable from this step's outputs — "
             "it burns a lane slot for nothing; drop it or consume its "
             "outputs")
W060 = _rule("W060", ERROR, "fanout-spec",
             "fix the Fanout annotation: shards must be >= 1 and every "
             "scatter= name must be one of the step's declared inputs "
             "(a step with no inputs has nothing to scatter)")
W061 = _rule("W061", WARNING, "fanout-unpicklable-fn",
             "partition_fn/combine_fn is a closure or lambda the fabric "
             "and checkpoints cannot carry; use a module-level function")
W062 = _rule("W062", ERROR, "fanout-gather-missing-shard",
             "the gather step must read every sibling shard's output "
             "URI (out#0..out#N-1) — a dropped shard would silently "
             "vanish from the combined result")
W063 = _rule("W063", ERROR, "fanout-sibling-ww",
             "sibling shards of one fan-out must write disjoint shard "
             "URIs; two shards writing the same uri#k race on the final "
             "version")
W070 = _rule("W070", WARNING, "slo-unbatchable",
             "slo_ms only steers the serving front door for remotable, "
             "memoizable (deterministic, declared-inputs-only) steps the "
             "coalescer can key by code fingerprint; drop the SLO or "
             "make the step batchable")
W071 = _rule("W071", ERROR, "preemptible-shard-no-gather",
             "a preemptible fan-out shard can be checkpoint-aborted and "
             "requeued; without the sibling gather barrier nothing "
             "fences re-publication of its shard URI — add the gather "
             "step or drop preemptible")

# ---------------------------------------------------------------- sanitizer
H101 = _rule("H101", ERROR, "duplicate-done",
             "a step completed more times than it was dispatched — a "
             "replayed/forged completion got past the runtime's "
             "outstanding-set guard")
H102 = _rule("H102", ERROR, "orphan-completion",
             "a completion arrived for a step never granted a lane slot "
             "— the event stream violates dispatch -> done ordering")
H103 = _rule("H103", ERROR, "lost-completion",
             "a dispatched step never reported done in a run that "
             "finished successfully — a completion was dropped")
H110 = _rule("H110", ERROR, "install-regression",
             "a tier's replica of a URI went backwards in version within "
             "one namespace epoch — a stale transfer overwrote a newer "
             "write (version-hazard fence failed)")
H111 = _rule("H111", ERROR, "evict-install-race",
             "a replica version was evicted that was never installed on "
             "that tier — eviction raced an in-flight install")

# ------------------------------------------------- explorer (cross-schedule)
H120 = _rule("H120", ERROR, "fence-epoch-regression",
             "an install landed carrying a namespace epoch older than one "
             "already observed for that namespace — a transfer that "
             "started before drop_namespace() installed into the reused "
             "namespace; fence the install on the live epoch")
H121 = _rule("H121", ERROR, "memo-double-execution",
             "one memo key (code fingerprint + input digests) executed "
             "more than once under memoization — the in-flight entry "
             "guard failed to make the second tenant a waiter")
H122 = _rule("H122", ERROR, "fair-share-starvation",
             "a run with ready steps and the smallest virtual time was "
             "passed over for a full starvation window of dispatches — "
             "the deficit-weighted scheduler is not serving the run it "
             "owes the next slot")
H123 = _rule("H123", ERROR, "residency-overshoot",
             "a namespace's resident bytes exceeded its configured "
             "per-tier budget — eviction did not run (or ran too late) "
             "on the install that crossed the ceiling")
H124 = _rule("H124", ERROR, "checkpoint-divergence",
             "resuming from a checkpointed prefix converged to different "
             "final content digests than the uninterrupted run — the "
             "checkpoint froze an inconsistent (completed, vars) pair "
             "or resume re-applied a non-idempotent step")
H125 = _rule("H125", ERROR, "parked-run-starved",
             "a parked submission stayed eligible (capacity free, head "
             "of the deadline order) for a full admission window without "
             "being admitted — the drain loop missed the capacity-freed "
             "wakeup; every slot release must re-run admission")
H126 = _rule("H126", ERROR, "preempt-burned-progress",
             "a preempted batch step lost retry budget or a completed "
             "checkpoint step — preemption must be attempt-free and "
             "resume from the latest checkpoint, else SLO pressure "
             "silently eats batch tenants' work")

# ---------------------------------------------------------------- selfcheck
L001 = _rule("L001", ERROR, "unregistered-event-kind",
             "add the kind to repro.obs.events.EVENT_SCHEMA with its "
             "required/optional info keys")
L002 = _rule("L002", ERROR, "unregistered-metric",
             "add the name to repro.obs.metrics.METRIC_CATALOG with a "
             "one-line doc")
L010 = _rule("L010", ERROR, "lock-order-inversion",
             "two code paths acquire the same pair of locks in opposite "
             "orders — a classic ABBA deadlock; pick one canonical order "
             "(document it on the lock declarations) and fix the "
             "inverted site")
L011 = _rule("L011", WARNING, "blocking-call-under-lock",
             "a blocking operation (sleep, socket recv/accept, untimed "
             "wait on a foreign event, pickling) runs while a lock is "
             "held — every other thread contending on that lock stalls "
             "for the full blocking duration; move the slow work outside "
             "the critical section")
L012 = _rule("L012", ERROR, "cond-wait-no-predicate-loop",
             "Condition.wait() outside a while-predicate loop — spurious "
             "wakeups and missed notifies are legal, so the waiter must "
             "re-check its predicate in a loop (while not pred: "
             "cond.wait())")


def max_severity(findings) -> str:
    """Worst severity present ('' when findings is empty)."""
    worst = ""
    for f in findings:
        if f.severity == ERROR:
            return ERROR
        if f.severity == WARNING:
            worst = WARNING
        elif not worst:
            worst = INFO
    return worst
