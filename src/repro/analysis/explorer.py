"""emcheck — deterministic schedule-space exploration for Emerald.

The PR 7 sanitizer judges *one* interleaving: whatever the threads
happened to do in that test run. This module enumerates interleavings.
It builds a model of ``EmeraldRuntime``'s scheduling semantics — lanes,
fair share, namespaced versioned store with budgets/eviction, cross-run
memoization, per-completion checkpoints — on top of the
:mod:`repro.cloud.simfabric` virtual-clock seam, where every
nondeterministic choice the real system resolves with thread timing is
an explicit, replayable *decision*:

  ``dispatch:<run>:<step>``   which ready step takes a free lane slot
  ``complete:<run>:<step>``   which in-flight completion lands first
  ``crash:<run>:<step>``      a worker dies under the task (burns a retry)
  ``timeout:<run>:<step>``    a ship times out and is harvested (no burn)
  ``preempt:<run>:<step>``    spot-style reclaim of the worker (no burn)
  ``install:<run>:<uri>``     a deferred write-back install lands
  ``ghost:<run>:<step>``      a duplicate completion lands (bug-flag only)
  ``drop:<run>``              namespace drop + warm resubmit

A ``Schedule`` is just the list of decisions taken; replaying it through
a fresh :class:`Simulation` reproduces the identical trace, which is
what makes minimized reproducer files deterministic.

Exploration strategies:

  * :func:`explore` — exhaustive DFS for small DAGs, with visited-state
    dedup and a conservative partial-order reduction: when the *only*
    enabled decisions are completions of tasks touching pairwise
    disjoint output URIs (and no shared memo key), all orders commute,
    so a single canonical order is explored.
  * :func:`sample` — seeded random walks for large DAGs, with
    crash/preempt/timeout injection driven by the fault budgets.

Every explored trace replays through the PR 7 sanitizer (H101–H111)
plus the cross-schedule invariants registered in ``findings.py``:
H120 fence-epoch regression, H121 memo double-execution, H122
fair-share starvation, H123 residency-budget overshoot, H124
checkpoint/resume divergence, H125 parked-run starvation, H126
preemption burning batch progress. A hazard-triggering schedule is
delta-debugged (:func:`minimize`) to a 1-minimal decision list and
serialized (:func:`save_reproducer`) for ``scripts/emcheck.py
--replay``.

Planted bugs: a model built with ``bugs={...}`` re-introduces a known
defect so the explorer can be validated against it (see ``BUGS``); the
flag ``duplicate_done`` is exactly the PR 4 double-decrement race.
"""
from __future__ import annotations

import hashlib
import json
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from ..core.workflow import Workflow
from ..cloud.simfabric import LOCAL, OFFLOAD, SimClock, SimFabric
from . import sanitizer
from .findings import Finding, finding

EMCHECK_VERSION = 1

#: planted-defect flags a model understands (each maps to the hazard the
#: explorer must find when the flag is set):
#:   duplicate_done — the PR 4 bug: a late/replayed completion is not
#:                    rejected by the outstanding-set guard  -> H101
#:   stale_install  — deferred write-back installs skip the version/
#:                    epoch fence                            -> H110/H120
#:   memo_no_guard  — the in-flight memo entry is not consulted, so a
#:                    concurrent same-key tenant re-executes -> H121
#:   unfair         — dispatch is not restricted to minimal-vtime runs,
#:                    so a schedule can starve a tenant      -> H122
#:   no_evict       — installs never trigger budget eviction -> H123
#:   ckpt_lost_step — the checkpoint freeze captures a step's outputs
#:                    but not its completion bit (the PR 4-era freeze
#:                    race), so resume re-applies it         -> H124
#:   parked_starved — the admission drain runs only at submit time
#:                    and misses the capacity-freed wakeup, so a
#:                    parked run stays eligible forever      -> H125
#:   preempt_lost_step — preemption burns a retry attempt and
#:                    discards the newest checkpointed step  -> H126
BUGS = ("duplicate_done", "stale_install", "memo_no_guard", "unfair",
        "no_evict", "ckpt_lost_step", "parked_starved",
        "preempt_lost_step")

Schedule = List[str]


def _digest(*parts: str) -> str:
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]


# =============================================================== model spec

@dataclass
class Tenant:
    """One simulated run: a real :class:`Workflow` plus submit options."""
    name: str
    wf: Workflow
    weight: float = 1.0
    init: Dict[str, str] = field(default_factory=dict)   # uri -> value token
    budgets: Dict[str, int] = field(default_factory=dict)  # tier -> bytes
    resubmit: bool = False   # after completing, drop namespace + run again
    park: bool = False       # submit into the admission queue (front door)
    deadline: float = 0.0    # admission order key: oldest deadline first


@dataclass
class SimModel:
    """A reconstructible scenario: tenants + knobs + planted bugs.

    ``name``/``params`` identify the builder in :data:`MODELS` so a
    reproducer file can rebuild the exact model; ad-hoc models (e.g.
    workflows collected from a user module by ``scripts/emcheck.py``)
    leave ``name`` empty and are replayable only in-process.
    """
    tenants: List[Tenant]
    offload_slots: int = 2
    local_slots: int = 1
    memoize: bool = False
    max_crashes: int = 0
    max_timeouts: int = 0
    max_preempts: int = 0
    starvation_window: int = 8
    admit_capacity: int = 0   # >0: parked tenants drain through this many
                              # concurrently-live admitted-run slots
    accum_steps: Set[str] = field(default_factory=set)
    bugs: Set[str] = field(default_factory=set)
    name: str = ""
    params: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self):
        unknown = set(self.bugs) - set(BUGS)
        assert not unknown, f"unknown bug flags: {sorted(unknown)}"

    @property
    def fair(self) -> bool:
        return "unfair" not in self.bugs


# ============================================================== simulation

class _SimRun:
    """Per-tenant dataflow state over the real Workflow object."""

    def __init__(self, tenant: Tenant):
        self.tenant = tenant
        self.name = tenant.name
        wf = tenant.wf
        self.steps = dict(wf.steps)
        self.succs = wf.successors()
        self.indeg = dict(wf.in_degrees())
        self.remaining = dict(self.indeg)
        self.completed: Set[str] = set()
        self.ready: List[str] = sorted(
            n for n, d in self.indeg.items() if d == 0)
        self.failed = False
        self.passes = 0           # completed warm-resubmit passes
        self.events: List[dict] = []
        # last consistent checkpoint: (completed frozenset, {uri: digest})
        self.ckpt: Tuple[frozenset, Dict[str, str]] = (frozenset(), {})

    def lane_of(self, step: str) -> str:
        return OFFLOAD if self.steps[step].remotable else LOCAL

    def reset_for_resubmit(self):
        self.remaining = dict(self.indeg)
        self.completed = set()
        self.ready = sorted(n for n, d in self.indeg.items() if d == 0)
        self.ckpt = (frozenset(), {})

    def done(self) -> bool:
        if self.failed:
            return True
        finished = len(self.completed) == len(self.steps)
        if self.tenant.resubmit:
            return finished and self.passes >= 1
        return finished


class SimStore:
    """Namespaced, versioned, budgeted content store (the MDSS model).

    Tracks per-URI versions and content digests, per-(uri, tier)
    replicas, per-namespace epochs, per-(namespace, tier) resident
    bytes with LRU eviction against tenant budgets, and the same
    install/eviction rows the sanitizer's ``check_store`` replays:
    ``(uri, tier, version, epoch, t)`` and
    ``(uri, tier, bytes, version, epoch, t)``.
    """

    def __init__(self, model: SimModel):
        self.model = model
        self.versions: Dict[str, int] = {}
        self.digests: Dict[str, str] = {}
        self.bytes_of: Dict[str, int] = {}
        self.replicas: Dict[Tuple[str, str], Tuple[int, int]] = {}
        self.epochs: Dict[str, int] = {t.name: 0 for t in model.tenants}
        self.lru: Dict[Tuple[str, str], List[str]] = {}   # (ns,tier)->uris
        self.installs: List[tuple] = []
        self.evictions: List[tuple] = []
        self.residency: List[tuple] = []  # (t, ns, tier, bytes)

    @staticmethod
    def ns_of(uri: str) -> str:
        return uri.split("/", 1)[0]

    def resident_bytes(self, ns: str, tier: str) -> int:
        return sum(self.bytes_of.get(u, 0)
                   for u in self.lru.get((ns, tier), ()))

    def _touch(self, uri: str, tier: str):
        ns = self.ns_of(uri)
        row = self.lru.setdefault((ns, tier), [])
        if uri in row:
            row.remove(uri)
        row.append(uri)

    def install(self, uri: str, tier: str, version: int, epoch: int,
                t: float, nbytes: int):
        self.installs.append((uri, tier, version, epoch, t))
        self.replicas[(uri, tier)] = (version, epoch)
        self.bytes_of[uri] = nbytes
        self._touch(uri, tier)

    def put(self, run: "_SimRun", uri: str, digest: str, nbytes: int,
            t: float, tier: str) -> int:
        ns = self.ns_of(uri)
        v = self.versions.get(uri, 0) + 1
        self.versions[uri] = v
        self.digests[uri] = digest
        self.install(uri, tier, v, self.epochs[ns], t, nbytes)
        return v

    def enforce_budget(self, ns: str, tier: str, t: float):
        budget = None
        for ten in self.model.tenants:
            if ten.name == ns:
                budget = ten.budgets.get(tier)
        if budget is None:
            return
        if "no_evict" in self.model.bugs:
            return
        row = self.lru.get((ns, tier), [])
        while row and self.resident_bytes(ns, tier) > budget:
            victim = row.pop(0)
            ver, ep = self.replicas.pop((victim, tier),
                                        (self.versions.get(victim, 1),
                                         self.epochs[ns]))
            self.evictions.append((victim, tier,
                                   self.bytes_of.get(victim, 0),
                                   ver, ep, t))

    def sample_residency(self, t: float):
        for ten in self.model.tenants:
            for tier in ten.budgets:
                self.residency.append(
                    (t, ten.name, tier,
                     self.resident_bytes(ten.name, tier)))

    def drop_namespace(self, ns: str):
        self.epochs[ns] += 1
        prefix = ns + "/"
        for uri in [u for u in self.versions if u.startswith(prefix)]:
            self.versions.pop(uri)
            self.digests.pop(uri, None)
            self.bytes_of.pop(uri, None)
        for key in [k for k in self.replicas if k[0].startswith(prefix)]:
            self.replicas.pop(key)
        for key in list(self.lru):
            if key[0] == ns:
                self.lru[key] = []

    def state_key(self) -> tuple:
        return (tuple(sorted(self.versions.items())),
                tuple(sorted(self.replicas.items())),
                tuple(sorted(self.epochs.items())),
                tuple(sorted((k, tuple(v)) for k, v in self.lru.items())))


class Simulation:
    """One deterministic execution of a :class:`SimModel`.

    Drive it with :meth:`enabled` / :meth:`apply`; the decisions taken
    accumulate in ``self.schedule``. ``preload`` (used by the H124
    resume check) seeds a tenant's completed set and variable digests
    from a checkpoint before the first decision.
    """

    def __init__(self, model: SimModel,
                 preload: Optional[Dict[str, Tuple[frozenset,
                                                   Dict[str, str]]]] = None):
        self.model = model
        self.clock = SimClock()
        self.fabric = SimFabric(
            self.clock, offload_slots=model.offload_slots,
            local_slots=model.local_slots, max_crashes=model.max_crashes,
            max_timeouts=model.max_timeouts,
            max_preempts=model.max_preempts)
        self.store = SimStore(model)
        self.runs: Dict[str, _SimRun] = {}
        self.vtime: Dict[str, float] = {}
        self.exec_nonce = 0
        self.memo_done: Dict[str, str] = {}      # key -> owner "run:step"
        self.memo_inflight: Dict[str, Tuple[str, str]] = {}
        self.executions: List[tuple] = []        # (key, run, step, t)
        self.dispatch_rounds: List[tuple] = []   # (chosen_run, owed tuple)
        self.admission_rounds: List[tuple] = []  # (admitted tuple, eligible)
        self.preempt_log: List[tuple] = []       # (run, step, d_attempts,
                                                 #  ckpt_before, ckpt_after)
        self.pending: List[str] = []             # deferred install/ghost
        self.pending_installs: Dict[str, tuple] = {}  # decision -> payload
        self.schedule: Schedule = []
        self.parked: List[str] = []       # park tenants awaiting admission
        for ten in model.tenants:
            run = _SimRun(ten)
            self.runs[ten.name] = run
            self.vtime[ten.name] = 0.0
            if ten.park and model.admit_capacity:
                self.parked.append(ten.name)
            for uri, token in ten.init.items():
                full = f"{ten.name}/{uri}"
                self.store.put(run, full, _digest("init", token), 1,
                               self.clock.now(), LOCAL)
        # submit-time drain: both the clean model and the parked_starved
        # bug admit whatever fits right now — the bug is that ONLY this
        # drain ever runs (the capacity-freed wakeup is lost)
        self._drain_admission()
        if preload:
            for name, (completed, digests) in preload.items():
                run = self.runs[name]
                run.completed = set(completed)
                for step in completed:
                    for succ in run.succs.get(step, ()):
                        run.remaining[succ] -= 1
                run.ready = sorted(
                    n for n in run.steps
                    if n not in run.completed and run.remaining[n] == 0)
                t = self.clock.now()
                for uri, dig in digests.items():
                    full = f"{name}/{uri}"
                    ns = name
                    v = self.store.versions.get(full, 0) + 1
                    self.store.versions[full] = v
                    self.store.digests[full] = dig
                    self.store.install(full, LOCAL, v,
                                       self.store.epochs[ns], t, 1)

    # ----------------------------------------------------------- enumeration
    def done(self) -> bool:
        return (all(r.done() for r in self.runs.values())
                and self.fabric.idle())

    def _dispatch_candidates(self, lane: str) -> List[Tuple[str, str]]:
        """(run, step) pairs dispatchable on ``lane`` right now."""
        out = []
        for name in sorted(self.runs):
            run = self.runs[name]
            if run.failed or name in self.parked:
                continue
            for step in run.ready:
                if run.lane_of(step) == lane:
                    out.append((name, step))
        return out

    # ------------------------------------------------------------- admission
    def _admission_eligible(self) -> List[str]:
        """Parked runs the front door owes admission right now: free
        admitted-run slots filled oldest-deadline-first (strict
        head-of-queue, like the runtime's drain loop)."""
        if not self.model.admit_capacity or not self.parked:
            return []
        live = sum(1 for n, r in self.runs.items()
                   if r.tenant.park and n not in self.parked
                   and not r.done())
        free = self.model.admit_capacity - live
        if free <= 0:
            return []
        order = sorted(self.parked,
                       key=lambda n: (self.runs[n].tenant.deadline, n))
        return order[:free]

    def _drain_admission(self) -> List[str]:
        admitted: List[str] = []
        while True:
            elig = self._admission_eligible()
            if not elig:
                return admitted
            for n in elig:
                self.parked.remove(n)
                admitted.append(n)

    def _owed(self, cands: Sequence[Tuple[str, str]]) -> List[str]:
        """Runs the fair-share scheduler owes the next slot (minimal
        virtual time among the candidates' runs)."""
        runs = sorted({r for r, _ in cands})
        lo = min(self.vtime[r] for r in runs)
        return [r for r in runs if self.vtime[r] <= lo + 1e-9]

    def enabled(self) -> List[str]:
        """All decisions legal in the current state, in a canonical
        deterministic order."""
        acts: List[str] = []
        for lane in (OFFLOAD, LOCAL):
            if self.fabric.free(lane) <= 0:
                continue
            cands = self._dispatch_candidates(lane)
            if not cands:
                continue
            if self.model.fair:
                owed = set(self._owed(cands))
                cands = [(r, s) for r, s in cands if r in owed]
            acts += [f"dispatch:{r}:{s}" for r, s in cands]
        for task in self.fabric.inflight():
            if (task.wait_key is not None
                    and task.wait_key not in self.memo_done):
                continue   # memo waiter gated on its owner's completion
            acts.append(f"complete:{task.run_id}:{task.step}")
        acts += list(self.pending)
        for task in self.fabric.inflight():
            if self.fabric.crashes_left > 0:
                acts.append(f"crash:{task.run_id}:{task.step}")
            if self.fabric.timeouts_left > 0:
                acts.append(f"timeout:{task.run_id}:{task.step}")
            if self.fabric.preempts_left > 0:
                acts.append(f"preempt:{task.run_id}:{task.step}")
        for name in sorted(self.runs):
            run = self.runs[name]
            if (run.tenant.resubmit and not run.failed and run.passes == 0
                    and len(run.completed) == len(run.steps)
                    and not any(t.run_id == name
                                for t in self.fabric.inflight())):
                acts.append(f"drop:{name}")
        return acts

    # ------------------------------------------------------------- mutation
    def _emit(self, run: "_SimRun", kind: str, step: str, t: float,
              **info):
        run.events.append({"kind": kind, "step": step, "t": t,
                           "info": info})

    def _memo_key(self, run: "_SimRun", step: str) -> Optional[str]:
        s = run.steps[step]
        if not self.model.memoize or s.memoizable is False or not s.outputs:
            return None
        in_digs = [self.store.digests.get(f"{run.name}/{u}", "?")
                   for u in sorted(s.inputs)]
        return _digest("memo", s.name, ",".join(sorted(s.inputs)),
                       ",".join(sorted(s.outputs)), *in_digs)

    def _out_digest(self, run: "_SimRun", step: str, uri: str) -> str:
        s = run.steps[step]
        in_digs = [self.store.digests.get(f"{run.name}/{u}", "?")
                   for u in sorted(s.inputs)]
        prev = ""
        if step in self.model.accum_steps:
            # non-idempotent step: folds its output's current content in
            prev = self.store.digests.get(f"{run.name}/{uri}", "")
        return _digest("out", s.name, uri, prev, *in_digs)

    def apply(self, decision: str):
        self.schedule.append(decision)
        t = self.clock.tick()
        parts = decision.split(":")
        kind = parts[0]
        handler = getattr(self, f"_do_{kind}")
        handler(parts[1:], t)
        if self.model.admit_capacity:
            # admission is deterministic, not a schedulable decision:
            # the runtime's drain loop runs after every driver message,
            # so the model drains eagerly after every decision. Under
            # parked_starved only the submit-time drain ever ran, so
            # capacity freed here is never noticed.
            eligible = tuple(self._admission_eligible())
            if "parked_starved" in self.model.bugs:
                admitted: Tuple[str, ...] = ()
            else:
                admitted = tuple(self._drain_admission())
            self.admission_rounds.append((admitted, eligible))
        self.store.sample_residency(t)

    def _do_dispatch(self, args: List[str], t: float):
        name, step = args
        run = self.runs[name]
        run.ready.remove(step)
        lane = run.lane_of(step)
        task = self.fabric.dispatch(name, step, lane,
                                    retries=run.steps[step].retries)
        # log the fair-share round before charging: owed = runs the
        # scheduler owes THIS slot (min vtime among this lane's
        # candidates, the dispatched step included)
        cands = [(name, step)] + self._dispatch_candidates(lane)
        self.dispatch_rounds.append((name, tuple(self._owed(cands))))
        self.vtime[name] += 1.0 / run.tenant.weight
        self._emit(run, "dispatch", step, t, lane=lane)
        key = self._memo_key(run, step)
        if key is not None:
            if key in self.memo_done:
                task.memo_hit = True
            elif (key in self.memo_inflight
                  and "memo_no_guard" not in self.model.bugs):
                task.wait_key = key
            else:
                self.memo_inflight[key] = (name, step)
        task.memo_keyed = key  # type: ignore[attr-defined]

    def _do_complete(self, args: List[str], t: float):
        name, step = args
        run = self.runs[name]
        task = self.fabric.complete(name, step)
        key = getattr(task, "memo_keyed", None)
        executed = not task.memo_hit and task.wait_key is None
        if executed:
            self.exec_nonce += 1
            if key is not None:
                self.executions.append((key, name, step, t))
                self.memo_done[key] = f"{name}:{step}"
                self.memo_inflight.pop(key, None)
        s = run.steps[step]
        for uri in s.outputs:
            full = f"{run.name}/{uri}"
            dig = self._out_digest(run, step, uri)
            nbytes = max(1, s.bytes_hint // max(1, len(s.outputs))
                         if s.bytes_hint else 1)
            if task.lane == OFFLOAD:
                v = self.store.put(run, full, dig, nbytes, t, "cloud")
                ep = self.store.epochs[run.name]
                d = f"install:{name}:{uri}"
                if d not in self.pending_installs:
                    self.pending.append(d)
                self.pending_installs[d] = (full, v, ep, dig, nbytes)
                self.store.enforce_budget(run.name, "cloud", t)
            else:
                self.store.put(run, full, dig, nbytes, t, LOCAL)
                self.store.enforce_budget(run.name, LOCAL, t)
        run.completed.add(step)
        for succ in run.succs.get(step, ()):
            run.remaining[succ] -= 1
            if run.remaining[succ] == 0 and succ not in run.completed:
                run.ready.append(succ)
        run.ready.sort()
        self._emit(run, "step_done", step, t,
                   offloaded=task.lane == OFFLOAD)
        # checkpoint after every completion, like RunCheckpointer
        digests = dict(run.ckpt[1])
        for uri in s.outputs:
            digests[uri] = self.store.digests[f"{run.name}/{uri}"]
        completed = set(run.completed)
        if "ckpt_lost_step" in self.model.bugs:
            # the freeze race: outputs captured, completion bit lost
            completed.discard(step)
        run.ckpt = (frozenset(completed), digests)
        if "duplicate_done" in self.model.bugs and task.lane == OFFLOAD:
            d = f"ghost:{name}:{step}"
            if d not in self.pending:
                self.pending.append(d)

    def _do_ghost(self, args: List[str], t: float):
        name, step = args
        self.pending.remove(f"ghost:{name}:{step}")
        run = self.runs[name]
        # the PR 4 bug: the outstanding-set guard is gone, so the late
        # duplicate lands as a second step_done
        self._emit(run, "step_done", step, t, offloaded=True)

    def _do_install(self, args: List[str], t: float):
        name, uri = args
        d = f"install:{name}:{uri}"
        self.pending.remove(d)
        full, v, ep, dig, nbytes = self.pending_installs.pop(d)
        stale = (self.store.epochs[name] != ep
                 or self.store.versions.get(full) != v)
        if stale and "stale_install" not in self.model.bugs:
            return   # fenced: the write-back is discarded
        self.store.install(full, LOCAL, v, ep, t, nbytes)
        self.store.enforce_budget(name, LOCAL, t)

    def _do_crash(self, args: List[str], t: float):
        name, step = args
        run = self.runs[name]
        survived = self.fabric.crash(name, step)
        self._emit(run, "retry", step, t,
                   attempt=self.fabric.task(name, step).attempts
                   if survived else run.steps[step].retries + 1)
        if not survived:
            self._fail_run(run)

    def _do_timeout(self, args: List[str], t: float):
        name, step = args
        run = self.runs[name]
        self.fabric.timeout(name, step)
        self._emit(run, "retry", step, t, attempt=0)

    def _do_preempt(self, args: List[str], t: float):
        name, step = args
        run = self.runs[name]
        task = self.fabric.task(name, step)
        before = task.attempts
        ckpt_before = len(run.ckpt[0])
        self.fabric.preempt(name, step)
        if "preempt_lost_step" in self.model.bugs:
            # the checkpoint-abort bug: the requeue path charges the
            # retry budget and the abort tears down the newest
            # checkpointed step along with the in-flight one
            task.attempts += 1
            if run.ckpt[0]:
                completed = set(run.ckpt[0])
                completed.discard(max(completed))
                run.ckpt = (frozenset(completed), dict(run.ckpt[1]))
        self.preempt_log.append((name, step, task.attempts - before,
                                 ckpt_before, len(run.ckpt[0])))
        self._emit(run, "retry", step, t, attempt=0)

    def _do_drop(self, args: List[str], t: float):
        (name,) = args
        run = self.runs[name]
        self.store.drop_namespace(name)
        run.passes += 1
        run.reset_for_resubmit()
        for ten_uri, token in run.tenant.init.items():
            full = f"{name}/{ten_uri}"
            self.store.put(run, full, _digest("init", token), 1, t, LOCAL)

    def _fail_run(self, run: "_SimRun"):
        run.failed = True
        run.ready = []
        for task in self.fabric.drop_run(run.name):
            key = getattr(task, "memo_keyed", None)
            if key is not None and self.memo_inflight.get(key) == task.key:
                self.memo_inflight.pop(key)   # un-poison for waiters
        for k in [p for p in self.pending
                  if p.split(":")[1] == run.name]:
            self.pending.remove(k)
            self.pending_installs.pop(k, None)

    # ------------------------------------------------------------- identity
    def state_key(self) -> tuple:
        runs = tuple(
            (n, frozenset(r.completed), tuple(r.ready), r.failed,
             r.passes)
            for n, r in sorted(self.runs.items()))
        vt = tuple((n, round(v, 6)) for n, v in sorted(self.vtime.items()))
        return (runs, vt, self.fabric.state_key(), self.store.state_key(),
                tuple(self.pending),
                tuple(sorted(self.memo_done)),
                tuple(sorted(self.memo_inflight)),
                tuple(sorted(self.parked)))

    # --------------------------------------------------------------- output
    def run_states(self) -> Dict[str, str]:
        return {n: ("failed" if r.failed else
                    "done" if r.done() else "running")
                for n, r in self.runs.items()}

    def final_digests(self) -> Dict[str, Dict[str, str]]:
        out: Dict[str, Dict[str, str]] = {}
        for name in self.runs:
            prefix = name + "/"
            out[name] = {u[len(prefix):]: d
                         for u, d in sorted(self.store.digests.items())
                         if u.startswith(prefix)}
        return out

    def trace(self) -> dict:
        ten_budgets = {}
        for ten in self.model.tenants:
            for tier, b in ten.budgets.items():
                ten_budgets[f"{ten.name}:{tier}"] = b
        return {
            "events": {n: r.events for n, r in sorted(self.runs.items())},
            "run_states": self.run_states(),
            "installs": list(self.store.installs),
            "evictions": list(self.store.evictions),
            "executions": list(self.executions),
            "dispatch_rounds": list(self.dispatch_rounds),
            "admission_rounds": list(self.admission_rounds),
            "admission_window": self.model.starvation_window,
            "preempt_log": list(self.preempt_log),
            "fair": self.model.fair,
            "starvation_window": self.model.starvation_window,
            "budgets": ten_budgets,
            "residency": list(self.store.residency),
        }


# ========================================================== trace checking

def check_trace(trace: dict) -> List[Finding]:
    """Replay one explored trace through the PR 7 sanitizer plus the
    cross-schedule invariants H120–H123. Accepts the dict produced by
    :meth:`Simulation.trace`; missing sections are skipped, so defect-
    corpus artifacts can carry only the section a rule needs."""
    out: List[Finding] = []
    states = trace.get("run_states", {})
    for name, events in trace.get("events", {}).items():
        out += sanitizer.check(
            events, completed_run=states.get(name, "done") == "done")
    if "installs" in trace or "evictions" in trace:
        out += sanitizer.check_store(trace.get("installs", ()),
                                     trace.get("evictions", ()))
        out += check_epochs(trace.get("installs", ()))
    if "executions" in trace:
        out += check_memo(trace["executions"])
    if "dispatch_rounds" in trace:
        out += check_starvation(trace["dispatch_rounds"],
                                trace.get("starvation_window", 8))
    if "admission_rounds" in trace:
        out += check_admission(trace["admission_rounds"],
                               trace.get("admission_window", 8))
    if "preempt_log" in trace:
        out += check_preemption(trace["preempt_log"])
    if "residency" in trace:
        out += check_residency(trace.get("budgets", {}),
                               trace["residency"])
    if "base_digests" in trace:
        out += check_resume_digests(trace["base_digests"],
                                    trace.get("resumed", ()))
    return out


def check_epochs(installs: Iterable[tuple]) -> List[Finding]:
    """H120: within one namespace, installs must never carry an epoch
    older than one already observed — a stale pre-drop transfer landing
    in the reused namespace."""
    out: List[Finding] = []
    seen: Dict[str, Tuple[int, str]] = {}   # ns -> (max epoch, uri)
    for uri, tier, version, epoch, t in sorted(installs,
                                               key=lambda r: r[4]):
        ns = uri.split("/", 1)[0]
        hi = seen.get(ns)
        if hi is not None and epoch < hi[0]:
            out.append(finding(
                "H120",
                f"install of {uri} v{version} on {tier} at t={t:g} "
                f"carries epoch {epoch} after namespace {ns!r} reached "
                f"epoch {hi[0]} (via {hi[1]})",
                uri=uri))
        if hi is None or epoch > hi[0]:
            seen[ns] = (epoch, uri)
    return out


def check_memo(executions: Iterable[tuple]) -> List[Finding]:
    """H121: one memo key must execute at most once."""
    out: List[Finding] = []
    first: Dict[str, tuple] = {}
    for key, run, step, t in executions:
        if key in first:
            r0, s0, t0 = first[key]
            out.append(finding(
                "H121",
                f"memo key {key} executed twice: {r0}:{s0} at t={t0:g} "
                f"and {run}:{step} at t={t:g} — the second should have "
                f"joined the in-flight entry as a waiter",
                steps=(s0, step)))
        else:
            first[key] = (run, step, t)
    return out


def check_starvation(dispatch_rounds: Iterable[tuple],
                     window: int) -> List[Finding]:
    """H122: under fair share, a run the scheduler owes the next slot
    (minimal virtual time, ready work) must be dispatched within the
    starvation window of consecutive dispatch rounds."""
    out: List[Finding] = []
    owed_streak: Dict[str, int] = {}
    flagged: Set[str] = set()
    for chosen, owed in dispatch_rounds:
        for run in owed:
            if run == chosen:
                owed_streak[run] = 0
            else:
                owed_streak[run] = owed_streak.get(run, 0) + 1
                if owed_streak[run] >= window and run not in flagged:
                    flagged.add(run)
                    out.append(finding(
                        "H122",
                        f"run {run!r} held the smallest virtual time "
                        f"with ready steps for {owed_streak[run]} "
                        f"consecutive dispatches without being chosen "
                        f"(window={window})"))
        for run in list(owed_streak):
            if run not in owed:
                owed_streak[run] = 0
    return out


def check_admission(admission_rounds: Iterable[tuple],
                    window: int) -> List[Finding]:
    """H125: a parked run the front door owes admission (capacity free,
    within the head of the deadline order) must be admitted within the
    admission window of consecutive drain rounds — a longer streak
    means a capacity-freed wakeup was lost."""
    out: List[Finding] = []
    streak: Dict[str, int] = {}
    flagged: Set[str] = set()
    for admitted, eligible in admission_rounds:
        for run in eligible:
            if run in admitted:
                streak[run] = 0
            else:
                streak[run] = streak.get(run, 0) + 1
                if streak[run] >= window and run not in flagged:
                    flagged.add(run)
                    out.append(finding(
                        "H125",
                        f"parked run {run!r} stayed admissible (free "
                        f"slot, head of the deadline order) for "
                        f"{streak[run]} consecutive drain rounds "
                        f"without being admitted (window={window})"))
        for run in list(streak):
            if run not in eligible:
                streak[run] = 0
    return out


def check_preemption(preempt_log: Iterable[tuple]) -> List[Finding]:
    """H126: preemption must be attempt-free and checkpoint-preserving —
    a preempted batch step may lose only its in-flight work, never
    retry budget or already-checkpointed completions."""
    out: List[Finding] = []
    for run, step, d_attempts, ckpt_before, ckpt_after in preempt_log:
        lost = []
        if d_attempts > 0:
            lost.append(f"burned {d_attempts} retry attempt(s)")
        if ckpt_after < ckpt_before:
            lost.append(f"dropped {ckpt_before - ckpt_after} "
                        "checkpointed completion(s)")
        if lost:
            out.append(finding(
                "H126",
                f"preemption of {run}:{step} {' and '.join(lost)} — "
                "SLO pressure is eating the batch tenant's progress",
                steps=(step,)))
    return out


def check_residency(budgets: Dict[str, int],
                    residency: Iterable[tuple]) -> List[Finding]:
    """H123: a namespace's resident bytes must never exceed its
    configured per-tier budget after any scheduler decision."""
    out: List[Finding] = []
    flagged: Set[str] = set()
    for t, ns, tier, nbytes in residency:
        key = f"{ns}:{tier}"
        budget = budgets.get(key)
        if budget is not None and nbytes > budget and key not in flagged:
            flagged.add(key)
            out.append(finding(
                "H123",
                f"namespace {ns!r} holds {nbytes} bytes on {tier} at "
                f"t={t:g}, over its budget of {budget} — eviction did "
                f"not fire on the crossing install"))
    return out


def check_resume(model: SimModel, schedule: Schedule) -> List[Finding]:
    """H124: resume from every checkpointed prefix of ``schedule`` must
    converge to the same final content digests as the uninterrupted
    run."""
    base = replay(model, schedule)
    run_benign(base)
    base_digs = base.final_digests()
    out: List[Finding] = []
    for cut in range(1, len(schedule)):
        pre = replay(model, schedule[:cut])
        preload = {n: r.ckpt for n, r in pre.runs.items()}
        resumed = Simulation(model, preload=preload)
        run_benign(resumed)
        digs = resumed.final_digests()
        for name, base_map in base_digs.items():
            for uri, dig in base_map.items():
                got = digs.get(name, {}).get(uri)
                if got is not None and got != dig:
                    out.append(finding(
                        "H124",
                        f"resume from prefix {cut} diverged on "
                        f"{name}/{uri}: {got} != {dig} from the "
                        f"uninterrupted run",
                        uri=f"{name}/{uri}"))
                    return out
    return out


def check_resume_digests(base_digests: Dict[str, Dict[str, str]],
                         resumed: Iterable[dict]) -> List[Finding]:
    """Corpus-artifact form of the H124 check: compare recorded resume
    outcomes (``{"prefix": int, "digests": {run: {uri: digest}}}``)
    against the uninterrupted run's digests."""
    out: List[Finding] = []
    for entry in resumed:
        cut = entry.get("prefix", -1)
        digs = entry.get("digests", {})
        for name, base_map in base_digests.items():
            for uri, dig in base_map.items():
                got = digs.get(name, {}).get(uri)
                if got is not None and got != dig:
                    out.append(finding(
                        "H124",
                        f"resume from prefix {cut} diverged on "
                        f"{name}/{uri}: {got} != {dig} from the "
                        f"uninterrupted run",
                        uri=f"{name}/{uri}"))
                    return out
    return out


# ============================================================= exploration

#: decision kinds a benign (default) scheduler takes; fault injection,
#: ghost completions and deferred installs stay schedule-only so a
#: hazard is attributable to the explicit decisions that caused it.
_BENIGN = ("dispatch", "complete", "drop")


def _benign(acts: Sequence[str]) -> List[str]:
    return [a for a in acts if a.split(":", 1)[0] in _BENIGN]


def run_benign(sim: Simulation, max_steps: int = 10000):
    """Finish a simulation with the deterministic default policy (first
    enabled benign decision)."""
    for _ in range(max_steps):
        acts = _benign(sim.enabled())
        if not acts:
            return
        sim.apply(acts[0])
    raise RuntimeError("benign policy did not terminate")


def replay(model: SimModel, schedule: Sequence[str],
           strict: bool = True) -> Simulation:
    """Rebuild the simulation state a schedule prefix leads to. With
    ``strict=False`` (advisory replay, used by the minimizer) decisions
    that are no longer enabled are skipped instead of raising."""
    sim = Simulation(model)
    for d in schedule:
        if d in sim.enabled():
            sim.apply(d)
        elif strict:
            raise ValueError(f"decision {d!r} not enabled at "
                             f"step {len(sim.schedule)}")
    return sim


@dataclass
class ExploreResult:
    schedules: int = 0                 # complete interleavings checked
    decisions: int = 0                 # total decisions executed
    deduped: int = 0                   # prefixes cut by visited-state dedup
    por_pruned: int = 0                # branches collapsed by POR
    truncated: bool = False            # stopped before exhausting the space
    hazard_count: int = 0              # traces with >=1 finding (uncapped)
    coverage: Set[tuple] = field(default_factory=set)  # distinct terminals
    #: first ``keep_hazards`` offending (schedule, findings) pairs
    hazards: List[Tuple[Schedule, List[Finding]]] = field(
        default_factory=list)

    @property
    def exhaustive(self) -> bool:
        return not self.truncated

    def hazard_rules(self) -> List[str]:
        return sorted({f.rule for _, fs in self.hazards for f in fs})


def _commuting_completions(sim: Simulation, acts: Sequence[str]) -> bool:
    """True when every enabled decision is a completion and all pairs
    commute: disjoint output URI sets within each namespace, no shared
    memo key, no memo owner with live waiters, no budget in play for
    the touched namespaces. Then every order reaches the same state and
    the same checker verdicts, so one canonical order suffices."""
    if len(acts) < 2 or any(not a.startswith("complete:") for a in acts):
        return False
    seen_uris: Set[str] = set()
    seen_keys: Set[str] = set()
    for a in acts:
        _, name, step = a.split(":")
        run = sim.runs[name]
        if run.tenant.budgets:
            return False
        task = sim.fabric.task(name, step)
        key = getattr(task, "memo_keyed", None)
        if key is not None:
            if key in seen_keys or key in sim.memo_inflight:
                return False
            seen_keys.add(key)
        for uri in run.steps[step].outputs:
            full = f"{name}/{uri}"
            if full in seen_uris:
                return False
            seen_uris.add(full)
    return True


def explore(model: SimModel, *, max_schedules: int = 20000,
            max_depth: int = 200, por: bool = True, dedup: bool = True,
            resume_check: bool = False, max_hazards: Optional[int] = None,
            keep_hazards: int = 50, metrics=None) -> ExploreResult:
    """Exhaustive DFS over the schedule space of ``model``.

    Visited-state dedup cuts prefixes that reach an already-explored
    state; partial-order reduction collapses commuting-completion
    branch points to one canonical order. Every terminal (and every
    dedup-cut prefix) trace runs through :func:`check_trace`; with
    ``resume_check`` each terminal schedule additionally runs the H124
    prefix-resume convergence check. ``max_hazards`` stops exploration
    early once that many offending traces have been seen (the usual
    bug-hunt mode wants the first one, then minimizes it).
    """
    res = ExploreResult()
    seen: Set[tuple] = set()

    def record(sim: Simulation, terminal: bool) -> bool:
        findings = check_trace(sim.trace())
        if terminal and resume_check:
            findings += check_resume(model, sim.schedule)
        if terminal:
            res.schedules += 1
            res.coverage.add(sim.state_key())
        if findings:
            res.hazard_count += 1
            if len(res.hazards) < keep_hazards:
                res.hazards.append((list(sim.schedule), findings))
            if metrics is not None:
                metrics.inc("emcheck.hazards_found", len(findings))
        return bool(findings)

    def dfs(prefix: Schedule) -> bool:
        """Returns False when a stop condition fired."""
        if res.schedules >= max_schedules or len(prefix) > max_depth:
            res.truncated = True
            return False
        sim = replay(model, prefix)
        res.decisions += len(prefix)
        if dedup:
            key = sim.state_key()
            if key in seen:
                # continuations were explored from the first visit, but
                # this prefix's *history* (event/install logs) is unique
                # to this path — check it before cutting
                res.deduped += 1
                record(sim, terminal=False)
                if (max_hazards is not None
                        and res.hazard_count >= max_hazards):
                    res.truncated = True
                    return False
                return True
            seen.add(key)
        acts = sim.enabled()
        if not acts:
            record(sim, terminal=True)
            if max_hazards is not None and res.hazard_count >= max_hazards:
                res.truncated = True
                return False
            return True
        if por and _commuting_completions(sim, acts):
            res.por_pruned += len(acts) - 1
            acts = acts[:1]
        for a in acts:
            if not dfs(prefix + [a]):
                return False
        return True

    dfs([])
    if metrics is not None:
        metrics.inc("emcheck.schedules_explored", res.schedules)
        metrics.inc("emcheck.states_deduped", res.deduped)
        metrics.inc("emcheck.por_pruned", res.por_pruned)
    return res


def sample(model: SimModel, *, schedules: int = 200, seed: int = 0,
           fault_rate: float = 0.25, max_depth: int = 2000,
           resume_check: bool = False, metrics=None) -> ExploreResult:
    """Seeded random schedule sampling for DAGs too large to exhaust.

    Each episode walks a fresh simulation to termination choosing
    uniformly among enabled decisions, except fault/ghost/install
    decisions which fire with probability ``fault_rate`` (so benign
    progress dominates but injections stay reachable). Identical
    (model, schedules, seed, fault_rate) arguments reproduce identical
    episodes.
    """
    rng = random.Random(seed)
    res = ExploreResult()
    res.truncated = True   # sampling never proves exhaustiveness
    for _ in range(schedules):
        sim = Simulation(model)
        for _ in range(max_depth):
            acts = sim.enabled()
            if not acts:
                break
            benign = _benign(acts)
            optional = [a for a in acts if a not in benign]
            if optional and (not benign or rng.random() < fault_rate):
                sim.apply(rng.choice(optional))
            else:
                sim.apply(rng.choice(benign))
        res.schedules += 1
        res.decisions += len(sim.schedule)
        res.coverage.add(sim.state_key())
        findings = check_trace(sim.trace())
        if resume_check and not findings:
            findings = check_resume(model, sim.schedule)
        if findings:
            res.hazards.append((list(sim.schedule), findings))
    if metrics is not None:
        metrics.inc("emcheck.schedules_explored", res.schedules)
        if res.hazards:
            metrics.inc("emcheck.hazards_found",
                        sum(len(fs) for _, fs in res.hazards))
    return res


# ============================================================ minimization

def _triggers(model: SimModel, schedule: Sequence[str],
              rules: Set[str], resume_check: bool) -> bool:
    sim = replay(model, schedule, strict=False)
    run_benign(sim)
    findings = check_trace(sim.trace())
    if resume_check:
        findings += check_resume(model, list(schedule))
    return bool({f.rule for f in findings} & rules)


def minimize(model: SimModel, schedule: Schedule,
             rules: Optional[Iterable[str]] = None,
             resume_check: bool = False) -> Schedule:
    """Delta-debug a hazard-triggering schedule to a 1-minimal decision
    list: no single decision (and no contiguous chunk, tried first at
    decreasing granularity) can be removed without losing the hazard.

    Replay during minimization is *advisory* — decisions no longer
    enabled after a removal are skipped, and the simulation is finished
    with the benign default policy — so candidate lists never have to
    be exactly feasible.
    """
    if rules is None:
        sim = replay(model, schedule, strict=False)
        run_benign(sim)
        found = check_trace(sim.trace())
        if resume_check:
            found += check_resume(model, list(schedule))
        rules = {f.rule for f in found}
    rules = set(rules)
    assert rules, "schedule does not trigger any hazard"
    cur = list(schedule)
    chunk = max(1, len(cur) // 2)
    while chunk >= 1:
        i = 0
        progressed = False
        while i < len(cur):
            cand = cur[:i] + cur[i + chunk:]
            if _triggers(model, cand, rules, resume_check):
                cur = cand
                progressed = True
            else:
                i += chunk
        if chunk == 1 and not progressed:
            break
        chunk = chunk // 2 if chunk > 1 else (1 if progressed else 0)
    # canonicalize: re-run advisory replay and keep only the decisions
    # that were actually applied, so the reproducer replays strictly
    sim = replay(model, cur, strict=False)
    applied = list(sim.schedule)
    if _triggers(model, applied, rules, resume_check):
        return applied
    return cur


# ========================================================== reproducer IO

def save_reproducer(path: str, model: SimModel, schedule: Schedule,
                    findings: Sequence[Finding], *,
                    minimized: bool = True, seed: Optional[int] = None):
    """Serialize a hazard reproducer. ``sort_keys`` + fixed separators
    keep the bytes identical across runs, so replay can be gated
    byte-for-byte in CI."""
    doc = {
        "emcheck_version": EMCHECK_VERSION,
        "model": {"name": model.name, "params": model.params,
                  "bugs": sorted(model.bugs)},
        "schedule": list(schedule),
        "hazards": sorted({f.rule for f in findings}),
        "minimized": bool(minimized),
    }
    if seed is not None:
        doc["seed"] = seed
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def load_reproducer(path: str) -> dict:
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("emcheck_version") != EMCHECK_VERSION:
        raise ValueError(f"unsupported reproducer version "
                         f"{doc.get('emcheck_version')!r}")
    return doc


def replay_reproducer(doc: dict,
                      model: Optional[SimModel] = None
                      ) -> Tuple[List[Finding], bool]:
    """Strictly replay a reproducer document. Returns the findings and
    whether the recorded hazard rules were re-triggered."""
    if model is None:
        ref = doc["model"]
        model = build_model(ref["name"], bugs=ref.get("bugs", ()),
                            **ref.get("params", {}))
    sim = replay(model, doc["schedule"], strict=True)
    run_benign(sim)
    findings = check_trace(sim.trace())
    want = set(doc.get("hazards", ()))
    got = {f.rule for f in findings}
    return findings, want <= got and bool(want)


# ============================================================ model library

def _wf_diamond() -> Workflow:
    wf = Workflow("diamond")
    wf.step("src", outputs=["x"], remotable=False)
    for i in range(1, 5):
        wf.step(f"mid{i}", inputs=["x"], outputs=[f"y{i}"], remotable=True)
    wf.step("sink", inputs=[f"y{i}" for i in range(1, 5)],
            outputs=["z"], remotable=False)
    return wf


def _wf_chain(n: int = 3, prefix: str = "s") -> Workflow:
    wf = Workflow(f"chain{n}")
    prev = None
    for i in range(n):
        wf.step(f"{prefix}{i}",
                inputs=[prev] if prev else [],
                outputs=[f"v{i}"], remotable=True)
        prev = f"v{i}"
    return wf


def _wf_wide(n: int = 8) -> Workflow:
    wf = Workflow(f"wide{n}")
    wf.step("fan", outputs=["seed"], remotable=True)
    for i in range(n):
        wf.step(f"w{i}", inputs=["seed"], outputs=[f"o{i}"],
                remotable=True)
    return wf


def model_diamond(*, bugs: Iterable[str] = ()) -> SimModel:
    """The canonical 6-step diamond: src -> mid1..mid4 -> sink, four
    remotable middles contending for two offload slots. Small enough
    to exhaust, rich enough to interleave dispatches and completions."""
    return SimModel([Tenant("A", _wf_diamond())], offload_slots=2,
                    local_slots=1, bugs=set(bugs), name="diamond",
                    params={})


def model_two_tenant(*, weight_a: float = 1.0, weight_b: float = 1.0,
                     width: int = 4,
                     bugs: Iterable[str] = ()) -> SimModel:
    """Two tenants sharing the offload lane — the fair-share /
    starvation scenario (H122 under the ``unfair`` flag)."""
    wa = _wf_wide(width)
    wb = _wf_wide(width)
    return SimModel([Tenant("A", wa, weight=weight_a),
                     Tenant("B", wb, weight=weight_b)],
                    offload_slots=1, local_slots=1,
                    starvation_window=4, bugs=set(bugs),
                    name="two_tenant",
                    params={"weight_a": weight_a, "weight_b": weight_b,
                            "width": width})


def model_memo_pair(*, bugs: Iterable[str] = ()) -> SimModel:
    """Two tenants running identical chains on identical inputs with
    memoization on — exactly one execution per key is legal (H121
    under ``memo_no_guard``)."""
    return SimModel(
        [Tenant("A", _wf_chain(2), init={"seed": "same"}),
         Tenant("B", _wf_chain(2), init={"seed": "same"})],
        offload_slots=2, local_slots=1, memoize=True,
        bugs=set(bugs), name="memo_pair", params={})


def model_budget(*, budget: int = 2,
                 bugs: Iterable[str] = ()) -> SimModel:
    """One tenant whose wide outputs exceed a cloud residency budget —
    eviction must keep residency under the ceiling (H123 under
    ``no_evict``)."""
    return SimModel(
        [Tenant("A", _wf_wide(4), budgets={"cloud": budget})],
        offload_slots=2, local_slots=1, bugs=set(bugs),
        name="budget", params={"budget": budget})


def model_resubmit(*, bugs: Iterable[str] = ()) -> SimModel:
    """A warm-resubmit tenant: the run completes, its namespace drops
    (epoch bump), and it runs again while deferred write-backs from the
    first pass may still be pending (H110/H120 under
    ``stale_install``)."""
    return SimModel([Tenant("A", _wf_chain(2), resubmit=True)],
                    offload_slots=1, local_slots=1, bugs=set(bugs),
                    name="resubmit", params={})


def model_ckpt_chain(*, bugs: Iterable[str] = ()) -> SimModel:
    """A chain with a non-idempotent (accumulating) middle step — the
    checkpoint/resume convergence scenario (H124 under
    ``ckpt_lost_step``)."""
    wf = Workflow("ckpt")
    wf.step("a", outputs=["x"], remotable=True)
    wf.step("acc", inputs=["x"], outputs=["x"], remotable=True)
    wf.step("b", inputs=["x"], outputs=["y"], remotable=True)
    return SimModel([Tenant("A", wf)], offload_slots=1, local_slots=1,
                    accum_steps={"acc"}, bugs=set(bugs),
                    name="ckpt_chain", params={})


def model_frontdoor(*, window: int = 4,
                    bugs: Iterable[str] = ()) -> SimModel:
    """The serving front door: two parked interactive tenants draining
    oldest-deadline-first through one admitted-run slot while a batch
    tenant's chain holds the lanes, with one spot preemption available
    (H125 under ``parked_starved``, H126 under ``preempt_lost_step``)."""
    return SimModel(
        [Tenant("A", _wf_chain(1, prefix="a"), park=True, deadline=1.0),
         Tenant("B", _wf_chain(1, prefix="b"), park=True, deadline=2.0),
         Tenant("C", _wf_chain(3, prefix="bat"))],
        offload_slots=2, local_slots=1, admit_capacity=1,
        max_preempts=1, starvation_window=window, bugs=set(bugs),
        name="frontdoor", params={"window": window})


#: name -> builder; every builder accepts ``bugs=`` plus its own params,
#: and stamps ``name``/``params`` so reproducers can rebuild it.
MODELS: Dict[str, Callable[..., SimModel]] = {
    "diamond": model_diamond,
    "two_tenant": model_two_tenant,
    "memo_pair": model_memo_pair,
    "budget": model_budget,
    "resubmit": model_resubmit,
    "ckpt_chain": model_ckpt_chain,
    "frontdoor": model_frontdoor,
}


def build_model(name: str, *, bugs: Iterable[str] = (),
                **params) -> SimModel:
    if name not in MODELS:
        raise KeyError(f"unknown model {name!r} "
                       f"(have: {', '.join(sorted(MODELS))})")
    return MODELS[name](bugs=bugs, **params)
