"""Emerald core: the paper's contribution as a composable JAX runtime."""
from repro.core.workflow import Step, Workflow, WorkflowError, remotable  # noqa: F401
from repro.core.partitioner import (MigrationPoint, PartitionError,  # noqa: F401
                                    PartitionedWorkflow, partition)
from repro.core.mdss import (MDSS, MDSSTransferError, NamespacedMDSS,  # noqa: F401
                             Transport, namespace_of, nbytes_of)
from repro.core.migration import MigrationManager, StepFailure  # noqa: F401
from repro.core.runtime import (AdmissionRefused, EmeraldRuntime,  # noqa: F401
                                Event, RunCancelled, RunHandle,
                                RuntimeClosed, WorkflowFailure)
from repro.core.executor import EmeraldExecutor  # noqa: F401
from repro.core.cost_model import CostModel, StepStats  # noqa: F401
from repro.core.scheduler import (AnnotatePolicy, CostModelPolicy,  # noqa: F401
                                  FairShare, LocalityPolicy, NeverPolicy,
                                  PlacementDecision, critical_path_lengths,
                                  make_policy)
from repro.core.tiers import Tier, default_tiers  # noqa: F401
