"""Roofline cost model for offload decisions (beyond-paper feature).

The paper decides offloading purely by developer annotation and lists
"offloading decisions" as an open issue. This model estimates, per step and
tier:

    t_exec(step, tier)  = max(flops / peak_flops, bytes / hbm_bw)
    t_move(n, src, dst) = latency + n / bw(src, dst)

and recommends offloading a remotable step iff

    t_exec(local) > t_move(stale_in) + t_exec(cloud) + t_move(results_back)

where ``stale_in`` counts ONLY input bytes whose latest version is not
already resident on the target tier — exactly the saving MDSS exists to
create (paper §3.4: task-code-only transfer when data is fresh).

Step FLOP/byte statistics come from three sources, best-first:
  1. measured EMA of past executions on a tier (runtime feedback),
  2. XLA ``cost_analysis`` captured when the migration manager compiles the
     step for a tier,
  3. developer hints on the Step (``flops_hint`` / ``bytes_hint``).

Link bandwidth likewise prefers measurement over constants: the offload
fabric's RPCTransport reports every real transfer via
``observe_bandwidth`` and ``transfer_time`` uses that EMA when present,
falling back to the tier's static link table otherwise. Samples are
keyed **per direction** — large fabric ships report the request and
reply legs separately (worker-measured receive time vs the remainder),
so an asymmetric up/down WAN link shows up as different
``measured_bw[(local, cloud)]`` and ``measured_bw[(cloud, local)]``
entries and ``placement_cost`` charges each stale input at the
bandwidth of the link it would actually cross, in the direction it
would cross it.

Staleness is content-aware: ``MDSS.staleness`` counts only chunks not
already resident at the destination tier (dedup by digest), so
``placement_cost`` charges only *non-resident, non-duplicate* bytes —
staging a value whose content another namespace already holds there is
modeled (and shipped) as free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.tiers import Tier


@dataclass
class StepStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    measured_s: Dict[str, float] = field(default_factory=dict)  # tier -> EMA

    def observe(self, tier: str, seconds: float, alpha: float = 0.5):
        prev = self.measured_s.get(tier)
        self.measured_s[tier] = seconds if prev is None else (
            alpha * seconds + (1 - alpha) * prev)


class CostModel:
    def __init__(self, tiers: Dict[str, Tier]):
        self.tiers = tiers
        self.stats: Dict[str, StepStats] = {}
        # observed wire bandwidth per (src, dst), EMA bytes/s — fed by the
        # fabric's RPCTransport; overrides the static link constants
        self.measured_bw: Dict[Tuple[str, str], float] = {}

    def stats_for(self, step_name: str) -> StepStats:
        return self.stats.setdefault(step_name, StepStats())

    def observe_bandwidth(self, src: str, dst: str, nbytes: float,
                          seconds: float, alpha: float = 0.5):
        """Record a real transfer (``nbytes`` moved in ``seconds``)."""
        if seconds <= 0 or nbytes <= 0:
            return
        bw = nbytes / seconds
        prev = self.measured_bw.get((src, dst))
        self.measured_bw[(src, dst)] = bw if prev is None else (
            alpha * bw + (1 - alpha) * prev)

    # ------------------------------------------------------------- estimates
    def exec_time(self, step, tier_name: str) -> float:
        tier = self.tiers[tier_name]
        st = self.stats_for(step.name)
        if tier_name in st.measured_s:
            return st.measured_s[tier_name]
        flops = st.flops or step.flops_hint
        byts = st.bytes_accessed or step.bytes_hint
        if not flops and not byts:
            # unmeasured fan-out shard: 1/N of whatever the un-expanded
            # parent step has measured or estimated (a prior non-fanned
            # run, or per-shard stats of a different width) — keeps cpl
            # priorities and fair-share charges meaningful on the first
            # sharded run
            if getattr(step, "fanout_role", "") == "shard" \
                    and step.fanout_parent and step.fanout_shards > 0:
                pst = self.stats.get(step.fanout_parent)
                if pst is not None:
                    parent_est = pst.measured_s.get(tier_name) or max(
                        pst.flops / tier.peak_flops,
                        pst.bytes_accessed / tier.hbm_bw)
                    if parent_est > 0:
                        return parent_est / step.fanout_shards
            return 0.0  # unknown -> neutral
        return max(flops / tier.peak_flops, byts / tier.hbm_bw)

    def transfer_time(self, nbytes: float, src: str, dst: str) -> float:
        if src == dst or nbytes == 0:
            return 0.0
        tier = self.tiers[src]
        bw = self.measured_bw.get((src, dst)) or tier.bw_to(dst)
        return tier.link_latency_s + nbytes / bw

    def placement_cost(self, step, tier_name: str, staleness=()) -> float:
        """Locality-aware per-tier score: ``est_exec(tier)`` plus the
        modeled transfer of every input byte NOT already resident there.
        ``staleness`` is ``MDSS.staleness`` output — ``(uri, src_tier,
        nbytes)`` triples — so each stale input is charged at the
        bandwidth of the link it would actually cross."""
        t = self.exec_time(step, tier_name)
        for _, src, n in staleness:
            if src != tier_name:
                t += self.transfer_time(n, src, tier_name)
        return t

    def offload_benefit(self, step, *, stale_in_bytes: float,
                        result_bytes: float, src: str = "local",
                        dst: str = "cloud") -> float:
        """Seconds saved by offloading (negative -> keep local)."""
        t_local = self.exec_time(step, src)
        t_remote = (self.transfer_time(stale_in_bytes, src, dst)
                    + self.exec_time(step, dst)
                    + self.transfer_time(result_bytes, dst, src))
        return t_local - t_remote

    def should_offload(self, step, **kw) -> bool:
        return self.offload_benefit(step, **kw) > 0.0
