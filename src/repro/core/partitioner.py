"""Static workflow partitioner (paper §3.1–§3.2).

Given an annotated workflow, validates the three legal-partition properties
and emits a *partitioned workflow*: the same step sequence with a
``MigrationPoint`` (the paper's "temporary step") inserted before every
remotable step. At run time the migration point suspends execution, hands
the step to the migration manager, and resumes on re-integration.

Properties enforced (paper §3.2):
  P1 — steps that access special local hardware cannot be offloaded.
  P2 — a remotable step's inputs/outputs must be variables declared at the
       same nesting level as the step (visible to siblings), so data can be
       re-integrated.
  P3 — no nested offloading: a remotable step may not contain remotable
       descendants; suspend/resume strictly alternate (guaranteed at step
       granularity by construction, validated for nesting).

Beyond-paper: **data-parallel fan-out expansion**. A step annotated with
:class:`~repro.core.workflow.Fanout` is rewritten here — before the
legality checks, so every downstream consumer (verifier, scheduler,
executor, sanitizer) sees only ordinary steps — into::

    big                      big.scatter     (partition_fn -> P#0..P#N-1)
    inputs=(P, cfg)    =>    big#k           (fn over P#k + broadcast cfg,
    outputs=(out,)                            k = 0..N-1, writes out#k)
    fanout=Fanout(N)         big.gather      (combine_fn -> out)

Each shard value ``uri#k`` is its own content-addressed MDSS entry, so
dedup, locality scoring, and per-shard memoization fall out of the
existing machinery; each shard step is an independent ready task for the
runtime's lanes and the fabric's requeue-on-worker-loss. Shard steps
keep the original fn (stable code fingerprint — the per-shard memo key
survives resubmission) and remap staged kwargs via ``Step.arg_names`` so
``fn(P=...)`` still receives its declared parameter names.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.mdss import shard_uri
from repro.core.workflow import (Fanout, Step, Variable, Workflow,
                                 WorkflowError)


class PartitionError(WorkflowError):
    def __init__(self, prop: int, msg: str):
        super().__init__(f"Property {prop} violated: {msg}")
        self.prop = prop


@dataclass
class MigrationPoint:
    """The 'temporary step' inserted before a remotable step."""
    target: str                 # name of the remotable step it guards

    @property
    def name(self) -> str:
        return f"__migrate__{self.target}"


@dataclass
class PartitionedWorkflow:
    workflow: Workflow
    sequence: List[object] = field(default_factory=list)  # Step | MigrationPoint

    @property
    def migration_points(self) -> List[MigrationPoint]:
        return [s for s in self.sequence if isinstance(s, MigrationPoint)]

    @property
    def remotable_steps(self) -> List[Step]:
        return [s for s in self.sequence
                if isinstance(s, Step) and s.remotable]


def _check_p1(wf: Workflow, s: Step):
    if s.remotable and s.requires_local_hardware:
        raise PartitionError(
            1, f"step {s.name} is remotable but requires local hardware")


def _check_p2(wf: Workflow, s: Step):
    if not s.remotable:
        return
    level = s.scope(wf)
    for v in s.inputs + s.outputs:
        var = wf.variables.get(v)
        if var is None:
            raise PartitionError(2, f"step {s.name}: variable {v} undeclared")
        if var.scope != level:
            raise PartitionError(
                2, f"step {s.name} (level {level}) uses variable {v} "
                   f"declared at level {var.scope}; inputs/outputs must be "
                   f"defined at the same level as the step")


def _check_p3(wf: Workflow, s: Step):
    if not s.remotable:
        return
    for d in wf.descendants(s.name):
        if d.remotable:
            raise PartitionError(
                3, f"remotable step {s.name} contains remotable descendant "
                   f"{d.name} (nested offloading)")


def partition(wf: Workflow) -> PartitionedWorkflow:
    """Validate legality and insert migration points (paper Fig 5/6).

    Fan-out steps are expanded first (:func:`expand_fanouts`), so the
    legality checks — and everything downstream of the returned
    ``PartitionedWorkflow`` — run over the scatter/shard/gather form.
    """
    wf = expand_fanouts(wf)
    wf.validate_vars()
    for s in wf.steps.values():
        _check_p1(wf, s)
        _check_p2(wf, s)
        _check_p3(wf, s)
    seq: List[object] = []
    for s in wf.toplevel():
        if s.remotable:
            seq.append(MigrationPoint(target=s.name))
        seq.append(s)
    return PartitionedWorkflow(workflow=wf, sequence=seq)


# ---------------------------------------------------------------- fan-out
def split_rows(value, n: int):
    """Default partition_fn: split along axis 0 into ``n`` parts."""
    return np.array_split(np.asarray(value), n)


def concat_rows(parts):
    """Default combine_fn: reassemble row-split parts along axis 0."""
    return np.concatenate([np.asarray(p) for p in parts], axis=0)


def _make_scatter_fn(scattered: Tuple[str, ...], n: int, partition_fn):
    def scatter(**kw):
        out = {}
        for uri in scattered:
            parts = list(partition_fn(kw[uri], n))
            if len(parts) != n:
                raise WorkflowError(
                    f"partition_fn returned {len(parts)} parts for {uri}, "
                    f"expected {n}")
            for k, part in enumerate(parts):
                out[shard_uri(uri, k)] = part
        return out
    return scatter


def _make_gather_fn(outputs: Tuple[str, ...], n: int, combine_fn):
    def gather(**kw):
        return {o: combine_fn([kw[shard_uri(o, k)] for k in range(n)])
                for o in outputs}
    return gather


def _append_step(wf: Workflow, s: Step):
    """Install an already-built Step (no re-validation — the source
    workflow's builders ran once; expansion only relocates/clones)."""
    wf.steps[s.name] = s
    wf.order.append(s.name)
    for out in s.outputs:
        if out not in wf.variables:
            wf.variables[out] = Variable(out, s.scope(wf),
                                         defined_at=s.defined_at,
                                         implicit=True)


def expand_fanouts(wf: Workflow) -> Workflow:
    """Rewrite every legally-fanned-out step into scatter + N shards +
    gather (see module docstring). Steps whose fan-out spec is illegal
    (``shards < 1``, scatter of a non-input) are left UNEXPANDED so the
    verifier's W060 names the defect at admission instead of this pass
    half-building a broken DAG. Returns ``wf`` itself when nothing
    expands; never mutates the input workflow.
    """
    todo = [s for s in wf.steps.values()
            if s.fanout is not None and not s.fanout_role
            and _fanout_spec_errors(s) == ()]
    if not todo:
        return wf
    for s in todo:
        if s.parent is not None or wf.children_of(s.name):
            raise WorkflowError(
                f"fan-out step {s.name} is nested (parent/children); "
                "fan-out is defined for top-level leaf steps only")
    expanding = {s.name for s in todo}
    out = Workflow(wf.name)
    for v in wf.variables.values():
        if not v.implicit:
            out.variables[v.name] = v
    for name in wf.order:
        s = wf.steps[name]
        if s.name not in expanding:
            _append_step(out, s)
            continue
        spec = s.fanout
        n = spec.shards
        scattered = tuple(spec.scatter) or s.inputs[:1]
        part_fn = spec.partition_fn or split_rows
        comb_fn = spec.combine_fn or concat_rows
        _append_step(out, Step(
            name=f"{s.name}.scatter",
            fn=_make_scatter_fn(scattered, n, part_fn),
            inputs=scattered,
            outputs=tuple(shard_uri(u, k)
                          for u in scattered for k in range(n)),
            jax_step=False, memoizable=False, retries=s.retries,
            fanout=spec, fanout_role="scatter", fanout_parent=s.name,
            fanout_shards=n, defined_at=s.defined_at))
        for k in range(n):
            _append_step(out, replace(
                s,
                name=f"{s.name}#{k}",
                inputs=tuple(shard_uri(u, k) if u in scattered else u
                             for u in s.inputs),
                arg_names=s.inputs,
                outputs=tuple(shard_uri(o, k) for o in s.outputs),
                out_names=s.outputs,
                flops_hint=s.flops_hint / n,
                bytes_hint=s.bytes_hint / n,
                fanout=None, fanout_role="shard", fanout_parent=s.name,
                shard_index=k, fanout_shards=n))
        _append_step(out, Step(
            name=f"{s.name}.gather",
            fn=_make_gather_fn(s.outputs, n, comb_fn),
            inputs=tuple(shard_uri(o, k)
                         for o in s.outputs for k in range(n)),
            outputs=s.outputs,
            jax_step=False, memoizable=False, retries=s.retries,
            fanout=spec, fanout_role="gather", fanout_parent=s.name,
            fanout_shards=n, defined_at=s.defined_at))
    return out


def _fanout_spec_errors(s: Step) -> Tuple[str, ...]:
    """Spec defects that make expansion impossible (the verifier's W060
    wording mirrors these)."""
    spec = s.fanout
    errs = []
    if spec.shards < 1:
        errs.append(f"declares {spec.shards} shards; a fan-out needs at "
                    "least one")
    unknown = [u for u in spec.scatter if u not in s.inputs]
    if unknown:
        errs.append(f"scatters {', '.join(unknown)}, not among the step's "
                    "declared inputs")
    if not spec.scatter and not s.inputs:
        errs.append("has no inputs to scatter over")
    return tuple(errs)
