"""Static workflow partitioner (paper §3.1–§3.2).

Given an annotated workflow, validates the three legal-partition properties
and emits a *partitioned workflow*: the same step sequence with a
``MigrationPoint`` (the paper's "temporary step") inserted before every
remotable step. At run time the migration point suspends execution, hands
the step to the migration manager, and resumes on re-integration.

Properties enforced (paper §3.2):
  P1 — steps that access special local hardware cannot be offloaded.
  P2 — a remotable step's inputs/outputs must be variables declared at the
       same nesting level as the step (visible to siblings), so data can be
       re-integrated.
  P3 — no nested offloading: a remotable step may not contain remotable
       descendants; suspend/resume strictly alternate (guaranteed at step
       granularity by construction, validated for nesting).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.workflow import Step, Workflow, WorkflowError


class PartitionError(WorkflowError):
    def __init__(self, prop: int, msg: str):
        super().__init__(f"Property {prop} violated: {msg}")
        self.prop = prop


@dataclass
class MigrationPoint:
    """The 'temporary step' inserted before a remotable step."""
    target: str                 # name of the remotable step it guards

    @property
    def name(self) -> str:
        return f"__migrate__{self.target}"


@dataclass
class PartitionedWorkflow:
    workflow: Workflow
    sequence: List[object] = field(default_factory=list)  # Step | MigrationPoint

    @property
    def migration_points(self) -> List[MigrationPoint]:
        return [s for s in self.sequence if isinstance(s, MigrationPoint)]

    @property
    def remotable_steps(self) -> List[Step]:
        return [s for s in self.sequence
                if isinstance(s, Step) and s.remotable]


def _check_p1(wf: Workflow, s: Step):
    if s.remotable and s.requires_local_hardware:
        raise PartitionError(
            1, f"step {s.name} is remotable but requires local hardware")


def _check_p2(wf: Workflow, s: Step):
    if not s.remotable:
        return
    level = s.scope(wf)
    for v in s.inputs + s.outputs:
        var = wf.variables.get(v)
        if var is None:
            raise PartitionError(2, f"step {s.name}: variable {v} undeclared")
        if var.scope != level:
            raise PartitionError(
                2, f"step {s.name} (level {level}) uses variable {v} "
                   f"declared at level {var.scope}; inputs/outputs must be "
                   f"defined at the same level as the step")


def _check_p3(wf: Workflow, s: Step):
    if not s.remotable:
        return
    for d in wf.descendants(s.name):
        if d.remotable:
            raise PartitionError(
                3, f"remotable step {s.name} contains remotable descendant "
                   f"{d.name} (nested offloading)")


def partition(wf: Workflow) -> PartitionedWorkflow:
    """Validate legality and insert migration points (paper Fig 5/6)."""
    wf.validate_vars()
    for s in wf.steps.values():
        _check_p1(wf, s)
        _check_p2(wf, s)
        _check_p3(wf, s)
    seq: List[object] = []
    for s in wf.toplevel():
        if s.remotable:
            seq.append(MigrationPoint(target=s.name))
        seq.append(s)
    return PartitionedWorkflow(workflow=wf, sequence=seq)
