"""MDSS — Multi-level Data Storage Service (paper §3.4).

URI-keyed, versioned, multi-tier data store:

  * writes land on the *writing* tier first (paper: "data is always
    accessible to the application", offline-capable) and propagate lazily,
  * ``synchronize`` reconciles tiers **last-writer-wins** (paper default),
  * ``ensure(uri, tier)`` is the offload fast-path: if the target tier
    already holds the latest version nothing moves (task-code-only
    offloading); otherwise only the stale entries transfer,
  * ``prefetch(uris, tier)`` is the pipelined variant: the same ensure on
    a background thread, so the transfer overlaps upstream compute — the
    executor issues it for a dispatched step's likely successors,
  * transfers run **outside** the store lock and install under a version
    guard (hazard check): a copy shipped for version *v* never overwrites
    a copy of a newer version, and a write that lands mid-transfer simply
    re-ships — concurrent readers/writers never block on the wire,
  * ``put(..., expect_version=)`` is a write fence: the put is refused
    (returns ``None``) when the entry has moved past the expected
    version — how a speculation loser is kept from clobbering newer data,
  * every cross-tier movement is accounted (bytes, modeled seconds), per
    namespace — the MDSS benchmark and the §Perf analysis read these
    counters,
  * **namespaces** (multi-tenant runtime): a URI ``ns/leaf`` belongs to
    namespace ``ns``. ``namespaced(ns, shared=...)`` returns a per-run
    view that writes under ``ns/`` but lets reads fall through to a
    common ``shared/`` namespace, so N concurrent workflows get isolated
    outputs while warm cross-run data (params, observations) is stored —
    and stays cloud-resident — exactly once. ``drop_namespace`` is run
    teardown: it frees every replica the run published,
  * **content addressing** (chunk dedup): every replica install registers
    its value's chunk digests (``wire.manifest_of``) in a per-tier chunk
    index carrying the same incremental residency accounting as the
    byte counters; ``staleness``/``stale_bytes`` then charge only chunks
    NOT already resident on the destination tier — a second tenant
    staging content-identical inputs (same params under another
    namespace, a re-upload after eviction) owes **zero** transfer bytes,
    and the locality scorer (``CostModel.placement_cost``) sees exactly
    that. A transport exposing ``transfer_ex`` (the fabric's
    RPCTransport) ships metadata only for fully-resident values;
    ``content_digest(uri)`` is the whole-value identity the runtime's
    cross-run step memoization keys on,
  * **residency budgets** (per namespace, per tier): resident bytes are
    accounted incrementally on every copy install/replace/delete, and
    ``set_namespace_budget(ns, tier, max_bytes)`` bounds a namespace's
    footprint on a tier. Crossing the budget schedules background LRU
    **eviction** of the coldest entries: the latest version is written
    back to the local tier first (plain replica movement through the
    hazard-checked transfer path — never a versioned put, so it can
    neither bump a fence epoch nor resurrect a dropped namespace), then
    the over-budget replica is deleted. ``capacity_bytes`` is the
    store-wide ceiling the runtime's admission control checks against,
    and ``eviction_bytes`` churn is the autoscaler's thrash signal.

Values are arbitrary pytrees of arrays / scalars. A ``Transport`` performs
the actual movement; the default in-process transport re-places arrays on
the destination tier's mesh (``jax.device_put``) when it has one.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.cloud.wire import manifest_of


class MDSSTransferError(RuntimeError):
    """A cross-tier transfer could not complete (e.g. a peer in-flight
    copy never landed). Maps to ``StepFailure`` at staging time so the
    executor's retry / tier-fallback path owns recovery."""


def namespace_of(uri: str) -> str:
    """Namespace component of a URI ('' for un-namespaced URIs)."""
    return uri.split("/", 1)[0] if "/" in uri else ""


def shard_uri(uri: str, k: int) -> str:
    """URI of shard ``k`` of a fanned-out value.

    A shard is an ordinary store entry — its own versions, manifest,
    chunk-index rows, and ``content_digest`` — so the locality scorer,
    wire dedup, and step memoization treat every shard independently:
    mutating one shard's rows re-digests (and re-ships, re-executes)
    only that shard. ``#`` never appears in namespace separators, so a
    namespaced view resolves ``ns/uri#k`` like any other leaf.
    """
    return f"{uri}#{k}"


def shard_uris(uri: str, n: int) -> List[str]:
    """All ``n`` shard URIs of ``uri``, in shard order."""
    return [shard_uri(uri, k) for k in range(n)]


def nbytes_of(value) -> int:
    total = 0
    for leaf in jax.tree.leaves(value):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif isinstance(leaf, (int, float, bool)):
            total += 8
        elif isinstance(leaf, (str, bytes)):
            total += len(leaf)
    return total


class Transport:
    """Moves a value between tiers; override for a real RPC fabric."""

    def __init__(self, tiers=None):
        self.tiers = tiers or {}

    def transfer(self, value, src: str, dst: str):
        tier = self.tiers.get(dst)
        if tier is not None and tier.mesh is not None:
            return value  # placement deferred to the executing jit's shardings
        return value


@dataclass
class _Entry:
    version: int = 0
    writer: str = ""
    copies: Dict[str, Tuple[int, Any]] = field(default_factory=dict)


class MDSS:
    def __init__(self, tiers, transport: Optional[Transport] = None,
                 cost_model=None, capacity_bytes: Optional[int] = None,
                 chunk_dedup: bool = True):
        self.tiers = tiers
        self.transport = transport or Transport(tiers)
        self.cost_model = cost_model
        # content-addressed residency: replica installs register chunk
        # digests per tier, and transfer obligations charge only chunks
        # not already resident at the destination (values are treated as
        # immutable once stored — mutating a stored array in place would
        # stale its cached manifest)
        self.chunk_dedup = chunk_dedup
        # store-wide resident-byte ceiling; the runtime's admission
        # control refuses new submissions when residency nears it
        self.capacity_bytes = capacity_bytes
        self._entries: Dict[str, _Entry] = {}
        # bumped by drop_namespace: fence tokens carry the epoch, so a
        # draining step's post-drop write-back is refused instead of
        # resurrecting the namespace (while a deliberate reuse of the
        # name by a NEW submission snapshots the new epoch and proceeds)
        self._ns_epoch: Dict[str, int] = {}
        self._lock = threading.RLock()
        # one wire flight per (uri, tier): racing ensures wait, not re-ship
        self._inflight: Dict[Tuple[str, str], threading.Event] = {}
        # best-effort prefetch backpressure: beyond this many concurrent
        # prefetch threads, new requests are dropped (ensure still staged
        # synchronously at execution time, so only overlap is lost)
        self._prefetch_slots = threading.BoundedSemaphore(4)
        # a peer in-flight transfer that never lands must not hang the
        # waiter forever: after max_transfer_waits expired waits the
        # ensure raises MDSSTransferError instead of retrying
        self.transfer_wait_s: float = 300.0
        self.max_transfer_waits: int = 3
        # accounting (sync_events is a bounded recent-transfer log — the
        # cumulative counters below carry the totals; a long-lived
        # multi-tenant store must not grow a per-transfer list forever)
        self.sync_events_cap = 4096
        self.bytes_moved: Dict[Tuple[str, str], int] = {}
        self.ns_bytes_moved: Dict[str, int] = {}     # per-namespace wire bytes
        self.modeled_seconds: float = 0.0
        self.sync_events: list = []
        self.prefetch_ops: int = 0
        self.prefetch_bytes: int = 0
        self.fenced_puts: int = 0
        # residency budgets + incremental resident-byte accounting: every
        # copies mutation goes through _set_copy/_del_copy so these stay
        # in lockstep with the store without full scans
        self._budgets: Dict[Tuple[str, str], int] = {}     # (ns, tier) -> max
        self._ns_tier_bytes: Dict[Tuple[str, str], int] = {}
        self._use_tick = itertools.count(1)                # LRU clock
        self._last_used: Dict[Tuple[str, str], int] = {}   # (uri, tier)
        self._evict_pending: set = set()   # (ns, tier) enforcement scheduled
        self.evictions: int = 0
        self.eviction_bytes: int = 0       # cumulative churn (autoscaler feed)
        # rows: (uri, tier, bytes, version, ns_epoch, t) — bounded below
        self.eviction_events: list = []    # bounded like sync_events
        # replica-install log consumed by the hazard sanitizer
        # (repro.analysis.sanitizer): rows (uri, tier, version, ns_epoch, t).
        # installs_total keeps the true count so a consumer can tell when
        # the bounded list has been trimmed and skip install-order checks.
        self.install_events: list = []
        self.installs_total: int = 0
        # per-tier chunk index: digest -> [refcount, length]. Kept in
        # lockstep with ``copies`` by _set_copy/_del_copy, same as the
        # residency byte counters — chunks leave the index exactly when
        # the last replica referencing them leaves the tier (eviction,
        # drop_namespace, overwrite)
        self._tier_chunks: Dict[str, Dict[bytes, list]] = {}
        self._manifest_cache: "OrderedDict[Tuple[str, int], tuple]" = \
            OrderedDict()
        self.manifest_cache_cap = 4096
        self.dedup_bytes_elided: int = 0   # transfer bytes chunk-dedup saved

    # ------------------------------------------------------------------ api
    def put(self, uri: str, value, tier: str = "local",
            expect_version: Optional[int] = None, _manifest=None):
        """New version written on ``tier`` (local-first semantics).

        With ``expect_version`` the put is a fenced write: it succeeds only
        if the entry is still at that version (compare-and-bump under the
        store lock). A stale writer — e.g. a speculation loser finishing
        after the winner already published — gets ``None`` back and the
        entry is untouched. ``_manifest`` lets batch callers pre-hash the
        value's chunk manifest outside the store lock.
        """
        if _manifest is None and self.chunk_dedup:
            # hash before taking the lock (re-entrant callers that
            # already hold it pay under the lock, same as before)
            _manifest = manifest_of(value)
        with self._lock:
            e = self._entries.setdefault(uri, _Entry())
            if expect_version is not None and e.version != expect_version:
                self.fenced_puts += 1
                return None
            e.version += 1
            e.writer = tier
            if _manifest is not None:
                self._cache_manifest((uri, e.version), _manifest)
            self._set_copy(uri, e, tier, e.version, value)
            return e.version

    def _premanifests(self, values: Dict[str, Any]) -> Dict[str, tuple]:
        """Hash a batch's manifests with NO lock held (for put_many)."""
        if not self.chunk_dedup:
            return {}
        return {uri: manifest_of(val) for uri, val in values.items()}

    def put_many(self, values: Dict[str, Any], tier: str = "local",
                 expect_versions: Optional[Dict[str, int]] = None):
        """Atomically publish several URIs (one lock hold).

        With ``expect_versions`` the whole batch is fenced **all-or-
        nothing**: if any entry moved past its expected version, nothing
        is written and ``None`` is returned — two speculation twins can
        never interleave a mixed set of a step's outputs. An absent entry
        counts as version 0: expecting a nonzero version of a URI that
        (no longer) exists is a stale expectation and fences the batch —
        e.g. the entry was dropped with its namespace mid-execution.
        """
        if expect_versions is not None:
            # cheap pre-check before paying the batch hash: a fenced
            # publish (speculation loser) is a designed-common event and
            # must not burn SHA-256 over outputs it will then discard.
            # The authoritative check re-runs under the same lock hold
            # as the writes.
            with self._lock:
                if self._fence_stale(values, expect_versions):
                    self.fenced_puts += 1
                    return None
        pre = self._premanifests(values)
        with self._lock:
            if expect_versions is not None \
                    and self._fence_stale(values, expect_versions):
                self.fenced_puts += 1
                return None
            return {uri: self.put(uri, val, tier, _manifest=pre.get(uri))
                    for uri, val in values.items()}

    def _fence_stale(self, values, expect_versions) -> bool:
        """Lock held: True if any entry moved past its expected version."""
        for uri in values:
            e = self._entries.get(uri)
            cur = 0 if e is None else e.version
            if cur != expect_versions.get(uri, 0):
                return True
        return False

    def version(self, uri: str) -> int:
        e = self._entries.get(uri)
        return 0 if e is None else e.version

    def peek_latest(self, uri: str):
        """(value, version) of the freshest replica, wherever it lives —
        a lock-held reference read, no transfer, no accounting. For
        observers (checkpointing) that need a consistent snapshot without
        paying or modeling data movement."""
        with self._lock:
            e = self._entries.get(uri)
            if e is None:
                return None, 0
            src = self._freshest_tier(e)
            if src is None:
                return None, 0
            return e.copies[src][1], e.version

    def has_latest(self, uri: str, tier: str) -> bool:
        with self._lock:
            e = self._entries.get(uri)
            if e is None:
                return False
            got = e.copies.get(tier)
            return got is not None and got[0] == e.version

    def stale_bytes(self, uris, tier: str) -> int:
        """Bytes that WOULD move to make ``tier`` current for ``uris``."""
        return sum(n for _, _, n in self.staleness(uris, tier))

    def staleness(self, uris, tier: str) -> List[Tuple[str, str, int]]:
        """Per-URI transfer obligation of placing a reader on ``tier``:
        ``(uri, freshest_src_tier, nbytes)`` for every entry whose latest
        version is NOT already resident there. The locality scheduler
        turns this into modeled transfer seconds per candidate tier.

        With chunk dedup, ``nbytes`` counts only the chunks the
        destination tier does not already hold under ANY entry — staging
        content-identical data (another tenant's copy of the same
        params, a re-upload after eviction) owes nothing, which is
        exactly what ``CostModel.placement_cost`` should charge.
        """
        uris = list(uris)
        self._warm_manifests(uris)          # hash misses outside the lock
        out: List[Tuple[str, str, int]] = []
        with self._lock:
            for uri in uris:
                e = self._entries.get(uri)
                if e is None or self.has_latest(uri, tier):
                    continue
                src = self._freshest_tier(e)
                if src is None:
                    continue
                version, value = e.copies[src]
                if self.chunk_dedup:
                    chunks = self._manifest_for(uri, version, value)[1]
                    n = self._missing_chunk_bytes(tier, chunks)
                else:
                    n = nbytes_of(value)
                out.append((uri, src, n))
        return out

    def get(self, uri: str, tier: str = "local"):
        """Value at ``tier``, syncing from the freshest tier if stale."""
        self.ensure([uri], tier)
        with self._lock:
            e = self._entries.get(uri)
            if e is None:
                raise KeyError(uri)
            return e.copies[tier][1]

    def ensure(self, uris, tier: str) -> int:
        """Make ``tier`` current for ``uris``; returns bytes moved.

        The transport call happens **outside** the store lock so a slow
        transfer never serialises unrelated puts/gets (or a concurrent
        prefetch). Installation is hazard-checked: the shipped copy is
        tagged with the version snapshotted before the transfer and never
        replaces a newer copy; if a writer bumped the entry mid-flight the
        loop re-ships the fresher version.
        """
        return sum(self._ensure_one(uri, tier) for uri in uris)

    def _ensure_one(self, uri: str, tier: str) -> int:
        moved = 0
        expired_waits = 0
        self._warm_manifests([uri])         # hash misses outside the lock
        while True:
            peer = None
            with self._lock:
                e = self._entries.get(uri)
                if e is None:
                    raise KeyError(uri)
                if self.has_latest(uri, tier):
                    self._touch(uri, tier)        # a read access, for LRU
                    return moved
                peer = self._inflight.get((uri, tier))
                if peer is None:
                    src = self._freshest_tier(e)
                    if src is None:
                        raise KeyError(f"{uri}: no replica anywhere")
                    snap_version = e.version
                    value = e.copies[src][1]
                    if self.chunk_dedup:
                        chunks = self._manifest_for(
                            uri, snap_version, value)[1]
                        missing = self._missing_chunk_bytes(tier, chunks)
                    else:
                        chunks, missing = None, None
                    flight = threading.Event()
                    self._inflight[(uri, tier)] = flight
            if peer is not None:
                # someone (e.g. a prefetch) is already shipping this copy:
                # wait for that flight instead of moving the bytes twice.
                # A flight that never lands (wedged transport, dead
                # prefetch thread) must not hang us forever: after
                # max_transfer_waits expired waits, surface a transfer
                # error — _stage_inputs maps it to StepFailure, so the
                # executor's retry/fallback path owns recovery.
                if not peer.wait(timeout=self.transfer_wait_s):
                    expired_waits += 1
                    if expired_waits >= self.max_transfer_waits:
                        raise MDSSTransferError(
                            f"{uri}: in-flight transfer to {tier} did not "
                            f"complete within {expired_waits} x "
                            f"{self.transfer_wait_s}s waits")
                continue
            try:
                # wire movement with no lock held. A chunk-aware
                # transport (transfer_ex) ships only non-resident chunks
                # — a fully-resident value is a metadata-only round trip
                # — and reports the bytes it actually owed; the default
                # transport is charged the same dedup-aware obligation.
                transfer_ex = getattr(self.transport, "transfer_ex", None)
                if transfer_ex is not None:
                    shipped, n = transfer_ex(value, src, tier,
                                             chunks=chunks,
                                             missing_bytes=missing)
                else:
                    shipped = self.transport.transfer(value, src, tier)
                    n = nbytes_of(shipped) if missing is None else missing
                if missing is not None:
                    self.dedup_bytes_elided += \
                        max(nbytes_of(shipped) - n, 0)
                with self._lock:
                    e = self._entries.get(uri)
                    if e is None:
                        raise KeyError(uri)
                    cur = e.copies.get(tier)
                    if cur is None or cur[0] < snap_version:
                        self._set_copy(uri, e, tier, snap_version, shipped)
                        moved += n
                        self._account(uri, src, tier, n)
                        self.sync_events.append((uri, src, tier, n))
                        if len(self.sync_events) > self.sync_events_cap:
                            del self.sync_events[
                                :len(self.sync_events) - self.sync_events_cap]
                    if self.has_latest(uri, tier):
                        return moved
            finally:
                with self._lock:
                    self._inflight.pop((uri, tier), None)
                flight.set()
            # version moved mid-transfer -> loop and ship the newer one

    # -------------------------------------------------------------- prefetch
    def prefetch(self, uris, tier: str) -> Optional[Future]:
        """Asynchronous :meth:`ensure` — transfer overlaps caller compute.

        Missing URIs (outputs of steps still in flight) are skipped, not
        errors: prefetch is a best-effort warm-up, correctness still rests
        on the synchronous ``ensure`` at execution time. Returns a future
        resolving to the bytes moved, or ``None`` when the request was
        dropped at the concurrency cap (stale prefetches are worthless, so
        past the cap requests are shed, not queued). Each admitted
        prefetch runs on its own short-lived daemon thread — nothing to
        shut down, nothing leaked.
        """
        uris = list(uris)
        if not self._prefetch_slots.acquire(blocking=False):
            return None
        fut: Future = Future()
        threading.Thread(target=self._prefetch_task, args=(uris, tier, fut),
                         daemon=True, name="mdss-prefetch").start()
        return fut

    def _prefetch_task(self, uris, tier: str, fut: Future):
        try:
            moved = 0
            for uri in uris:
                try:
                    moved += self._ensure_one(uri, tier)
                except Exception:
                    # best-effort by contract: a missing uri or transport
                    # hiccup must neither kill the rest of the batch nor
                    # surface on a future nobody retrieves — the one
                    # ensure that matters runs synchronously at staging
                    pass
            with self._lock:
                self.prefetch_ops += 1
                self.prefetch_bytes += moved
            fut.set_result(moved)
        finally:
            self._prefetch_slots.release()

    def synchronize(self, uri: Optional[str] = None, tiers=None):
        """Paper's ``synchronize``: reconcile replicas last-writer-wins."""
        with self._lock:
            uris = [uri] if uri else list(self._entries)
            tiers = tiers or list(self.tiers)
            pairs = [(u, t) for u in uris for t in tiers
                     if t in self._entries[u].copies
                     or t == self._entries[u].writer]
        for u, t in pairs:       # transfers outside the lock
            self.ensure([u], t)

    # ------------------------------------------------------------- internal
    def _freshest_tier(self, e: _Entry) -> Optional[str]:
        best, best_v = None, -1
        for t, (v, _) in e.copies.items():
            if v > best_v:
                best, best_v = t, v
        return best if best_v == e.version else None

    def _account(self, uri: str, src: str, dst: str, n: int):
        key = (src, dst)
        self.bytes_moved[key] = self.bytes_moved.get(key, 0) + n
        ns = namespace_of(uri)
        self.ns_bytes_moved[ns] = self.ns_bytes_moved.get(ns, 0) + n
        if self.cost_model is not None:
            self.modeled_seconds += self.cost_model.transfer_time(n, src, dst)

    def _touch(self, uri: str, tier: str):
        self._last_used[(uri, tier)] = next(self._use_tick)

    # ------------------------------------------------- content addressing
    def _manifest_for(self, uri: str, version: int, value):
        """(content_digest, [(chunk_digest, length), ...]) of a stored
        value, cached per (uri, version) — lock held. Hashing happens
        once per version however many tiers the replica reaches; the
        public put paths pre-hash OUTSIDE the lock and seed this cache,
        so a multi-MB publish does not stall other tenants' store ops."""
        key = (uri, version)
        got = self._manifest_cache.get(key)
        if got is not None:
            self._manifest_cache.move_to_end(key)
            return got
        mani = manifest_of(value)
        self._cache_manifest(key, mani)
        return mani

    def _cache_manifest(self, key, mani):
        self._manifest_cache[key] = mani
        while len(self._manifest_cache) > self.manifest_cache_cap:
            self._manifest_cache.popitem(last=False)

    def _warm_manifests(self, uris):
        """Hash any manifest-cache misses for ``uris``' freshest replicas
        with NO lock held, then seed the cache. The read paths
        (staleness, content_digest, ensure) call this first so their
        under-lock work is dict lookups, not SHA-256 of multi-MB values
        — a racing version bump can still miss and hash under the lock,
        but that is the rare case, not the steady state."""
        if not self.chunk_dedup:
            return
        with self._lock:
            todo = []
            for uri in uris:
                e = self._entries.get(uri)
                if e is None:
                    continue
                src = self._freshest_tier(e)
                if src is None:
                    continue
                version, value = e.copies[src]
                if (uri, version) not in self._manifest_cache:
                    todo.append((uri, version, value))
        if not todo:
            return
        hashed = [(u, v, manifest_of(val)) for u, v, val in todo]
        with self._lock:
            for u, v, mani in hashed:
                if (u, v) not in self._manifest_cache:
                    self._cache_manifest((u, v), mani)

    def _chunks_retain(self, tier: str, uri: str, version: int, value):
        idx = self._tier_chunks.setdefault(tier, {})
        for d, ln in self._manifest_for(uri, version, value)[1]:
            ent = idx.get(d)
            if ent is None:
                idx[d] = [1, ln]
            else:
                ent[0] += 1

    def _chunks_release(self, tier: str, uri: str, version: int, value):
        idx = self._tier_chunks.get(tier)
        if idx is None:
            return
        for d, _ in self._manifest_for(uri, version, value)[1]:
            ent = idx.get(d)
            if ent is not None:
                ent[0] -= 1
                if ent[0] <= 0:
                    del idx[d]

    def _missing_chunk_bytes(self, tier: str, chunks) -> int:
        """Bytes of ``chunks`` not resident on ``tier`` — lock held."""
        idx = self._tier_chunks.get(tier, {})
        return sum(ln for d, ln in chunks if d not in idx)

    def tier_chunk_stats(self, tier: str) -> Tuple[int, int]:
        """(distinct chunks, deduped bytes) resident on ``tier``."""
        with self._lock:
            idx = self._tier_chunks.get(tier, {})
            return len(idx), sum(ln for _, ln in idx.values())

    def content_digest(self, uri: str) -> bytes:
        """Digest identifying the freshest replica's full content — the
        identity cross-run step memoization keys on."""
        self._warm_manifests([uri])
        with self._lock:
            e = self._entries.get(uri)
            if e is None:
                raise KeyError(uri)
            src = self._freshest_tier(e)
            if src is None:
                raise KeyError(f"{uri}: no fresh replica anywhere")
            version, value = e.copies[src]
            return self._manifest_for(uri, version, value)[0]

    def _set_copy(self, uri: str, e: _Entry, tier: str, version: int, value):
        """Install/replace ``tier``'s copy (lock held) keeping the
        incremental resident-byte counters and LRU clock current, and
        schedule eviction when the write pushes a namespace over its
        budget on this tier."""
        key = (namespace_of(uri), tier)
        old = e.copies.get(tier)
        if old is not None:
            self._ns_tier_bytes[key] = \
                self._ns_tier_bytes.get(key, 0) - nbytes_of(old[1])
            if self.chunk_dedup:
                self._chunks_release(tier, uri, old[0], old[1])
        e.copies[tier] = (version, value)
        self._ns_tier_bytes[key] = \
            self._ns_tier_bytes.get(key, 0) + nbytes_of(value)
        if self.chunk_dedup:
            self._chunks_retain(tier, uri, version, value)
        self.installs_total += 1
        self.install_events.append(
            (uri, tier, version, self._ns_epoch.get(key[0], 0),
             time.perf_counter()))
        if len(self.install_events) > self.sync_events_cap:
            del self.install_events[
                :len(self.install_events) - self.sync_events_cap]
        self._touch(uri, tier)
        self._maybe_schedule_eviction(*key)

    def _del_copy(self, uri: str, e: _Entry, tier: str) -> int:
        """Drop ``tier``'s copy (lock held); returns the bytes freed."""
        old = e.copies.pop(tier, None)
        if old is None:
            return 0
        if self.chunk_dedup:
            self._chunks_release(tier, uri, old[0], old[1])
        n = nbytes_of(old[1])
        key = (namespace_of(uri), tier)
        left = self._ns_tier_bytes.get(key, 0) - n
        if left > 0:
            self._ns_tier_bytes[key] = left
        else:
            self._ns_tier_bytes.pop(key, None)
        self._last_used.pop((uri, tier), None)
        return n

    # ------------------------------------------- residency budgets / eviction
    def set_namespace_budget(self, ns: str, tier: str,
                             max_bytes: Optional[int]):
        """Bound namespace ``ns``'s resident bytes on ``tier``
        (``None`` clears the budget). If the namespace is already over,
        background eviction starts immediately. The local tier is the
        eviction write-back target and cannot carry a budget — accepting
        one would be a bound that silently never evicts."""
        if max_bytes is not None and tier == "local":
            raise ValueError(
                "local is the eviction write-back tier: a residency "
                "budget there cannot be enforced")
        with self._lock:
            key = (ns, tier)
            if max_bytes is None:
                self._budgets.pop(key, None)
                return
            self._budgets[key] = int(max_bytes)
            self._maybe_schedule_eviction(ns, tier)

    def namespace_budget(self, ns: str, tier: str) -> Optional[int]:
        with self._lock:
            return self._budgets.get((ns, tier))

    def namespace_tier_bytes(self, ns: str, tier: str) -> int:
        """Bytes currently resident for namespace ``ns`` on ``tier``
        (incremental counter — no scan)."""
        with self._lock:
            return self._ns_tier_bytes.get((ns, tier), 0)

    def resident_bytes(self, tier: Optional[str] = None) -> int:
        """Total resident bytes (all replicas), optionally one tier's."""
        with self._lock:
            return sum(v for (_, t), v in self._ns_tier_bytes.items()
                       if tier is None or t == tier)

    def over_capacity(self, headroom: float = 1.0) -> bool:
        """True when residency reaches ``headroom`` x ``capacity_bytes``
        (False when no capacity is configured) — the admission signal."""
        cap = self.capacity_bytes
        return bool(cap) and self.resident_bytes() >= headroom * cap

    def _maybe_schedule_eviction(self, ns: str, tier: str):
        """Lock held: kick a background enforcement thread for an
        over-budget (namespace, tier), at most one at a time per pair."""
        key = (ns, tier)
        budget = self._budgets.get(key)
        if tier == "local" or budget is None \
                or self._ns_tier_bytes.get(key, 0) <= budget \
                or key in self._evict_pending:
            return
        self._evict_pending.add(key)
        threading.Thread(target=self._evict_task, args=key, daemon=True,
                         name="mdss-evict").start()

    def _evict_task(self, ns: str, tier: str):
        key = (ns, tier)
        while True:
            try:
                n, _ = self.enforce_budget(ns, tier)
            except Exception:
                n = 0       # transport wedged / store torn down mid-evict
            with self._lock:
                budget = self._budgets.get(key)
                if n == 0 or budget is None \
                        or self._ns_tier_bytes.get(key, 0) <= budget:
                    # done, unenforceable (no candidates), or budget gone:
                    # stop — the next over-budget write re-triggers
                    self._evict_pending.discard(key)
                    return

    def enforce_budget(self, ns: str, tier: str,
                       writeback_tier: str = "local") -> Tuple[int, int]:
        """Evict LRU entries of ``ns`` on ``tier`` until the configured
        budget fits; returns ``(entries_evicted, bytes_evicted)``.

        Eviction is write-back-then-drop: if ``tier`` holds the only
        latest copy it is first re-replicated on ``writeback_tier``
        through the normal hazard-checked transfer path. That path is
        plain replica movement — it never bumps a version and never
        recreates an entry (a namespace dropped mid-eviction surfaces as
        ``KeyError`` and is skipped), so eviction cannot defeat the fence
        epochs that keep a draining step's stale write-back out. Entries
        with a transfer currently in flight to ``tier`` are not
        candidates (the installing thread would just re-create the copy).
        """
        budget = self._budgets.get((ns, tier))
        if budget is None or tier == writeback_tier:
            return (0, 0)
        evicted_n = evicted_b = 0
        prefix = ns + "/" if ns else ""
        guard = 0
        while True:
            guard += 1
            if guard > 10000:    # pathological transport: never spin forever
                break
            with self._lock:
                if self._ns_tier_bytes.get((ns, tier), 0) <= budget:
                    break
                cands = [(self._last_used.get((u, tier), 0), u)
                         for u, e in self._entries.items()
                         if u.startswith(prefix) and tier in e.copies
                         and (u, tier) not in self._inflight
                         and (ns != "" or "/" not in u)]
                if not cands:
                    break
                _, victim = min(cands)
            try:
                # write-back outside the lock (hazard-checked install)
                self._ensure_one(victim, writeback_tier)
            except KeyError:
                continue       # entry/namespace dropped mid-eviction
            except MDSSTransferError:
                break          # wedged transfer: give up, retry next call
            with self._lock:
                e = self._entries.get(victim)
                if e is None:
                    continue
                tcopy = e.copies.get(tier)
                wcopy = e.copies.get(writeback_tier)
                if tcopy is None:
                    continue
                if wcopy is None or wcopy[0] < tcopy[0]:
                    continue   # a newer write landed on tier: re-ship it
                n = self._del_copy(victim, e, tier)
                self.evictions += 1
                self.eviction_bytes += n
                evicted_n += 1
                evicted_b += n
                self.eviction_events.append(
                    (victim, tier, n, tcopy[0],
                     self._ns_epoch.get(namespace_of(victim), 0),
                     time.perf_counter()))
                if len(self.eviction_events) > self.sync_events_cap:
                    del self.eviction_events[
                        :len(self.eviction_events) - self.sync_events_cap]
        return evicted_n, evicted_b

    # ----------------------------------------------------------- namespaces
    def namespaced(self, ns: str, shared: Optional[str] = None
                   ) -> "NamespacedMDSS":
        """A per-run view: writes land under ``ns/``, reads of URIs absent
        from ``ns`` fall through to the ``shared`` namespace."""
        return NamespacedMDSS(self, ns, shared=shared)

    def namespace_entries(self, ns: str):
        """URIs currently stored under namespace ``ns``."""
        prefix = ns + "/"
        with self._lock:
            return [u for u in self._entries if u.startswith(prefix)]

    def namespace_bytes(self, ns: str) -> int:
        """Wire bytes moved so far on behalf of namespace ``ns``."""
        with self._lock:
            return self.ns_bytes_moved.get(ns, 0)

    def namespace_resident_bytes(self, ns: str) -> int:
        """Bytes currently resident (all replicas) under namespace ``ns``."""
        with self._lock:
            return sum(v for (n, _), v in self._ns_tier_bytes.items()
                       if n == ns)

    def drop_namespace(self, ns: str) -> Tuple[int, int]:
        """Run teardown: delete every entry under ``ns/`` (and the
        namespace's residency budgets).

        Returns ``(entries_dropped, resident_bytes_freed)``. In-flight
        work targeting dropped URIs finishes harmlessly: the transfer
        install step re-checks the entry under the lock (a missing entry
        surfaces as KeyError to the best-effort shipper), and a draining
        step's fenced write-back is refused because its fence tokens
        carry the pre-drop namespace epoch — neither resurrects the data.
        """
        prefix = ns + "/"
        with self._lock:
            doomed = [u for u in self._entries if u.startswith(prefix)]
            freed = 0
            for u in doomed:
                e = self._entries[u]
                for t in list(e.copies):
                    freed += self._del_copy(u, e, t)
                del self._entries[u]
            # purge the dropped URIs' cached manifests (AFTER the
            # deletions — _del_copy's chunk release re-warms them): a
            # reused namespace restarts versions at 1, and a stale
            # (uri, version) hit would hand the OLD content's digest to
            # new data — wrong memo keys, wrong residency pricing
            dead = set(doomed)
            for key in [k for k in self._manifest_cache if k[0] in dead]:
                del self._manifest_cache[key]
            self._ns_epoch[ns] = self._ns_epoch.get(ns, 0) + 1
            for key in [k for k in self._budgets if k[0] == ns]:
                del self._budgets[key]
        return len(doomed), freed

    # ------------------------------------------------------------ reporting
    def total_bytes_moved(self) -> int:
        return sum(self.bytes_moved.values())

    def register_metrics(self, registry):
        """Expose the store's counters — including the previously
        orphaned ``eviction_bytes`` — as pull gauges in a metrics
        registry. Gauges read under the store lock at snapshot time, so
        hot-path puts/transfers pay nothing extra."""
        registry.gauge("mdss.resident_bytes", self.resident_bytes)
        registry.gauge("mdss.bytes_moved", self.total_bytes_moved)
        registry.gauge("mdss.modeled_seconds", lambda: self.modeled_seconds)
        registry.gauge("mdss.prefetch_ops", lambda: self.prefetch_ops)
        registry.gauge("mdss.prefetch_bytes", lambda: self.prefetch_bytes)
        registry.gauge("mdss.fenced_puts", lambda: self.fenced_puts)
        registry.gauge("mdss.evictions", lambda: self.evictions)
        registry.gauge("mdss.eviction_bytes", lambda: self.eviction_bytes)
        registry.gauge("mdss.dedup_bytes_elided",
                       lambda: self.dedup_bytes_elided)
        registry.gauge("mdss.entries", lambda: len(self._entries))
        registry.gauge("mdss.chunk_index_bytes", self._chunk_index_bytes)

    def _chunk_index_bytes(self) -> int:
        """Deduped bytes across every tier's chunk index."""
        with self._lock:
            return sum(sum(ln for _, ln in idx.values())
                       for idx in self._tier_chunks.values())

    def introspect(self) -> dict:
        """Structured residency snapshot: per-(namespace, tier) resident
        bytes vs. budget, per-tier totals + chunk-index occupancy, and
        the store's cumulative counters. One lock hold — internally
        consistent."""
        with self._lock:
            residency = [
                {"namespace": ns, "tier": tier, "resident_bytes": n,
                 "budget_bytes": self._budgets.get((ns, tier))}
                for (ns, tier), n in sorted(self._ns_tier_bytes.items())]
            tier_rows = []
            for name in self.tiers:
                idx = self._tier_chunks.get(name, {})
                tier_rows.append({
                    "name": name,
                    "objects": sum(1 for e in self._entries.values()
                                   if name in e.copies),
                    "resident_bytes": sum(
                        v for (_, t), v in self._ns_tier_bytes.items()
                        if t == name),
                    "capacity_bytes": None,   # store-wide cap: see top level
                    "chunks": len(idx),
                    "chunk_bytes": sum(ln for _, ln in idx.values()),
                })
            counters = {
                "bytes_moved": sum(self.bytes_moved.values()),
                "modeled_seconds": self.modeled_seconds,
                "prefetch_ops": self.prefetch_ops,
                "prefetch_bytes": self.prefetch_bytes,
                "fenced_puts": self.fenced_puts,
                "evictions": self.evictions,
                "eviction_bytes": self.eviction_bytes,
                "dedup_bytes_elided": self.dedup_bytes_elided,
                "entries": len(self._entries),
            }
        return {"residency": residency, "tiers": tier_rows,
                "capacity_bytes": self.capacity_bytes, "counters": counters}

    def reset_accounting(self):
        self.bytes_moved.clear()
        self.ns_bytes_moved.clear()
        self.modeled_seconds = 0.0
        self.sync_events.clear()
        self.prefetch_ops = 0
        self.prefetch_bytes = 0
        self.fenced_puts = 0
        self.evictions = 0
        self.eviction_bytes = 0
        self.eviction_events.clear()
        self.install_events.clear()
        self.installs_total = 0


class NamespacedMDSS:
    """Per-run MDSS view (multi-tenant isolation with shared warm data).

    Implements the executor/manager-facing MDSS surface over a base store:

      * writes (``put``/``put_many``) always land under ``ns/uri`` — a run
        can never clobber another run's (or the shared namespace's) data,
      * reads (``get``/``ensure``/``version``/...) resolve ``uri`` to
        ``ns/uri`` when the run has written it, else fall through to
        ``shared/uri`` when a shared namespace is configured and holds the
        URI — cross-run warm data (params, observations) is stored and
        kept cloud-resident exactly once,
      * write fences (``expect_version``) compare against the *resolved*
        read version, so a fence snapshotted against a shared-namespace
        entry still means "nothing newer was published" when the fenced
        write creates the run's first private copy of the URI.

    Resolution is decided per call; dataflow (WAR/WAW) edges inside a run
    serialise its readers against its writers, and other runs never write
    this namespace, so a read resolved to ``shared`` cannot race a private
    overwrite it should have seen.
    """

    def __init__(self, base: MDSS, ns: str, shared: Optional[str] = None):
        assert "/" not in ns, f"namespace may not contain '/': {ns!r}"
        self.base = base
        self.ns = ns
        self.shared = shared if shared != ns else None

    # ------------------------------------------------------- key resolution
    def _wkey(self, uri: str) -> str:
        return f"{self.ns}/{uri}"

    def _rkey(self, uri: str) -> str:
        wk = f"{self.ns}/{uri}"
        if self.shared is None:
            return wk
        with self.base._lock:
            if wk in self.base._entries:
                return wk
            sk = f"{self.shared}/{uri}"
            if sk in self.base._entries:
                return sk
        return wk

    # ------------------------------------------------------------------ api
    def put(self, uri: str, value, tier: str = "local",
            expect_version: Optional[int] = None):
        if expect_version is None:
            return self.base.put(self._wkey(uri), value, tier)
        with self.base._lock:           # pre-check before paying the hash
            if self.version(uri) != expect_version:
                self.base.fenced_puts += 1
                return None
        mani = manifest_of(value) if self.base.chunk_dedup else None
        with self.base._lock:
            if self.version(uri) != expect_version:
                self.base.fenced_puts += 1
                return None
            return self.base.put(self._wkey(uri), value, tier,
                                 _manifest=mani)

    def fence_tokens(self, uris) -> Dict[str, Tuple[str, int, int]]:
        """Snapshot (resolved key, version, namespace epoch) per URI for
        a later fenced ``put_many``. Tokens carry the *resolution* — a
        bare version number is ambiguous across the shared/private
        boundary (shared/u at v1 and a later private run/u at v1 compare
        equal), which would let a speculation loser's late publish slip
        past the fence — and the namespace *epoch*, so a draining step's
        write-back after ``drop_namespace`` is refused rather than
        resurrecting the dropped data."""
        with self.base._lock:
            epoch = self.base._ns_epoch.get(self.ns, 0)
            return {u: (self._rkey(u), self.base.version(self._rkey(u)),
                        epoch)
                    for u in uris}

    def put_many(self, values: Dict[str, Any], tier: str = "local",
                 expect_versions: Optional[Dict] = None):
        """Fenced batch publish. ``expect_versions`` values may be plain
        ints (compat: compared against the resolved read version) or
        :meth:`fence_tokens` tuples (compared against resolution, version
        AND namespace epoch — required for correctness under shared-read
        fallback and namespace teardown)."""
        if expect_versions is not None:
            with self.base._lock:   # pre-check before paying the hash
                if self._batch_stale(values, expect_versions):
                    self.base.fenced_puts += 1
                    return None
        pre = self.base._premanifests(values)
        with self.base._lock:
            if expect_versions is not None \
                    and self._batch_stale(values, expect_versions):
                self.base.fenced_puts += 1
                return None
            return {uri: self.base.put(self._wkey(uri), val, tier,
                                       _manifest=pre.get(uri))
                    for uri, val in values.items()}

    def _batch_stale(self, values, expect_versions) -> bool:
        """Base lock held: True if any fence token no longer matches."""
        for uri in values:
            exp = expect_versions.get(uri, 0)
            if isinstance(exp, tuple):
                rkey, ver = exp[0], exp[1]
                cur = self._rkey(uri)
                stale = (cur != rkey
                         or self.base.version(cur) != ver
                         or (len(exp) > 2 and exp[2] !=
                             self.base._ns_epoch.get(self.ns, 0)))
            else:
                stale = self.version(uri) != exp
            if stale:
                return True
        return False

    def version(self, uri: str) -> int:
        return self.base.version(self._rkey(uri))

    def peek_latest(self, uri: str):
        return self.base.peek_latest(self._rkey(uri))

    def content_digest(self, uri: str) -> bytes:
        return self.base.content_digest(self._rkey(uri))

    def has_latest(self, uri: str, tier: str) -> bool:
        return self.base.has_latest(self._rkey(uri), tier)

    def stale_bytes(self, uris, tier: str) -> int:
        return self.base.stale_bytes([self._rkey(u) for u in uris], tier)

    def staleness(self, uris, tier: str):
        return self.base.staleness([self._rkey(u) for u in uris], tier)

    def get(self, uri: str, tier: str = "local"):
        return self.base.get(self._rkey(uri), tier)

    def ensure(self, uris, tier: str) -> int:
        return self.base.ensure([self._rkey(u) for u in uris], tier)

    def prefetch(self, uris, tier: str) -> Optional[Future]:
        return self.base.prefetch([self._rkey(u) for u in uris], tier)

    def synchronize(self, uri: Optional[str] = None, tiers=None):
        return self.base.synchronize(
            self._rkey(uri) if uri is not None else None, tiers)

    def resolves_shared(self, uri: str) -> bool:
        """True when a read of ``uri`` currently falls through to the
        shared namespace (the run holds no private copy)."""
        return self.shared is not None and \
            self._rkey(uri).startswith(self.shared + "/")

    # ----------------------------------------------------------- accounting
    def bytes_moved_here(self) -> int:
        return self.base.namespace_bytes(self.ns)

    def set_budget(self, tier: str, max_bytes: Optional[int]):
        """Residency budget for THIS run's namespace on ``tier``."""
        self.base.set_namespace_budget(self.ns, tier, max_bytes)

    def resident_bytes_here(self, tier: str) -> int:
        return self.base.namespace_tier_bytes(self.ns, tier)

    def drop(self) -> Tuple[int, int]:
        return self.base.drop_namespace(self.ns)

    @property
    def tiers(self):
        return self.base.tiers

    @property
    def cost_model(self):
        return self.base.cost_model
