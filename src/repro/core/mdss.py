"""MDSS — Multi-level Data Storage Service (paper §3.4).

URI-keyed, versioned, multi-tier data store:

  * writes land on the *writing* tier first (paper: "data is always
    accessible to the application", offline-capable) and propagate lazily,
  * ``synchronize`` reconciles tiers **last-writer-wins** (paper default),
  * ``ensure(uri, tier)`` is the offload fast-path: if the target tier
    already holds the latest version nothing moves (task-code-only
    offloading); otherwise only the stale entries transfer,
  * every cross-tier movement is accounted (bytes, modeled seconds) — the
    MDSS benchmark and the §Perf analysis read these counters.

Values are arbitrary pytrees of arrays / scalars. A ``Transport`` performs
the actual movement; the default in-process transport re-places arrays on
the destination tier's mesh (``jax.device_put``) when it has one.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def nbytes_of(value) -> int:
    total = 0
    for leaf in jax.tree.leaves(value):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif isinstance(leaf, (int, float, bool)):
            total += 8
        elif isinstance(leaf, (str, bytes)):
            total += len(leaf)
    return total


class Transport:
    """Moves a value between tiers; override for a real RPC fabric."""

    def __init__(self, tiers=None):
        self.tiers = tiers or {}

    def transfer(self, value, src: str, dst: str):
        tier = self.tiers.get(dst)
        if tier is not None and tier.mesh is not None:
            return value  # placement deferred to the executing jit's shardings
        return value


@dataclass
class _Entry:
    version: int = 0
    writer: str = ""
    copies: Dict[str, Tuple[int, Any]] = field(default_factory=dict)


class MDSS:
    def __init__(self, tiers, transport: Optional[Transport] = None,
                 cost_model=None):
        self.tiers = tiers
        self.transport = transport or Transport(tiers)
        self.cost_model = cost_model
        self._entries: Dict[str, _Entry] = {}
        self._lock = threading.RLock()
        # accounting
        self.bytes_moved: Dict[Tuple[str, str], int] = {}
        self.modeled_seconds: float = 0.0
        self.sync_events: list = []

    # ------------------------------------------------------------------ api
    def put(self, uri: str, value, tier: str = "local"):
        """New version written on ``tier`` (local-first semantics)."""
        with self._lock:
            e = self._entries.setdefault(uri, _Entry())
            e.version += 1
            e.writer = tier
            e.copies[tier] = (e.version, value)
            return e.version

    def version(self, uri: str) -> int:
        e = self._entries.get(uri)
        return 0 if e is None else e.version

    def has_latest(self, uri: str, tier: str) -> bool:
        with self._lock:
            e = self._entries.get(uri)
            if e is None:
                return False
            got = e.copies.get(tier)
            return got is not None and got[0] == e.version

    def stale_bytes(self, uris, tier: str) -> int:
        """Bytes that WOULD move to make ``tier`` current for ``uris``."""
        total = 0
        with self._lock:
            for uri in uris:
                e = self._entries.get(uri)
                if e is None or self.has_latest(uri, tier):
                    continue
                src = self._freshest_tier(e)
                if src is not None:
                    total += nbytes_of(e.copies[src][1])
        return total

    def get(self, uri: str, tier: str = "local"):
        """Value at ``tier``, syncing from the freshest tier if stale."""
        self.ensure([uri], tier)
        with self._lock:
            e = self._entries.get(uri)
            if e is None:
                raise KeyError(uri)
            return e.copies[tier][1]

    def ensure(self, uris, tier: str) -> int:
        """Make ``tier`` current for ``uris``; returns bytes moved."""
        moved = 0
        with self._lock:
            for uri in uris:
                e = self._entries.get(uri)
                if e is None:
                    raise KeyError(uri)
                if self.has_latest(uri, tier):
                    continue
                src = self._freshest_tier(e)
                if src is None:
                    raise KeyError(f"{uri}: no replica anywhere")
                value = e.copies[src][1]
                value = self.transport.transfer(value, src, tier)
                n = nbytes_of(value)
                moved += n
                self._account(src, tier, n)
                e.copies[tier] = (e.version, value)
                self.sync_events.append((uri, src, tier, n))
        return moved

    def synchronize(self, uri: Optional[str] = None, tiers=None):
        """Paper's ``synchronize``: reconcile replicas last-writer-wins."""
        with self._lock:
            uris = [uri] if uri else list(self._entries)
            tiers = tiers or list(self.tiers)
            for u in uris:
                for t in tiers:
                    if t in self._entries[u].copies or t == self._entries[u].writer:
                        self.ensure([u], t)

    # ------------------------------------------------------------- internal
    def _freshest_tier(self, e: _Entry) -> Optional[str]:
        best, best_v = None, -1
        for t, (v, _) in e.copies.items():
            if v > best_v:
                best, best_v = t, v
        return best if best_v == e.version else None

    def _account(self, src: str, dst: str, n: int):
        key = (src, dst)
        self.bytes_moved[key] = self.bytes_moved.get(key, 0) + n
        if self.cost_model is not None:
            self.modeled_seconds += self.cost_model.transfer_time(n, src, dst)

    # ------------------------------------------------------------ reporting
    def total_bytes_moved(self) -> int:
        return sum(self.bytes_moved.values())

    def reset_accounting(self):
        self.bytes_moved.clear()
        self.modeled_seconds = 0.0
        self.sync_events.clear()
