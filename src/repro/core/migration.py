"""Migration manager (paper §3.3): offload / execute / re-integrate.

Life-cycle for a remotable step *i* (paper's wording in quotes):

  1. the migration point "suspends the execution of the workflow" and hands
     *i* to this manager,
  2. MDSS makes *i*'s input URIs current on the target tier — if the tier
     already holds the latest versions the offload is **code-only**
     (paper §3.4), and "code" on TPU is a per-(step, tier) compile-cache
     entry, so repeat offloads move nothing at all,
  3. *i* executes on the tier (under its mesh when it has one),
  4. outputs are ``put`` on the executing tier and lazily synced — a
     downstream offloaded step reads them in place, the paper's key saving,
  5. the workflow resumes ("re-integration").

Execution statistics (wall time, XLA cost analysis at first compile) feed
the cost model for the beyond-paper scheduling policy.

Multi-tenancy: one manager serves every run of a shared runtime. The
compile cache is keyed by (step name, tier, *code fingerprint*) so the
second submission of the same workflow — same step code, typically a new
``Workflow`` object — reuses the compiled executable (code-only repeat
offloads) while two tenants that happen to share a step *name* with
different code never collide. Cost-model stats stay keyed by step name
(the paper's granularity) and likewise survive across runs, so a repeat
submission is pre-measured from the first one. ``execute`` accepts a
per-run ``mdss`` view (namespace isolation) and a ``priority`` class that
rides down to the fabric broker.

Cross-run step memoization (opt-in: ``memoize=True`` on the manager /
runtime, or ``memoizable=True`` per step): an execution is keyed by
``(step code fingerprint, input content digests, output names)``. Two
tenants submitting the identical step over content-identical inputs
share ONE execution — the second publishes the first's host-snapshot
outputs into its own namespace (a fenced put, zero staging, zero wire
bytes) instead of re-running; a tenant arriving while the first is
still executing waits on it rather than racing. Only safe for
deterministic, side-effect-free steps — a memoized result is reused
whenever code and input *content* match, regardless of namespace, run,
or wall-clock; steps that read clocks, RNGs, or external state must
leave memoization off (``memoizable=False`` overrides a manager-wide
``memoize=True``).
"""
from __future__ import annotations

import pickle
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax

from repro.core.cost_model import CostModel
from repro.core.mdss import MDSS, nbytes_of
from repro.core.tiers import Tier
from repro.core.workflow import Step
from repro.obs.tracing import Tracer


class StepFailure(RuntimeError):
    pass


def step_code_key(step: Step):
    """Stable identity of a step's *code* (not its enclosing workflow).

    Registry steps are identified by registry name; closure/default-free
    plain fns by (code object, globals identity) — CPython compares code
    objects by VALUE (bytecode, consts, names, location), so rebuilding
    an identical workflow in the same module for a second submission
    still hits the compile cache, while a same-named tenant step with
    different code (even two ``exec``'d bodies sharing ``<string>:1``)
    gets its own entry. Globals identity matters because equal code can
    read *different* module globals (``return x * SCALE`` under two
    modules); identical-looking fns from different global environments
    are therefore a safe miss, never a shared hit. Functions that carry
    per-object state (closures, bound methods, default args, non-plain
    callables) key by object identity outright."""
    if step.remote_impl:
        return ("registry", step.remote_impl)
    fn = step.fn
    code = getattr(fn, "__code__", None)
    stateless = (code is not None
                 and getattr(fn, "__closure__", None) is None
                 and getattr(fn, "__self__", None) is None
                 and not getattr(fn, "__defaults__", None)
                 and not getattr(fn, "__kwdefaults__", None))
    if stateless:
        return ("code", code, id(getattr(fn, "__globals__", None)))
    return ("id", id(fn))


_IMMUTABLE_CAPTURE = (int, float, complex, bool, str, bytes, frozenset,
                      tuple, type(None))


def fabric_runnable_reason(step: Step) -> Optional[str]:
    """``None`` if ``step`` could execute in a fabric worker, else a
    one-line reason. Mirrors ``Fabric.can_run`` without needing a live
    fabric, so the static verifier shares the dispatcher's judgement."""
    if getattr(step, "remote_impl", None):
        return None
    if step.fn is None:
        return "no fn and no remote_impl"
    if getattr(step, "jax_step", True):
        return "jax step (mesh-placed in-process by design)"
    try:
        pickle.dumps(step.fn)
        return None
    except Exception as exc:
        return f"fn is not picklable ({type(exc).__name__}: {exc})"


def memo_unsafe_reasons(step: Step) -> list:
    """Why memoizing ``step`` could serve stale results: state the step's
    fn reads that the memo key ``(code fingerprint, input digests,
    outputs)`` cannot see. Immutable scalar captures are fine — a closure
    keys by object identity, which pins them — but a *mutable* capture
    (list/dict/array/object) can change between calls under one key."""
    fn = step.fn
    if fn is None:
        return []
    reasons = []
    cells = getattr(fn, "__closure__", None)
    if cells:
        names = getattr(getattr(fn, "__code__", None), "co_freevars", ())
        for name, cell in zip(names, cells):
            try:
                v = cell.cell_contents
            except ValueError:      # unfilled cell
                reasons.append(f"closes over unfilled cell {name!r}")
                continue
            if not isinstance(v, _IMMUTABLE_CAPTURE):
                reasons.append(
                    f"closes over mutable {type(v).__name__} {name!r}")
    for v in (getattr(fn, "__defaults__", None) or ()):
        if not isinstance(v, _IMMUTABLE_CAPTURE):
            reasons.append(f"mutable default of type {type(v).__name__}")
    for v in (getattr(fn, "__kwdefaults__", None) or {}).values():
        if not isinstance(v, _IMMUTABLE_CAPTURE):
            reasons.append(f"mutable kw default of type {type(v).__name__}")
    if getattr(fn, "__self__", None) is not None:
        reasons.append("bound method: instance state is outside the key")
    return reasons


@dataclass
class OffloadReport:
    step: str
    tier: str
    seconds: float
    bytes_in: int
    bytes_out: int
    code_only: bool
    remote: bool = False            # executed in a fabric worker process
    worker_pid: int = 0             # pid of that worker (0 = in-process)
    fenced: bool = False            # write-back refused: a newer version
                                    # landed while this execution ran
                                    # (speculation loser / stale straggler)
    staged_s: float = 0.0           # wall time spent staging inputs — the
                                    # observed counterpart of the locality
                                    # scheduler's modeled transfer score
    memo_hit: bool = False          # reused a memoized execution: the step
                                    # fn never ran and nothing was staged


class _MemoEntry:
    """One memoized execution: in-flight until ``event`` fires, then
    either ``outputs`` (host snapshots) or ``error``. ``pin`` holds a
    strong reference to the step's fn for id-keyed code keys — without
    it a GC'd closure's recycled object id could collide a LATER,
    different function into this entry's key (the compile cache pins its
    fn the same way, implicitly, by caching it)."""
    __slots__ = ("event", "outputs", "error", "nbytes", "pin")

    def __init__(self, pin=None):
        self.event = threading.Event()
        self.outputs: Optional[Dict[str, Any]] = None
        self.error: Optional[BaseException] = None
        self.nbytes = 0
        self.pin = pin


class MigrationManager:
    def __init__(self, tiers: Dict[str, Tier], mdss: MDSS,
                 cost_model: Optional[CostModel] = None,
                 remote_timeout_s: float = 120.0, memoize: bool = False):
        self.tiers = tiers
        self.mdss = mdss
        self.cost_model = cost_model or CostModel(tiers)
        self.remote_timeout_s = remote_timeout_s
        # cross-run memoization (see module docstring): default-off
        # manager-wide, overridable per step via Step.memoizable
        self.memoize = memoize
        self.memo_cap = 128                  # entries
        self.memo_cap_bytes = 256 << 20      # pinned host snapshots
        self._memo: "OrderedDict[Tuple, _MemoEntry]" = OrderedDict()
        self._memo_bytes = 0
        self._memo_lock = threading.Lock()
        self.memo_hits = 0
        self.memo_waits = 0
        # LRU-bounded: a long-lived runtime sees unboundedly many step
        # objects (fresh closures per tenant submission key by id), and a
        # cache entry pins its fn plus captured state — cap, don't grow
        self._compile_cache: Dict[Tuple, Any] = {}
        self._cache_lock = threading.Lock()
        self.compile_cache_cap = 1024
        self.compile_cache_hits = 0
        # bounded like the compile cache: one manager serves a long-lived
        # runtime, and an unbounded per-step report log would grow forever
        self.reports_cap = 4096
        self.reports: list[OffloadReport] = []
        # disabled by default; an owning runtime swaps in its live tracer
        # so stage/exec/install phases record under the dispatch span
        self.tracer = Tracer(enabled=False)

    def register_metrics(self, registry):
        """Expose the manager's cross-run caches in a metrics registry."""
        registry.gauge("memo.entries", lambda: len(self._memo))
        registry.gauge("memo.bytes", lambda: self._memo_bytes)
        registry.gauge("memo.hits", lambda: self.memo_hits)
        registry.gauge("memo.waits", lambda: self.memo_waits)
        registry.gauge("compile_cache.entries",
                       lambda: len(self._compile_cache))
        registry.gauge("compile_cache.hits",
                       lambda: self.compile_cache_hits)

    def memo_stats(self) -> dict:
        return {"entries": len(self._memo), "bytes": self._memo_bytes,
                "hits": self.memo_hits, "waits": self.memo_waits,
                "compile_cache_hits": self.compile_cache_hits}

    # ----------------------------------------------------------- executable
    def _executable(self, step: Step, tier_name: str):
        key = (step.name, tier_name, step_code_key(step))
        with self._cache_lock:
            cached = self._compile_cache.pop(key, None)
            if cached is not None:
                self._compile_cache[key] = cached    # LRU refresh
                self.compile_cache_hits += 1
                return cached
        fn = step.fn
        registry_fn = False
        if fn is None and step.remote_impl:
            # registry-only step: resolve the same fn the workers run so
            # the local tier remains a valid fallback
            from repro.cloud import tasklib
            fn = tasklib.resolve(step.remote_impl)
            registry_fn = True
        if fn is None:
            raise StepFailure(f"step {step.name} has no fn or remote_impl")
        if step.jax_step and not registry_fn:
            # registry fns are numpy-land by contract — never jit them,
            # whatever jax_step defaults to
            fn = jax.jit(fn)
        with self._cache_lock:
            self._compile_cache[key] = fn
            while len(self._compile_cache) > self.compile_cache_cap:
                self._compile_cache.pop(next(iter(self._compile_cache)))
        return fn

    def _capture_cost(self, step: Step, fn, kwargs):
        """First-execution XLA cost analysis -> cost model stats."""
        st = self.cost_model.stats_for(step.name)
        if st.flops or not step.jax_step:
            return
        try:
            ca = fn.lower(**kwargs).compile().cost_analysis()
            st.flops = float(ca.get("flops", 0.0))
            st.bytes_accessed = float(ca.get("bytes accessed", 0.0))
        except Exception:
            pass

    # -------------------------------------------------------------- execute
    def execute(self, step: Step, tier_name: str, *, mdss=None,
                priority: int = 0,
                memoize: Optional[bool] = None) -> OffloadReport:
        """Run ``step`` on ``tier_name``; inputs/outputs through MDSS.

        When the tier is fabric-backed (``tier.worker_pool``) and the step
        is fabric-runnable (registry name or picklable plain fn), execution
        happens in a worker OS process and the report carries the real
        bytes that crossed the wire; otherwise it runs in-process exactly
        as the seed did (jax steps always do — their point is mesh-placed
        execution, not process separation).

        ``mdss`` selects the data view — a run's :class:`NamespacedMDSS`
        under the multi-tenant runtime, the shared base store otherwise.
        ``priority`` is the fabric dispatch class: the broker serves
        higher classes first, so an interactive run's tasks overtake a
        batch run's queued work.

        When the step is memoizable (manager ``memoize`` / step
        ``memoizable``) the execution is shared across runs by content
        key: a hit publishes the memoized host snapshots into THIS run's
        namespace (fenced, zero staging) and reports ``memo_hit=True``.
        ``memoize=False`` forces this one execution uncached — how a
        speculation backup races its twin for real instead of becoming a
        waiter on the twin's own in-flight memo entry.
        """
        mdss = self.mdss if mdss is None else mdss
        key = self._memo_key(step, mdss, memoize)
        if key is None:
            return self._execute_now(step, tier_name, mdss, priority)[0]
        return self._execute_memoized(step, tier_name, mdss, priority, key)

    # ---------------------------------------------------------- memoization
    def _memo_key(self, step: Step, mdss, override: Optional[bool] = None):
        on = override
        if on is None:
            on = step.memoizable if step.memoizable is not None \
                else self.memoize
        if not on or not step.outputs:
            return None
        digest = getattr(mdss, "content_digest", None)
        if digest is None:
            return None
        try:
            in_digests = tuple((u, digest(u)) for u in step.inputs)
        except KeyError:
            return None      # an input is absent: not memoizable this run
        return (step_code_key(step), in_digests, tuple(step.outputs))

    def _execute_memoized(self, step: Step, tier_name: str, mdss,
                          priority: int, key) -> "OffloadReport":
        while True:
            with self._memo_lock:
                ent = self._memo.get(key)
                owner = ent is None
                if owner:
                    ent = _MemoEntry(pin=step.fn)
                    self._memo[key] = ent
                    self._trim_memo()
            if owner:
                try:
                    rep, out = self._execute_now(step, tier_name, mdss,
                                                 priority)
                except BaseException as e:
                    with self._memo_lock:
                        if self._memo.get(key) is ent:
                            del self._memo[key]
                    ent.error = e
                    ent.event.set()
                    raise
                # host COPIES, never views: the owner's run published
                # these same arrays into its namespace and hands them to
                # its caller — a tenant mutating its fetched result must
                # not corrupt the cache (a fenced publish still computed
                # content valid for this input key, so it is kept)
                ent.outputs = {k: jax.tree.map(lambda x: np.array(x), v)
                               for k, v in out.items()}
                ent.nbytes = sum(nbytes_of(v) for v in ent.outputs.values())
                with self._memo_lock:
                    if self._memo.get(key) is ent:
                        self._memo_bytes += ent.nbytes
                        self._trim_memo()
                ent.event.set()
                return rep
            # an identical execution is in flight (or done) on another
            # run: share it instead of re-running the step
            self.memo_waits += 1
            if not ent.event.wait(self.remote_timeout_s):
                # owner wedged (or a speculation twin racing itself):
                # degrade to an uncached execution, never deadlock
                return self._execute_now(step, tier_name, mdss, priority)[0]
            if ent.error is not None:
                continue     # owner failed and removed the entry: take over
            return self._publish_memoized(step, tier_name, mdss, ent)

    def _publish_memoized(self, step: Step, tier_name: str, mdss,
                          ent: _MemoEntry) -> "OffloadReport":
        fence = getattr(mdss, "fence_tokens", None)
        out_versions = fence(step.outputs) if fence is not None else \
            {k: mdss.version(k) for k in step.outputs}
        # each hit gets its own copies: N tenants sharing one execution
        # must not alias one mutable array across their namespaces
        published = mdss.put_many(
            {k: jax.tree.map(lambda x: np.array(x), ent.outputs[k])
             for k in step.outputs}, tier="local",
            expect_versions=out_versions)
        rep = OffloadReport(step.name, tier_name, 0.0, 0, 0,
                            code_only=True, fenced=published is None,
                            memo_hit=True)
        with self._memo_lock:
            self.memo_hits += 1
        self.reports.append(rep)
        if len(self.reports) > self.reports_cap:
            del self.reports[:len(self.reports) - self.reports_cap]
        return rep

    def _trim_memo(self):
        """Memo-lock held: drop oldest COMPLETED entries past the entry
        OR byte cap — host snapshots pin real driver memory, so the
        bound must be bytes, not just count. In-flight entries have
        waiters and are never evicted."""
        while len(self._memo) > self.memo_cap \
                or self._memo_bytes > self.memo_cap_bytes:
            for k, v in self._memo.items():
                if v.event.is_set():
                    self._memo_bytes -= v.nbytes
                    del self._memo[k]
                    break
            else:
                return

    def _execute_now(self, step: Step, tier_name: str, mdss,
                     priority: int = 0):
        tier = self.tiers[tier_name]
        uris = list(step.inputs)
        stale = mdss.stale_bytes(uris, tier_name)
        # snapshot output versions: the write-back below is fenced on them,
        # so a slow duplicate (speculation loser) can't clobber data a
        # faster twin or a downstream step has already published. A
        # namespaced view supplies (resolved key, version) tokens — a bare
        # number is ambiguous across its shared/private read boundary
        fence = getattr(mdss, "fence_tokens", None)
        out_versions = fence(step.outputs) if fence is not None else \
            {k: mdss.version(k) for k in step.outputs}
        t_stage = time.perf_counter()
        with self.tracer.span("ship", cat="data", step=step.name,
                              tier=tier_name) as shsp:
            bytes_in, kwargs = self._stage_inputs(step, tier_name, uris,
                                                  mdss)
            if shsp.ctx is not None:
                shsp.set(bytes=bytes_in)
        staged_s = time.perf_counter() - t_stage
        fabric = getattr(tier, "worker_pool", None)
        if fabric is not None and fabric.can_run(step):
            with self.tracer.span("exec", cat="exec", step=step.name,
                                  tier=tier_name, remote=True):
                out, dt, wire_in, wire_out, pid = self._execute_remote(
                    step, fabric, kwargs, priority)
            # report the worker's actual wire ingress; the MDSS staging
            # bytes remain visible in mdss.bytes_moved
            bytes_in = wire_in
            remote, worker_pid, wire_bytes_out = True, pid, wire_out
        else:
            fn = self._executable(step, tier_name)
            self._capture_cost(step, fn, kwargs)
            t0 = time.perf_counter()
            with self.tracer.span("exec", cat="exec", step=step.name,
                                  tier=tier_name, remote=False):
                ctx = tier.mesh if tier.mesh is not None else _nullcontext()
                with ctx:
                    out = fn(**kwargs)
                out = jax.block_until_ready(out) if step.jax_step else out
            dt = time.perf_counter() - t0
            remote, worker_pid, wire_bytes_out = False, 0, 0
        if not isinstance(out, dict):
            if len(step.outputs) != 1:
                raise StepFailure(
                    f"step {step.name} returned non-dict for multiple outputs")
            out = {(step.out_names or step.outputs)[0]: out}
        if step.out_names:
            # shard steps: the fn returns its original output names;
            # publish them under this shard's uri#k outputs
            out = {u: out[n] for u, n in zip(step.outputs, step.out_names)
                   if n in out}
        missing = set(step.outputs) - set(out)
        if missing:
            raise StepFailure(f"step {step.name} missing outputs {missing}")
        # all-or-nothing fenced publish: twins can never interleave a
        # mixed set of one step's outputs
        with self.tracer.span("install", cat="data", step=step.name,
                              tier=tier_name) as insp:
            published = mdss.put_many(
                {k: out[k] for k in step.outputs}, tier=tier_name,
                expect_versions=out_versions)
            fenced = published is None
            if insp.ctx is not None:
                insp.set(fenced=fenced)
        bytes_out = 0 if fenced else sum(nbytes_of(out[k])
                                         for k in step.outputs)
        if remote and not fenced:   # a refused publish moved no output bytes
            bytes_out = wire_bytes_out
        if not fenced:
            # a fenced run is a stale straggler — its wall time must not
            # pollute the runtime EMA the speculation trigger feeds on
            self.cost_model.stats_for(step.name).observe(tier_name, dt)
        rep = OffloadReport(step.name, tier_name, dt, bytes_in, bytes_out,
                            code_only=(stale == 0 and bool(uris)),
                            remote=remote, worker_pid=worker_pid,
                            fenced=fenced, staged_s=staged_s)
        self.reports.append(rep)
        if len(self.reports) > self.reports_cap:
            del self.reports[:len(self.reports) - self.reports_cap]
        return rep, out

    def _stage_inputs(self, step: Step, tier_name: str, uris, mdss):
        """MDSS ensure + get with fabric faults (a worker dying while the
        transport ships a stale input), stuck in-flight transfers
        (``MDSSTransferError``) and vanished entries (``KeyError`` from a
        namespace dropped mid-run) mapped to StepFailure, so staging
        errors go through the executor's retry path like execution
        errors."""
        from concurrent.futures import TimeoutError as _FutTimeout
        names = step.arg_names or tuple(uris)
        if len(names) != len(uris):
            raise StepFailure(
                f"step {step.name}: arg_names has {len(names)} entries for "
                f"{len(uris)} inputs — they must be parallel")
        try:
            bytes_in = mdss.ensure(uris, tier_name)
            return bytes_in, {n: mdss.get(u, tier_name)
                              for n, u in zip(names, uris)}
        except StepFailure:
            raise
        except (RuntimeError, LookupError, _FutTimeout, TimeoutError) as e:
            raise StepFailure(
                f"step {step.name}: staging inputs on {tier_name} failed: "
                f"{e!r}") from e

    def _execute_remote(self, step: Step, fabric, kwargs, priority: int = 0):
        """Dispatch through the fabric broker; fabric faults surface as
        StepFailure so the executor's retry / tier-fallback logic applies."""
        from concurrent.futures import TimeoutError as _FutTimeout
        from repro.cloud.broker import FabricError
        try:
            # the current (exec) span's identity rides the task frame
            # header to the worker — its recv/exec/send phases come back
            # in the reply and nest under this driver-side span
            task = fabric.submit_step(step, kwargs, priority=priority,
                                      trace_ctx=self.tracer.current_ctx())
            out = task.result(self.remote_timeout_s)
        except FabricError as e:
            raise StepFailure(f"fabric: {e}") from e
        except (TimeoutError, _FutTimeout) as e:
            raise StepFailure(
                f"step {step.name} timed out after {self.remote_timeout_s}s "
                "on the fabric") from e
        return (out, task.seconds, task.bytes_sent, task.bytes_received,
                task.worker_pid)


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
