"""Continuous batching for the serving front door.

The serve path's one-task-per-decode regime is exactly the fine-grained
task shape that drowns in per-task scheduling overhead (every decode
pays a full partition/validate/dispatch round trip for microseconds of
compute). A :class:`BatchCoalescer` amortises that: concurrent decode
requests from *many* tenants land in a bucket keyed by (code
fingerprint, shape signature), a short adaptive window collects them,
and the whole bucket dispatches as ONE fused task whose inputs are
stacked along a new leading batch axis — the per-task overhead is paid
once per batch instead of once per request.

Window semantics (the "adaptive" part):

  * a bucket flushes when its window elapses (``window_s`` after the
    first request arrived),
  * early when it reaches ``max_batch`` requests (``"full"``),
  * earlier still when the tightest per-request deadline minus the
    fused-execution EMA says waiting any longer would miss an SLO
    (``"deadline"``) — a near-SLO request forces the flush for the
    whole bucket.

Fair share: the fused task costs what one task costs; each participant
owes 1/k of it. Callers pass a ``charge(cost)`` callback per request
(typically wired to ``FairShare.charge``) and the coalescer invokes it
with ``fused_seconds / k`` after each flush.

Coalescing is only safe for steps that are *batchable*: deterministic,
side-effect-free, same code fingerprint, and row-independent along the
stacked axis (request i's output row must not depend on request j's
input row). The verifier's W070 flags SLOs on steps that cannot meet
this contract.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.runtime import Event


class CoalesceError(RuntimeError):
    """The fused execution failed; every participant sees the error."""


class _Ticket:
    """One request's slot in a pending batch."""

    __slots__ = ("value", "deadline_perf", "charge", "_done", "_result",
                 "_error", "submitted_t")

    def __init__(self, value, deadline_perf, charge):
        self.value = value
        self.deadline_perf = deadline_perf
        self.charge = charge
        self.submitted_t = time.perf_counter()
        self._done = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError("fused batch still executing")
        if self._error is not None:
            raise self._error
        return self._result

    def done(self) -> bool:
        return self._done.is_set()

    def _finish(self, result=None, error=None):
        self._result = result
        self._error = error
        self._done.set()


@dataclass
class _Bucket:
    key: Any
    created_t: float
    tickets: List[_Ticket] = field(default_factory=list)


class BatchCoalescer:
    """Collects per-request decode steps into fused batched dispatches.

    ``fuse_fn(key, stacked, k)`` executes the fused work — typically one
    runtime submission over a batched decode workflow — and returns an
    array (or sequence) whose leading axis is the batch axis; row ``i``
    fans back to request ``i``'s ticket. One daemon thread owns all
    flush timing, so a submitter that never calls ``result()`` cannot
    stall the bucket.
    """

    def __init__(self, fuse_fn: Callable[[Any, np.ndarray, int], Any], *,
                 window_s: float = 0.004, max_batch: int = 32,
                 metrics=None, tracer=None, name: str = "coalescer"):
        self.fuse_fn = fuse_fn
        self.window_s = window_s
        self.max_batch = max_batch
        self.metrics = metrics
        self.tracer = tracer
        self.name = name
        self.events: List[Event] = []    # park/flush timeline (thread-safe
                                         # appends; same Event type as runs)
        self._cond = threading.Condition()
        self._buckets: Dict[Any, _Bucket] = {}
        self._closed = False
        self._exec_ema = 0.0             # fused execution seconds
        self.flushes = 0
        self.coalesced = 0
        self.fused_requests = 0
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name=f"{name}-flush")
        self._thread.start()

    # ------------------------------------------------------------ submission
    def submit(self, key, value, *, deadline_s: Optional[float] = None,
               charge: Optional[Callable[[float], None]] = None) -> _Ticket:
        """Join the bucket for ``key``; returns a ticket whose
        ``result()`` yields this request's row of the fused output.
        ``deadline_s`` (relative) lets this request force an early flush;
        ``charge`` receives this request's 1/k share of the fused cost."""
        deadline_perf = None if deadline_s is None \
            else time.perf_counter() + deadline_s
        t = _Ticket(value, deadline_perf, charge)
        with self._cond:
            if self._closed:
                raise CoalesceError("coalescer is closed")
            b = self._buckets.get(key)
            if b is None:
                b = self._buckets[key] = _Bucket(key, time.perf_counter())
            b.tickets.append(t)
            pending = len(b.tickets)
            self.coalesced += 1
            self._cond.notify_all()
        if self.metrics is not None:
            self.metrics.inc("frontdoor.coalesced")
        info = {"key": str(key), "pending": pending}
        if deadline_s is not None:
            info["deadline_s"] = deadline_s
        now = time.perf_counter()
        self.events.append(Event("coalesce", "<batch>", "", now, info,
                                 time.time()))
        return t

    # ------------------------------------------------------------- flushing
    def _due_at(self, b: _Bucket) -> float:
        """Absolute perf_counter time this bucket must flush by."""
        if len(b.tickets) >= self.max_batch:
            return 0.0
        due = b.created_t + self.window_s
        deadlines = [t.deadline_perf for t in b.tickets
                     if t.deadline_perf is not None]
        if deadlines:
            # flush early enough that the fused execution (EMA) still
            # lands before the tightest participant deadline
            due = min(due, min(deadlines) - self._exec_ema)
        return due

    def _loop(self):
        while True:
            with self._cond:
                while not self._closed:
                    now = time.perf_counter()
                    due = [b for b in self._buckets.values()
                           if self._due_at(b) <= now]
                    if due:
                        break
                    horizon = min((self._due_at(b)
                                   for b in self._buckets.values()),
                                  default=None)
                    self._cond.wait(None if horizon is None
                                    else max(horizon - now, 0.0))
                if self._closed and not self._buckets:
                    return
                if self._closed:
                    due = list(self._buckets.values())
                for b in due:
                    self._buckets.pop(b.key, None)
            for b in due:
                self._flush(b)

    def _flush(self, b: _Bucket):
        k = len(b.tickets)
        if k == 0:
            return
        reason = "full" if k >= self.max_batch else (
            "deadline" if any(t.deadline_perf is not None
                              for t in b.tickets)
            and time.perf_counter() < b.created_t + self.window_s
            else "window")
        waited = time.perf_counter() - b.created_t
        stacked = np.stack([np.asarray(t.value) for t in b.tickets], axis=0)
        t0 = time.perf_counter()
        err: Optional[BaseException] = None
        out = None
        try:
            if self.tracer is not None and self.tracer.enabled:
                # umbrella span: the fused dispatch (and everything the
                # runtime nests under it) groups under one batch
                with self.tracer.span("fused_batch", cat="serve",
                                      track=f"coalescer:{self.name}",
                                      key=str(b.key), batch=k):
                    out = self.fuse_fn(b.key, stacked, k)
            else:
                out = self.fuse_fn(b.key, stacked, k)
        except BaseException as e:
            err = e
        seconds = time.perf_counter() - t0
        self._exec_ema = seconds if self._exec_ema == 0.0 \
            else 0.5 * seconds + 0.5 * self._exec_ema
        self.flushes += 1
        self.fused_requests += k
        if self.metrics is not None:
            self.metrics.inc("frontdoor.flushes")
            self.metrics.observe("frontdoor.fused_batch", k)
        now = time.perf_counter()
        self.events.append(Event(
            "flush", "<batch>", "", now,
            {"key": str(b.key), "batch": k, "waited_s": waited,
             "reason": reason, "seconds": seconds}, time.time()))
        share = seconds / k
        for i, t in enumerate(b.tickets):
            if t.charge is not None:
                try:
                    t.charge(share)      # 1/k of the fused cost
                except Exception:
                    pass                 # accounting must not fail requests
            if err is not None:
                t._finish(error=CoalesceError(
                    f"fused batch over {b.key!r} failed: {err!r}"))
            else:
                try:
                    t._finish(result=out[i])
                except BaseException as e:
                    t._finish(error=CoalesceError(
                        f"fused batch over {b.key!r} returned no row "
                        f"{i} of {k}: {e!r}"))

    # --------------------------------------------------------- introspection
    def introspect(self) -> dict:
        now = time.perf_counter()
        with self._cond:
            buckets = [{
                "key": str(b.key),
                "pending": len(b.tickets),
                "oldest_wait_s": now - b.created_t,
            } for b in self._buckets.values()]
        return {
            "name": self.name,
            "window_s": self.window_s,
            "max_batch": self.max_batch,
            "flushes": self.flushes,
            "coalesced": self.coalesced,
            "fused_requests": self.fused_requests,
            "avg_batch": (self.fused_requests / self.flushes)
            if self.flushes else 0.0,
            "exec_ema_s": self._exec_ema,
            "buckets": buckets,
        }

    # -------------------------------------------------------------- shutdown
    def close(self):
        """Flush everything still pending, then stop the flush thread."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout=30.0)
