"""Workflow model (paper §2/§3.1): steps, variables with scope, DAG.

The paper expresses workflows in WF/XAML with a ``migration`` attribute on
offloadable nodes. The JAX-native equivalent is a declarative Python DAG:

    wf = Workflow("AT")
    wf.var("model", scope=())          # workflow-level variable
    wf.step("forward", fn, inputs=("model",), outputs=("syn",))
    wf.step("misfit", fn2, inputs=("syn", "obs"), outputs=("chi",),
            remotable=True)

Steps may nest (``parent=``) — XAML's hierarchical nodes — and variables
carry a scope path used by the partitioner's Property-2 check. Dataflow
(read-after-write on variable URIs) defines the DAG; steps with no path
between them are *parallel* and may offload concurrently (paper Fig 9b).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclass
class Variable:
    name: str
    scope: Tuple[str, ...] = ()     # path of enclosing step names; () = top


@dataclass
class Step:
    name: str
    fn: Optional[Callable[..., Dict[str, Any]]] = None
    inputs: Tuple[str, ...] = ()
    outputs: Tuple[str, ...] = ()
    remotable: bool = False
    requires_local_hardware: bool = False      # Property 1 trigger
    parent: Optional[str] = None               # nesting (XAML hierarchy)
    jax_step: bool = True                      # fn is jax-traceable
    flops_hint: float = 0.0                    # cost-model hints
    bytes_hint: float = 0.0
    retries: int = 2                           # fault-tolerance budget
    remote_impl: Optional[str] = None          # fabric step-registry name
    # cross-run memoization override: True forces it on for this step,
    # False forces it off (e.g. a clock/RNG-reading step under a
    # memoize=True runtime), None defers to the manager-wide default.
    # Only set True for deterministic, side-effect-free steps.
    memoizable: Optional[bool] = None

    def scope(self, wf: "Workflow") -> Tuple[str, ...]:
        """Path of enclosing steps."""
        path = []
        p = self.parent
        while p is not None:
            path.append(p)
            p = wf.steps[p].parent
        return tuple(reversed(path))


def remotable(**hints):
    """Decorator marking a plain function's step defaults (API sugar)."""
    def wrap(fn):
        fn.__emerald_remotable__ = True
        fn.__emerald_hints__ = hints
        return fn
    return wrap


class WorkflowError(ValueError):
    pass


@dataclass
class Workflow:
    name: str
    steps: Dict[str, Step] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    variables: Dict[str, Variable] = field(default_factory=dict)

    # ------------------------------------------------------------- builders
    def var(self, name: str, scope: Tuple[str, ...] = ()) -> "Workflow":
        if name in self.variables:
            raise WorkflowError(f"variable {name} redefined")
        self.variables[name] = Variable(name, tuple(scope))
        return self

    def step(self, name: str, fn=None, *, inputs=(), outputs=(),
             remotable: Optional[bool] = None, parent=None, **kw) -> Step:
        if name in self.steps:
            raise WorkflowError(f"step {name} redefined")
        if parent is not None and parent not in self.steps:
            raise WorkflowError(f"unknown parent step {parent}")
        if remotable is None:
            remotable = bool(getattr(fn, "__emerald_remotable__", False))
        hints = dict(getattr(fn, "__emerald_hints__", {}))
        hints.update(kw)
        s = Step(name, fn, tuple(inputs), tuple(outputs), remotable,
                 parent=parent, **hints)
        self.steps[name] = s
        self.order.append(name)
        # implicitly declare output variables at the step's level
        for out in s.outputs:
            if out not in self.variables:
                self.variables[out] = Variable(out, s.scope(self))
        return s

    # ------------------------------------------------------------ structure
    def toplevel(self) -> List[Step]:
        return [self.steps[n] for n in self.order if self.steps[n].parent is None]

    def children_of(self, name: str) -> List[Step]:
        return [self.steps[n] for n in self.order if self.steps[n].parent == name]

    def descendants(self, name: str) -> List[Step]:
        out = []
        for c in self.children_of(name):
            out.append(c)
            out.extend(self.descendants(c.name))
        return out

    def dependencies(self) -> Dict[str, set]:
        """Dataflow DAG over top-level steps.

        Edges: read-after-write (a reader depends on the latest writer),
        write-after-write (a re-writer depends on the previous writer) and
        write-after-read (a re-writer depends on every reader of the
        previous version — otherwise a concurrent writer could clobber an
        earlier reader's input). All edges point from earlier to later
        steps in declaration order, so ``order`` is a valid topological
        order of this DAG.
        """
        deps: Dict[str, set] = {}
        last_writer: Dict[str, str] = {}
        readers: Dict[str, List[str]] = {}     # readers since the last write
        for s in self.toplevel():
            deps[s.name] = set()
            for v in s.inputs:
                if v in last_writer:
                    deps[s.name].add(last_writer[v])
                readers.setdefault(v, []).append(s.name)
            for v in s.outputs:
                if v in last_writer:          # write-after-write ordering
                    deps[s.name].add(last_writer[v])
                for r in readers.get(v, ()):  # write-after-read ordering
                    if r != s.name:
                        deps[s.name].add(r)
                readers[v] = []               # new version: no readers yet
                last_writer[v] = s.name
        return deps

    def successors(self, deps: Optional[Dict[str, set]] = None
                   ) -> Dict[str, set]:
        """Reverse adjacency of :meth:`dependencies` (step -> dependents).

        Pass a precomputed ``deps`` to avoid rebuilding the edge map.
        """
        deps = self.dependencies() if deps is None else deps
        succ: Dict[str, set] = {n: set() for n in deps}
        for n, ds in deps.items():
            for d in ds:
                succ[d].add(n)
        return succ

    def in_degrees(self, completed=(),
                   deps: Optional[Dict[str, set]] = None) -> Dict[str, int]:
        """Remaining-dependency counts, ignoring already-``completed`` steps."""
        done = set(completed)
        deps = self.dependencies() if deps is None else deps
        return {n: len(ds - done) for n, ds in deps.items() if n not in done}

    def validate_vars(self):
        for s in self.steps.values():
            for v in s.inputs:
                if v not in self.variables:
                    raise WorkflowError(
                        f"step {s.name} reads undeclared variable {v}")
