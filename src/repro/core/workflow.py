"""Workflow model (paper §2/§3.1): steps, variables with scope, DAG.

The paper expresses workflows in WF/XAML with a ``migration`` attribute on
offloadable nodes. The JAX-native equivalent is a declarative Python DAG:

    wf = Workflow("AT")
    wf.var("model", scope=())          # workflow-level variable
    wf.step("forward", fn, inputs=("model",), outputs=("syn",))
    wf.step("misfit", fn2, inputs=("syn", "obs"), outputs=("chi",),
            remotable=True)

Steps may nest (``parent=``) — XAML's hierarchical nodes — and variables
carry a scope path used by the partitioner's Property-2 check. Dataflow
(read-after-write on variable URIs) defines the DAG; steps with no path
between them are *parallel* and may offload concurrently (paper Fig 9b).
"""
from __future__ import annotations

import dataclasses
import sys
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


def _call_site(depth: int = 2) -> str:
    """``file:line`` of the caller ``depth`` frames up (best effort)."""
    try:
        f = sys._getframe(depth)
        return f"{f.f_code.co_filename}:{f.f_lineno}"
    except Exception:
        return ""


@dataclass
class Variable:
    name: str
    scope: Tuple[str, ...] = ()     # path of enclosing step names; () = top
    defined_at: str = ""            # "file:line" of the declaring call
    implicit: bool = False          # auto-declared as a step output (never
                                    # part of the workflow's input surface)


@dataclass(frozen=True)
class Fanout:
    """Data-parallel fan-out annotation for a step.

    A step carrying a ``Fanout`` never executes as declared: the
    partitioner expands it at submit time into one *scatter* step (runs
    ``partition_fn`` over each scattered input, publishing N independent
    content-addressed shard values ``uri#k``), N *shard* sub-steps (the
    original fn over its shard's slice plus the un-scattered broadcast
    inputs), and one *gather* step (``combine_fn`` over the shard
    outputs ``out#k``, publishing the step's declared outputs). Each
    shard is an independent ready task: it is placed, fair-share-charged,
    requeued on worker loss, and memoized (key = code fingerprint + that
    shard's input digest) on its own.

    ``scatter`` names which inputs are partitioned per shard (default:
    the first declared input); the rest broadcast whole to every shard.
    ``partition_fn(value, n)`` must return exactly ``n`` parts (default:
    row split along axis 0); ``combine_fn(parts)`` reassembles the shard
    outputs (default: row concatenation). Both should be module-level
    (picklable) functions so checkpoints and workers can carry them —
    the verifier's W061 flags closures/lambdas.
    """
    shards: int
    scatter: Tuple[str, ...] = ()
    partition_fn: Optional[Callable] = None
    combine_fn: Optional[Callable] = None


@dataclass
class Step:
    name: str
    fn: Optional[Callable[..., Dict[str, Any]]] = None
    inputs: Tuple[str, ...] = ()
    outputs: Tuple[str, ...] = ()
    remotable: bool = False
    requires_local_hardware: bool = False      # Property 1 trigger
    parent: Optional[str] = None               # nesting (XAML hierarchy)
    jax_step: bool = True                      # fn is jax-traceable
    flops_hint: float = 0.0                    # cost-model hints
    bytes_hint: float = 0.0
    retries: int = 2                           # fault-tolerance budget
    remote_impl: Optional[str] = None          # fabric step-registry name
    # cross-run memoization override: True forces it on for this step,
    # False forces it off (e.g. a clock/RNG-reading step under a
    # memoize=True runtime), None defers to the manager-wide default.
    # Only set True for deterministic, side-effect-free steps.
    memoizable: Optional[bool] = None
    # data-parallel fan-out (see Fanout): set on the user-declared step;
    # the partitioner's expansion replaces it with scatter/shard/gather
    # steps whose fanout_role/fanout_parent/shard_index identify them
    fanout: Optional[Fanout] = None
    fanout_role: str = ""                      # "" | scatter | shard | gather
    fanout_parent: str = ""                    # original step's name
    shard_index: int = -1                      # k for shard steps
    fanout_shards: int = 0                     # fan-out width N
    # serving front door: a preemptible step's in-flight broker task may
    # be checkpoint-aborted and requeued (attempt-free) when an
    # interactive tenant's SLO is threatened. Only long batch work
    # should opt in; the verifier's W071 gates fan-out legality.
    preemptible: bool = False
    # per-step latency SLO (interactive serving). Feeds the coalescer's
    # flush deadline and the admission queue's slack ordering; W070
    # flags it on steps the front door cannot actually batch.
    slo_ms: Optional[float] = None
    # staged-call parameter names, parallel to ``inputs``: execution
    # calls fn(**{arg_names[i]: value_of(inputs[i])}). None = inputs ARE
    # the parameter names (the default contract). Lets an expanded shard
    # step read ``P#3`` while its fn still receives ``P=``.
    arg_names: Optional[Tuple[str, ...]] = None
    # returned-dict keys, parallel to ``outputs``: the fn returns
    # {out_names[i]: value} and execution publishes it as outputs[i].
    # None = outputs ARE the returned keys. The shard twin of arg_names:
    # the original fn still returns {"out": ...}, published as out#3.
    out_names: Optional[Tuple[str, ...]] = None
    defined_at: str = ""                       # "file:line" of wf.step(...)

    def scope(self, wf: "Workflow") -> Tuple[str, ...]:
        """Path of enclosing steps."""
        path = []
        p = self.parent
        while p is not None:
            path.append(p)
            p = wf.steps[p].parent
        return tuple(reversed(path))


def remotable(**hints):
    """Decorator marking a plain function's step defaults (API sugar)."""
    def wrap(fn):
        fn.__emerald_remotable__ = True
        fn.__emerald_hints__ = hints
        return fn
    return wrap


class WorkflowError(ValueError):
    pass


@dataclass
class Workflow:
    name: str
    steps: Dict[str, Step] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    variables: Dict[str, Variable] = field(default_factory=dict)

    # ------------------------------------------------------------- builders
    def var(self, name: str, scope: Tuple[str, ...] = ()) -> "Workflow":
        site = _call_site()
        if name in self.variables:
            prev = self.variables[name].defined_at or "<unknown site>"
            raise WorkflowError(
                f"variable {name} redefined at {site or '<unknown site>'}; "
                f"first declared at {prev}")
        self.variables[name] = Variable(name, tuple(scope), defined_at=site)
        return self

    def step(self, name: str, fn=None, *, inputs=(), outputs=(),
             remotable: Optional[bool] = None, parent=None, **kw) -> Step:
        site = _call_site()
        if name in self.steps:
            prev = self.steps[name].defined_at or "<unknown site>"
            raise WorkflowError(
                f"step {name} redefined at {site or '<unknown site>'}; "
                f"first defined at {prev}")
        if parent is not None and parent not in self.steps:
            raise WorkflowError(f"unknown parent step {parent}")
        outputs = tuple(outputs)
        seen: set = set()
        for out in outputs:
            if out in seen:
                raise WorkflowError(
                    f"step {name} (at {site or '<unknown site>'}) declares "
                    f"output {out} more than once; a step publishes exactly "
                    "one version per output URI")
            seen.add(out)
        if remotable is None:
            remotable = bool(getattr(fn, "__emerald_remotable__", False))
        hints = dict(getattr(fn, "__emerald_hints__", {}))
        hints.update(kw)
        hints.setdefault("defined_at", site)
        s = Step(name, fn, tuple(inputs), outputs, remotable,
                 parent=parent, **hints)
        self.steps[name] = s
        self.order.append(name)
        # implicitly declare output variables at the step's level
        for out in s.outputs:
            if out not in self.variables:
                self.variables[out] = Variable(out, s.scope(self),
                                               defined_at=site,
                                               implicit=True)
        return s

    # ------------------------------------------------------------ structure
    def toplevel(self) -> List[Step]:
        return [self.steps[n] for n in self.order if self.steps[n].parent is None]

    def children_of(self, name: str) -> List[Step]:
        return [self.steps[n] for n in self.order if self.steps[n].parent == name]

    def descendants(self, name: str) -> List[Step]:
        out = []
        for c in self.children_of(name):
            out.append(c)
            out.extend(self.descendants(c.name))
        return out

    def dependencies(self, kinds: bool = False):
        """Dataflow DAG over top-level steps.

        Edges: read-after-write (a reader depends on the latest writer),
        write-after-write (a re-writer depends on the previous writer) and
        write-after-read (a re-writer depends on every reader of the
        previous version — otherwise a concurrent writer could clobber an
        earlier reader's input). All edges point from earlier to later
        steps in declaration order, so ``order`` is a valid topological
        order of this DAG.

        With ``kinds=True`` each edge carries its hazard kinds instead of
        being a bare name: ``{step: {dep: frozenset({"RAW","WAR","WW"})}}``.
        RAW edges are true dataflow; WAR/WW edges are anti-dependency
        fences the scheduler inserts to serialise conflicting versions.
        """
        kinded: Dict[str, Dict[str, set]] = {}
        last_writer: Dict[str, str] = {}
        readers: Dict[str, List[str]] = {}     # readers since the last write
        for s in self.toplevel():
            edges = kinded.setdefault(s.name, {})
            for v in s.inputs:
                if v in last_writer:
                    edges.setdefault(last_writer[v], set()).add("RAW")
                readers.setdefault(v, []).append(s.name)
            for v in s.outputs:
                if v in last_writer and last_writer[v] != s.name:
                    # write-after-write ordering
                    edges.setdefault(last_writer[v], set()).add("WW")
                for r in readers.get(v, ()):  # write-after-read ordering
                    if r != s.name:
                        edges.setdefault(r, set()).add("WAR")
                readers[v] = []               # new version: no readers yet
                last_writer[v] = s.name
        if kinds:
            return {n: {d: frozenset(ks) for d, ks in es.items()}
                    for n, es in kinded.items()}
        return {n: set(es) for n, es in kinded.items()}

    def successors(self, deps: Optional[Dict[str, set]] = None
                   ) -> Dict[str, set]:
        """Reverse adjacency of :meth:`dependencies` (step -> dependents).

        Pass a precomputed ``deps`` to avoid rebuilding the edge map.
        """
        deps = self.dependencies() if deps is None else deps
        succ: Dict[str, set] = {n: set() for n in deps}
        for n, ds in deps.items():
            for d in ds:
                succ[d].add(n)
        return succ

    def in_degrees(self, completed=(),
                   deps: Optional[Dict[str, set]] = None) -> Dict[str, int]:
        """Remaining-dependency counts, ignoring already-``completed`` steps."""
        done = set(completed)
        deps = self.dependencies() if deps is None else deps
        return {n: len(ds - done) for n, ds in deps.items() if n not in done}

    def validate_vars(self):
        for s in self.steps.values():
            for v in s.inputs:
                if v not in self.variables:
                    raise WorkflowError(
                        f"step {s.name} reads undeclared variable {v}")
