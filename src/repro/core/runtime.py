"""EmeraldRuntime — one long-lived scheduler serving many workflows.

The paper's Emerald offloads the steps of *one* workflow at a time; a
service absorbing heavy traffic must amortise the expensive parts — the
worker pool, warm compile caches, cloud-resident data — across
submissions instead of rebuilding them per run. The runtime is that
amortisation layer:

  * **one driver event loop** reacts to submissions and step completions
    for N concurrent workflows (a multi-run dispatcher keyed by run id),
  * **one offload/local lane pair** (thread pools sized once) is shared:
    idle lanes of one run absorb ready work from another, which is where
    the aggregate-throughput win over back-to-back ``run()`` calls comes
    from (inter-workflow parallelism),
  * **one MigrationManager** carries the compile cache and cost-model
    statistics across runs — the second submission of the same step is
    code-only and pre-measured,
  * **one MDSS** holds every run's data under a per-run namespace
    (``run_id/uri``), with shared-read of a common namespace for warm
    cross-run data (``publish``); ``RunHandle.release()`` drops a run's
    namespace at teardown,
  * **cross-run fair share** composes with the per-run critical-path
    priority: each free lane slot goes to the run with the smallest
    deficit-weighted share (``FairShare``), then that run's highest-cpl
    ready step dispatches — one wide workflow cannot starve the rest,
    and ``weight``/``priority`` let an interactive run overtake batch.

API::

    rt = EmeraldRuntime(manager)              # or EmeraldRuntime() to own one
    h1 = rt.submit(wf_a, {"x": xa})           # non-blocking
    h2 = rt.submit(wf_b, {"x": xb}, weight=2.0, priority=1)
    out = h1.result(); h2.cancel(); rt.close()

``EmeraldExecutor`` (core/executor.py) is now a thin compat shim over a
private runtime, so the single-workflow API and its semantics (events,
checkpoints, retries, speculation) are unchanged.

Per-run recovery semantics are inherited wholesale from the event-driven
executor: retry with tier fallback, straggler speculation with
version-fenced losers, incremental per-completion checkpoints, and
failure draining in-flight siblings before the run's handle fails —
without disturbing the other runs.

Placement is **locality-aware** when the run's policy exposes
``place()`` (``policy="locality"``): each ready step is scored per tier
as ``est_exec + est_transfer(bytes not already resident)``, the cheaper
tier picks the lane, and the full rationale (scores, stale bytes,
reason) is emitted as a ``place`` event at dispatch. Fair-share charging
uses the same score, so a run burning transfer budget pays for it.

Checkpoint *writes* run on a dedicated writer lane (one thread), never
on the driver: the driver freezes a consistent (completed, vars)
snapshot, queues the pickle, and coalesces further dirt until the write
lands. A per-run completion fence keeps ``result()`` from resolving
before the run's final checkpoint is durable, and a failed write still
fails that run (durability contract) without stalling other tenants.

Admission control: when the shared store carries a ``capacity_bytes``
ceiling, ``submit`` refuses new runs (:class:`AdmissionRefused`) once
residency crosses ``admission_headroom`` x capacity — backpressure at
the front door instead of an OOM mid-run. Per-run residency budgets
(``submit(residency_budget={...})``) bound a tenant's footprint per tier
with MDSS-side LRU eviction.
"""
from __future__ import annotations

import heapq
import itertools
import os
import pickle
import queue
import threading
import time
import warnings
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.mdss import MDSS
from repro.core.migration import MigrationManager, StepFailure
from repro.core.partitioner import PartitionedWorkflow, partition
from repro.core.scheduler import (POLICIES, FairShare, critical_path_lengths,
                                  make_policy)
from repro.core.tiers import default_tiers
from repro.core.workflow import Step, Workflow
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import Tracer, wall_now


@dataclass
class Event:
    kind: str          # dispatch | suspend | offload | resume | local |
                       # retry | speculate | prefetch | checkpoint |
                       # place | step_done | scatter | shard_done |
                       # gather — schema in repro.obs.events
    step: str
    tier: str = ""
    t: float = 0.0      # perf_counter: monotonic, for intra-process deltas
    info: dict = field(default_factory=dict)
    t_wall: float = 0.0  # wall-clock epoch seconds: cross-process timeline


class WorkflowFailure(RuntimeError):
    pass


class AdmissionRefused(RuntimeError):
    """submit() refused: the shared store is at/near its capacity
    ceiling. Release namespaces, raise ``MDSS.capacity_bytes``, or
    retry after eviction frees residency."""


class RunCancelled(RuntimeError):
    """The run was cancelled before completing."""


class RuntimeClosed(RuntimeError):
    """The runtime shut down before the run completed."""


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
class RunCheckpointer:
    """Per-run incremental checkpoint state (cache + pickle snapshots).

    ``EmeraldExecutor`` inherits these methods unchanged; the runtime
    creates one per submission when it owns checkpointing. The cache is
    fed ONLY from init/resume vars and the outputs of harvested
    completions — a checkpoint can never capture the published outputs of
    a step that is still in flight (which resume would then double-apply
    on a non-idempotent step).
    """

    def __init__(self, mdss, wf: Workflow, checkpoint_dir: Optional[str],
                 ckpt_name: Optional[str] = None):
        self.mdss = mdss
        self.wf = wf
        self.checkpoint_dir = checkpoint_dir
        self.ckpt_name = ckpt_name or wf.name
        # uri -> (version, host snapshot)
        self._ckpt_cache: Dict[str, tuple] = {}
        # (completed, vars) frozen by the driver for the async writer —
        # see _freeze
        self._pending: Optional[tuple] = None

    def _emit(self, kind, step, tier="", **info):   # rebound by the runtime
        pass

    def _ckpt_path(self):
        return os.path.join(self.checkpoint_dir, f"{self.ckpt_name}.wfckpt")

    def _cache_var(self, uri: str):
        """Snapshot ``uri``'s freshest value into the checkpoint cache
        (skip if the cached version is already current). Uses a reference
        read (``peek_latest``) — no cross-tier transfer lands on the
        driver thread for checkpointing."""
        val, ver = self.mdss.peek_latest(uri)
        if ver and self._ckpt_cache.get(uri, (0, None))[0] != ver:
            self._ckpt_cache[uri] = (ver, jax.tree.map(np.asarray, val))

    def _cache_outputs(self, harvested: Step):
        """Snapshot a harvested step's outputs into the checkpoint cache.

        Must run BEFORE the step's successors dispatch: the outputs are
        final right now (WAW/WAR edges keep any later writer blocked until
        this harvest), so the reference read snapshots exactly what was
        published — no transfer involved. The pickle write itself
        (``_save_checkpoint``) has no ordering constraint and runs after
        dispatch, off the critical path.
        """
        if self.checkpoint_dir:
            for uri in harvested.outputs:
                self._cache_var(uri)

    def _freeze(self, completed):
        """Driver-side: freeze the (completed, vars) pair the NEXT
        ``_save_checkpoint`` will write. The write itself runs on the
        runtime's checkpoint lane, concurrent with the driver caching
        later completions into ``_ckpt_cache`` — without this snapshot
        the pickle could capture an output whose step is absent from
        ``completed``, and resume would double-apply it."""
        self._pending = (sorted(completed),
                         {uri: val
                          for uri, (_, val) in self._ckpt_cache.items()})

    def _save_checkpoint(self, completed):
        if not self.checkpoint_dir:
            return
        pend, self._pending = self._pending, None
        if pend is None:     # direct (synchronous) caller: live cache
            pend = (sorted(completed),
                    {uri: val for uri, (_, val) in self._ckpt_cache.items()})
        names, snapshot = pend
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        tmp = self._ckpt_path() + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump({"completed": list(names), "vars": snapshot}, f)
        os.replace(tmp, self._ckpt_path())
        self._emit("checkpoint", "<workflow>", n=len(names))

    def _load_checkpoint(self):
        if not self.checkpoint_dir or not os.path.exists(self._ckpt_path()):
            return None
        with open(self._ckpt_path(), "rb") as f:
            return pickle.load(f)


# --------------------------------------------------------------------------
# run handle
# --------------------------------------------------------------------------
class RunHandle:
    """Client-side view of one submitted workflow run."""

    def __init__(self, run_id: str, namespace: str, runtime: "EmeraldRuntime",
                 events: List[Event]):
        self.run_id = run_id
        self.namespace = namespace
        self.events = events
        self.findings = []          # verifier findings (submit(validate=...))
        self._runtime = runtime
        self._done = threading.Event()
        self._result: Optional[dict] = None
        self._error: Optional[BaseException] = None
        # True while the submission sits in the front door's admission
        # queue (submit(park=True) under capacity pressure); cleared by
        # the drain loop when the run is admitted
        self._parked = False
        # set (at most once, BEFORE the run is enqueued) by the runtime:
        # fires on any terminal state — result, failure, cancel
        self._on_done = None
        # a private runtime to close synchronously inside result() (the
        # compat shim's pools-shut-before-run-returns contract); wait()/
        # state users fall back to the _on_done reaper
        self._close_on_result: Optional["EmeraldRuntime"] = None

    # ------------------------------------------------------------ lifecycle
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> dict:
        """Block for the run's re-integrated variables (or its failure)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"run {self.run_id} still executing")
        if self._close_on_result is not None:
            self._close_on_result.close()       # idempotent
        if self._error is not None:
            raise self._error
        return self._result

    def cancel(self):
        """Request cancellation: queued steps are abandoned, in-flight
        steps drain, then ``result`` raises :class:`RunCancelled`."""
        self._runtime._inbox.put(("cancel", self.run_id))

    def release(self):
        """Drop this run's MDSS namespace (teardown of its data).

        Returns ``(entries_dropped, resident_bytes_freed)``; a no-op for
        un-namespaced (compat shim) runs."""
        if not self.namespace:
            return (0, 0)
        out = self._runtime.mdss.drop_namespace(self.namespace)
        # freed residency may admit a parked run right now
        self._runtime._nudge()
        return out

    @property
    def state(self) -> str:
        if not self._done.is_set():
            return "parked" if self._parked else "running"
        if isinstance(self._error, RunCancelled):
            return "cancelled"
        return "failed" if self._error is not None else "done"

    def _finish(self, result=None, error=None):
        self._result = result
        self._error = error
        self._done.set()
        if self._on_done is not None:
            try:
                self._on_done(self)
            except Exception:
                pass   # a teardown hook must never poison the finalizer


# --------------------------------------------------------------------------
# internal per-run state
# --------------------------------------------------------------------------
@dataclass
class _Run:
    run_id: str
    ns: str
    handle: RunHandle
    wf: Workflow
    steps: Dict[str, Step]
    succs: Dict[str, set]
    indeg: Dict[str, int]
    order_idx: Dict[str, int]
    completed: set
    mdss: Any                       # NamespacedMDSS or base MDSS
    policy: Any
    fetch: Any
    checkpointer: Optional[RunCheckpointer]
    weight: float
    priority: int
    speculate_after: Optional[float]
    prefetch: bool
    events: List[Event]
    lock: threading.Lock = field(default_factory=threading.Lock)
    ready: Dict[bool, list] = field(
        default_factory=lambda: {True: [], False: []})   # keyed by offloaded?
    inflight: int = 0
    failures: List[BaseException] = field(default_factory=list)
    cancelled: bool = False
    ckpt_dirty: bool = False
    ckpt_inflight: int = 0          # writes queued on the checkpoint lane
    placements: Dict[str, Any] = field(default_factory=dict)
    placed: Dict[str, str] = field(default_factory=dict)  # step -> tier
    retries: int = 0
    # wall/monotonic epoch pair fixed at submission: every event's
    # t_wall = epoch_wall + (t - epoch_perf), so driver events land on
    # the same epoch timeline as worker-reported phases (satellite: the
    # old perf_counter-only Event was incomparable across processes)
    epoch_wall: float = field(default_factory=time.time)
    epoch_perf: float = field(default_factory=time.perf_counter)
    root_ctx: Any = None            # (trace_id, span_id) of the run span
    # per fan-out parent: the "fanout" span identity allocated when the
    # scatter step dispatches, so every shard/gather dispatch span nests
    # under one umbrella in the trace; recorded (and popped) when the
    # gather completes. fanout_t0 holds the matching wall start.
    fanout_ctx: Dict[str, Any] = field(default_factory=dict)
    fanout_t0: Dict[str, float] = field(default_factory=dict)
    # serving-front-door state: an absolute perf_counter deadline plus a
    # per-run SLO (ms). When the deadline's slack shrinks below the SLO
    # while ready work is still waiting for a lane, the driver preempts
    # the longest-running preemptible batch task (once per run).
    slo_ms: Optional[float] = None
    deadline_perf: Optional[float] = None
    preempt_fired: bool = False
    # seconds this run waited parked; credited as a fair-share deficit at
    # admission so near-SLO latecomers overtake long-resident tenants
    admit_credit: float = 0.0

    def emit(self, kind, step, tier="", **info):
        t = time.perf_counter()
        with self.lock:
            self.events.append(Event(kind, step, tier, t, info,
                                     self.epoch_wall + (t - self.epoch_perf)))


@dataclass
class _Parked:
    """One submission waiting in the front door's admission queue.

    Everything ``_materialize`` needs to turn it into a live ``_Run`` is
    carried here verbatim from ``submit``; validation already ran at park
    time (a rejected workflow is refused immediately, it never parks),
    and NO runtime state — reservations, namespace budgets, init_vars —
    lands until admission, so cancelling or failing a parked entry needs
    no rollback (the symmetric-release contract the admission paths
    share)."""
    handle: RunHandle
    pwf: PartitionedWorkflow
    wf: Workflow
    run_id: str
    ns: str
    mdss: Any
    init_vars: Optional[Dict[str, Any]]
    residency_budget: Optional[Dict[str, int]]
    declared: int
    policy: Optional[str]
    fetch: Any
    resume: bool
    weight: float
    priority: int
    speculate_after: Any
    prefetch: Optional[bool]
    checkpointer: Optional[RunCheckpointer]
    reason: str                     # capacity | budget | run_slots
    seq: int                        # FIFO tiebreak among equal deadlines
    parked_t: float                 # perf_counter at park time
    slo_ms: Optional[float] = None
    deadline_perf: Optional[float] = None
    preempt_fired: bool = False


def _park_order(p: _Parked) -> tuple:
    """Drain order: oldest (smallest) absolute deadline first, then FIFO.
    Strict head-of-queue admission — a later small run never bypasses the
    head (that bypass is exactly the H125 starvation shape)."""
    return (p.deadline_perf if p.deadline_perf is not None else float("inf"),
            p.seq)


_AUTO = object()


# --------------------------------------------------------------------------
# the runtime
# --------------------------------------------------------------------------
class EmeraldRuntime:
    """Long-lived multi-tenant scheduler over one shared fabric + MDSS."""

    def __init__(self, manager: Optional[MigrationManager] = None, *,
                 tiers=None, policy: str = "annotate",
                 cloud_tier: str = "cloud", max_workers: int = 8,
                 local_workers: int = 4,
                 speculate_after: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None, prefetch: bool = True,
                 shared_namespace: str = "shared", name: str = "emerald",
                 admission_headroom: float = 0.9,
                 park_limit: int = 64,
                 max_active_runs: Optional[int] = None,
                 memoize: Optional[bool] = None,
                 telemetry: bool = True,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 dispatch_hook=None):
        if manager is None:
            tiers = tiers or default_tiers()
            cm = CostModel(tiers)
            manager = MigrationManager(tiers, MDSS(tiers, cost_model=cm), cm)
        assert policy in POLICIES
        self.manager = manager
        self.mdss = manager.mdss                 # the shared base store
        # telemetry=False turns tracing AND metrics into no-ops (one
        # boolean check per call site) for minimum-overhead runs; pass a
        # shared Tracer/MetricsRegistry to aggregate across runtimes
        self.telemetry = telemetry
        self.tracer = tracer if tracer is not None else Tracer(
            enabled=telemetry)
        self.metrics = metrics if metrics is not None else MetricsRegistry(
            enabled=telemetry)
        manager.tracer = self.tracer
        manager.register_metrics(self.metrics)
        self.mdss.register_metrics(self.metrics)
        self.default_policy = policy
        self.cloud_tier = cloud_tier
        self.max_workers = max_workers
        self.local_workers = local_workers
        self.speculate_after = speculate_after
        self.checkpoint_dir = checkpoint_dir
        self.prefetch = prefetch
        self.shared_namespace = shared_namespace
        self.name = name
        self.admission_headroom = admission_headroom
        # serving front door: the bounded admission (parking) queue.
        # submit(park=True) parks instead of raising AdmissionRefused
        # when capacity is tight; the driver drains it oldest-deadline-
        # first as capacity frees. queue_full is the only hard refusal.
        self.park_limit = park_limit
        # optional cap on concurrently admitted runs (the "lane
        # capacity" admission signal — None = unbounded, the pre-front-
        # door behaviour); counted by _live under _runs_lock
        self.max_active_runs = max_active_runs
        self._parked: List[_Parked] = []         # guarded by _runs_lock
        self._park_seq = itertools.count(1)
        self._live = 0                           # admitted, unfinalized runs
        self.parked_total = 0
        self.admitted_total = 0
        self._coalescers: List[Any] = []         # introspection attach point
        if memoize is not None:
            # cross-run step memoization (manager-wide): two tenants
            # submitting identical step code over content-identical
            # inputs share one execution. Only for deterministic steps —
            # see MigrationManager; Step.memoizable overrides per step.
            self.manager.memoize = memoize

        # schedule-exploration seam (emcheck): when set, the hook is
        # offered every dispatch choice — hook(lane, sorted run_ids) ->
        # chosen run_id or None to defer to fair share. Runs on the
        # driver thread; production leaves it None.
        self.dispatch_hook = dispatch_hook
        self._fair = FairShare()
        self._inbox: "queue.SimpleQueue" = queue.SimpleQueue()
        self._runs: Dict[str, _Run] = {}
        self._runs_lock = threading.Lock()       # _runs snapshot for stats
        # run_id -> (namespace, declared residency budget): admitted-but-
        # unfilled budgets count against remaining capacity at the front
        # door, so admission is budget-aware, not just occupancy-aware
        self._reserved: Dict[str, tuple] = {}
        self._busy = {True: 0, False: 0}         # keyed by offloaded?
        # (run_id, step) pairs granted a lane and not yet harvested — the
        # guard that makes a duplicate/late "done" (e.g. a speculation
        # loser surfacing after the winner) a no-op instead of a
        # double-decrement of lane slots and successor in-degrees
        self._outstanding: set = set()
        self._slots = {True: max_workers, False: local_workers}
        self._counter = itertools.count(1)
        self._closed = False
        self._close_lock = threading.Lock()
        self._close_done = threading.Event()
        self._draining = False
        self.runs_completed = 0
        self._fabric = None

        m = self.metrics
        m.gauge("runtime.active_runs", self.active_runs)
        m.gauge("runtime.offload_backlog", self.offload_backlog)
        m.gauge("runtime.lane_busy.offload", lambda: self._busy[True])
        m.gauge("runtime.lane_busy.local", lambda: self._busy[False])
        m.gauge("runtime.runs_completed", lambda: self.runs_completed)
        m.gauge("scheduler.fair_share", self._fair.shares)
        m.gauge("frontdoor.parked_depth", lambda: len(self._parked))
        m.gauge("frontdoor.parked_total", lambda: self.parked_total)
        m.gauge("frontdoor.admitted_total", lambda: self.admitted_total)

        self._offload_pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix=f"{name}-offload")
        self._local_pool = ThreadPoolExecutor(
            max_workers=local_workers, thread_name_prefix=f"{name}-local")
        # re-integration fetches run here so a slow cloud->local sync
        # never stalls the driver (and with it every other run's dispatch)
        self._misc_pool = ThreadPoolExecutor(
            max_workers=2, thread_name_prefix=f"{name}-finalize")
        # dedicated checkpoint writer lane: pickle writes must never
        # serialise the driver loop (one slow-disk tenant would stall
        # every other run's dispatch); one thread keeps per-run write
        # order trivially FIFO
        self._ckpt_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"{name}-ckpt")
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name=f"{name}-driver")
        self._driver.start()

    # ------------------------------------------------------------------ api
    def submit(self, workflow, init_vars: Optional[Dict[str, Any]] = None, *,
               policy: Optional[str] = None, fetch=None, resume: bool = False,
               weight: float = 1.0, priority: int = 0,
               namespace: Optional[str] = None,
               residency_budget: Optional[Dict[str, int]] = None,
               speculate_after=_AUTO, prefetch: Optional[bool] = None,
               checkpointer: Optional[RunCheckpointer] = None,
               events: Optional[List[Event]] = None,
               on_done=None, validate: str = "error",
               park: bool = False, deadline_s: Optional[float] = None,
               slo_ms: Optional[float] = None) -> RunHandle:
        """Enqueue a workflow for concurrent execution (non-blocking).

        ``workflow`` may be a :class:`Workflow` (partitioned here) or an
        already-partitioned :class:`PartitionedWorkflow`. ``namespace``
        defaults to a fresh ``runN`` namespace (pass an explicit one to
        resubmit into warm per-run data, or ``""`` to address the base
        store un-namespaced — the compat shim's mode). ``weight`` is the
        fair-share knob (2.0 = twice the lane share under contention);
        ``priority`` is the fabric dispatch class (higher overtakes lower
        in the broker queue). ``residency_budget`` maps tier name ->
        max resident bytes for this run's namespace (MDSS evicts LRU
        entries back to local past the budget). Raises
        :class:`AdmissionRefused` when the shared store is within
        ``admission_headroom`` of its ``capacity_bytes`` ceiling, OR when
        the submission's declared ``residency_budget`` does not fit the
        *remaining* capacity — current residency plus the still-unfilled
        declared budgets of every admitted run — so a burst of small-now
        grow-later tenants is refused up front instead of thrashing the
        evictor mid-run. Returns a :class:`RunHandle`.

        ``validate`` runs the static verifier (``repro.analysis``) at
        admission: ``"error"`` (default) raises
        :class:`~repro.analysis.WorkflowRejected` on error-severity
        findings before any state is touched, ``"warn"`` admits and
        records every finding on ``handle.findings`` (plus a
        ``UserWarning`` when errors were found), ``"off"`` skips the
        pass. Warnings/infos never block in any mode.

        ``park=True`` turns every capacity refusal into a *parked*
        submission instead: the handle returns immediately in state
        ``"parked"`` and the driver's drain loop admits it (oldest
        ``deadline_s`` first, FIFO within equal deadlines) once
        residency reservations and run slots free up. A full parking
        queue (``park_limit``) is then the only hard refusal. ``slo_ms``
        arms SLO protection: when a parked (or admitted, lane-starved)
        interactive run's deadline slack shrinks below its SLO, the
        driver checkpoint-aborts the longest-running preemptible batch
        task so the decode path holds its p99.
        """
        if self._closed:
            raise RuntimeClosed("runtime is closed")
        park_reason = None
        if self.mdss.over_capacity(self.admission_headroom):
            if not park:
                raise AdmissionRefused(
                    f"shared store holds {self.mdss.resident_bytes()} of "
                    f"{self.mdss.capacity_bytes} capacity bytes (headroom "
                    f"{self.admission_headroom:.0%}): submission refused")
            park_reason = "capacity"
        if resume and namespace is None:
            # a fresh auto namespace has no prior state OR checkpoint to
            # resume from — silently re-running the whole DAG (including
            # non-idempotent completed steps) is the failure checkpoints
            # exist to prevent, so demand the original namespace
            raise ValueError(
                "resume=True needs the namespace of the run being resumed "
                "(auto namespaces are fresh per submission)")
        pwf = workflow if isinstance(workflow, PartitionedWorkflow) \
            else partition(workflow)
        wf = pwf.workflow
        n = next(self._counter)
        run_id = f"{wf.name}#{n}"
        ns = f"run{n}" if namespace is None else namespace
        mdss = self.mdss if ns == "" else self.mdss.namespaced(
            ns, shared=self.shared_namespace)
        if residency_budget and not ns:
            raise ValueError(
                "residency_budget needs a namespaced run (an "
                "un-namespaced submission shares the base store)")
        declared = sum(residency_budget.values()) if residency_budget else 0
        deadline_perf = None if deadline_s is None \
            else time.perf_counter() + deadline_s
        limit = self.admission_headroom * self.mdss.capacity_bytes \
            if declared and self.mdss.capacity_bytes else None
        with self._runs_lock:
            if self.max_active_runs is not None \
                    and self._live >= self.max_active_runs:
                if not park:
                    raise AdmissionRefused(
                        f"{self._live} of {self.max_active_runs} run slots "
                        "busy: submission refused")
                park_reason = park_reason or "run_slots"
            if limit is not None and park_reason is None:
                # check + reserve atomically: two concurrent submits that
                # each fit alone but not together must not both pass. An
                # admitted run's unfilled declared budget is capacity it
                # may still legitimately consume.
                reserved = sum(
                    max(0, decl - self.mdss.namespace_resident_bytes(rns))
                    for rns, decl in self._reserved.values())
                committed = self.mdss.resident_bytes() + reserved
                if committed + declared > limit:
                    if not park:
                        raise AdmissionRefused(
                            f"declared residency budget {declared} does not "
                            f"fit remaining capacity ({committed} of "
                            f"{limit:.0f} already committed by residency + "
                            "admitted budgets)")
                    park_reason = "budget"
            if park_reason is None:
                if limit is not None:
                    self._reserved[run_id] = (ns, declared)
                self._live += 1
        if park_reason is not None:
            return self._park(
                pwf, wf, run_id, ns, mdss, init_vars, residency_budget,
                declared, policy, fetch, resume, weight, priority,
                speculate_after, prefetch, checkpointer, events, on_done,
                validate, park_reason, deadline_s, deadline_perf, slo_ms)
        try:
            return self._submit_admitted(
                pwf, wf, run_id, ns, mdss, init_vars, residency_budget,
                policy, fetch, resume, weight, priority, speculate_after,
                prefetch, checkpointer, events, on_done, validate,
                slo_ms=slo_ms, deadline_perf=deadline_perf)
        except BaseException:
            # anything that fails between admission and the driver taking
            # ownership must release the reservation — a leak here would
            # shrink admission capacity forever. The run-slot count
            # releases symmetrically (same lock, same path) so a rejected
            # submission can never wedge the front door shut.
            with self._runs_lock:
                self._reserved.pop(run_id, None)
                self._live -= 1
            raise

    def _park(self, pwf, wf, run_id, ns, mdss, init_vars, residency_budget,
              declared, policy, fetch, resume, weight, priority,
              speculate_after, prefetch, checkpointer, events, on_done,
              validate, reason, deadline_s, deadline_perf, slo_ms
              ) -> RunHandle:
        """Park a submission the capacity checks refused. Validation runs
        FIRST — before the entry lands anywhere — so a rejected workflow
        is refused outright and a parked entry needs no rollback ever:
        no reservation, namespace budget, or init_vars put exists until
        the drain loop admits it."""
        findings = self._validate_submission(
            wf, mdss, init_vars, residency_budget, resume, validate)
        sink = events if events is not None else []
        handle = RunHandle(run_id, ns, self, sink)
        handle.findings = findings
        handle._on_done = on_done
        handle.trace_id = run_id
        handle._parked = True
        entry = _Parked(
            handle=handle, pwf=pwf, wf=wf, run_id=run_id, ns=ns, mdss=mdss,
            init_vars=init_vars, residency_budget=residency_budget,
            declared=declared, policy=policy, fetch=fetch, resume=resume,
            weight=weight, priority=priority, speculate_after=speculate_after,
            prefetch=prefetch, checkpointer=checkpointer, reason=reason,
            seq=next(self._park_seq), parked_t=time.perf_counter(),
            slo_ms=slo_ms, deadline_perf=deadline_perf)
        with self._runs_lock:
            if len(self._parked) >= self.park_limit:
                self.metrics.inc("frontdoor.queue_full")
                raise AdmissionRefused(
                    f"queue_full: admission queue holds {len(self._parked)} "
                    f"of {self.park_limit} parked submissions")
            self._parked.append(entry)
            depth = len(self._parked)
            self.parked_total += 1
        info = {"reason": reason, "depth": depth}
        if deadline_s is not None:
            info["deadline_s"] = deadline_s
        if slo_ms is not None:
            info["slo_ms"] = slo_ms
        t = time.perf_counter()
        sink.append(Event("park", "<workflow>", "", t, info, time.time()))
        # wake the driver for an immediate drain attempt (capacity may
        # already suffice — e.g. park under run-slot pressure that a
        # finalize just relieved)
        self._nudge()
        if self._closed and not self._driver.is_alive():
            # close() fully raced this park: nobody will ever drain it
            self._fail_parked(RuntimeClosed("runtime closed"))
        return handle

    def _submit_admitted(self, pwf, wf, run_id, ns, mdss, init_vars,
                         residency_budget, policy, fetch, resume, weight,
                         priority, speculate_after, prefetch, checkpointer,
                         events, on_done, validate="error", slo_ms=None,
                         deadline_perf=None) -> RunHandle:
        if residency_budget:
            for tier_name, max_bytes in residency_budget.items():
                self.mdss.set_namespace_budget(ns, tier_name, max_bytes)
        try:
            findings = self._validate_submission(
                wf, mdss, init_vars, residency_budget, resume, validate)
        except BaseException:
            # a rejected submission must leave no trace: clear the
            # budgets this call just configured (nothing else landed yet
            # — validation runs before the init_vars puts)
            for tier_name in (residency_budget or ()):
                self.mdss.set_namespace_budget(ns, tier_name, None)
            raise
        sink = events if events is not None else []
        handle = RunHandle(run_id, ns, self, sink)
        handle.findings = findings
        # installed before the run can possibly finalize — no TOCTOU
        handle._on_done = on_done
        handle.trace_id = run_id
        self._materialize(pwf, wf, run_id, ns, mdss, init_vars, resume,
                          policy, fetch, weight, priority, speculate_after,
                          prefetch, checkpointer, handle, sink, slo_ms,
                          deadline_perf)
        return handle

    def _materialize(self, pwf, wf, run_id, ns, mdss, init_vars, resume,
                     policy, fetch, weight, priority, speculate_after,
                     prefetch, checkpointer, handle, sink, slo_ms,
                     deadline_perf) -> "_Run":
        completed: set = set()
        for uri, val in (init_vars or {}).items():
            if uri not in wf.variables:
                wf.var(uri)
            mdss.put(uri, val, tier="local")
        if checkpointer is None and self.checkpoint_dir:
            checkpointer = RunCheckpointer(
                mdss, wf, self.checkpoint_dir,
                ckpt_name=f"{ns}.{wf.name}" if ns else wf.name)
        if resume and checkpointer is not None:
            state = checkpointer._load_checkpoint()
            if state is not None:
                completed = set(state["completed"])
                for uri, val in state["vars"].items():
                    mdss.put(uri, val, tier="local")
        if checkpointer is not None and checkpointer.checkpoint_dir:
            # seed from EVERY resident variable (init/resume vars and state
            # carried over from previous runs in this namespace): nothing
            # is in flight yet, so everything resident is completed work.
            # Variables currently resolving to the SHARED namespace are
            # not this run's state and are skipped — checkpointing them
            # would make resume write private (stale, re-staged) copies
            # of data meant to be stored once and read live.
            for uri in wf.variables:
                if not mdss.version(uri):
                    continue
                if getattr(mdss, "resolves_shared", None) is not None \
                        and mdss.resolves_shared(uri):
                    continue
                checkpointer._cache_var(uri)

        steps = {s.name: s for s in wf.toplevel()}
        completed &= set(steps)
        deps = wf.dependencies()
        succs = wf.successors(deps=deps)
        indeg = wf.in_degrees(completed, deps=deps)
        order_idx = {nm: i for i, nm in enumerate(wf.order)}
        run_policy = make_policy(policy or self.default_policy,
                                 self.manager.cost_model, mdss,
                                 self.cloud_tier)
        if hasattr(run_policy, "set_priorities"):
            run_policy.set_priorities(critical_path_lengths(
                wf, self.manager.cost_model, self.cloud_tier, succ=succs))

        # one trace per run: the root "run" span's identity is allocated
        # now (so every child can parent to it) and recorded at finalize
        root_ctx = (run_id, self.tracer.next_id()) \
            if self.tracer.enabled else None
        run = _Run(run_id=run_id, ns=ns, handle=handle, wf=wf, steps=steps,
                   succs=succs, indeg=indeg, order_idx=order_idx,
                   completed=completed, mdss=mdss, policy=run_policy,
                   fetch=fetch, checkpointer=checkpointer, weight=weight,
                   priority=priority,
                   speculate_after=self.speculate_after
                   if speculate_after is _AUTO else speculate_after,
                   prefetch=self.prefetch if prefetch is None else prefetch,
                   events=sink, root_ctx=root_ctx, slo_ms=slo_ms,
                   deadline_perf=deadline_perf)
        handle.epoch_wall = run.epoch_wall
        if checkpointer is not None:
            checkpointer._emit = run.emit
        self._inbox.put(("submit", run))
        # close() may have fully raced this submit (entry check passed,
        # driver already exited): nobody will consume the message, so
        # flush it ourselves — the handle resolves instead of hanging
        if self._closed and not self._driver.is_alive():
            self._flush_orphaned_inbox()
        return run

    def _validate_submission(self, wf, mdss, init_vars, residency_budget,
                             resume, validate):
        """Admission-time static verification (repro.analysis). Runs
        before ANY submission state lands (budgets, init_vars puts), so
        a rejection leaves the runtime and store untouched."""
        if validate not in ("error", "warn", "off"):
            raise ValueError(
                f"validate must be 'error', 'warn' or 'off', "
                f"not {validate!r}")
        if validate == "off":
            return []
        from repro.analysis.verifier import WorkflowRejected, verify
        provided = None
        if not resume:
            # the bound set: explicit init vars plus whatever is already
            # resident for this run's namespace (warm resubmission /
            # shared-namespace fall-through)
            provided = set(init_vars or ())
            provided |= {u for u in wf.variables
                         if u not in provided and mdss.version(u)}
        findings = verify(wf, provided=provided,
                          residency_budget=residency_budget,
                          tiers=self.manager.tiers,
                          capacity_bytes=self.mdss.capacity_bytes)
        errors = [f for f in findings if f.severity == "error"]
        if errors:
            if validate == "error":
                self.metrics.inc("runtime.submissions_rejected")
                raise WorkflowRejected(wf.name, findings)
            warnings.warn(
                f"emerald verifier: workflow {wf.name!r} admitted with "
                f"{len(errors)} error-severity finding(s) "
                f"(validate='warn'): "
                + "; ".join(f"{f.rule} {f.message}" for f in errors),
                stacklevel=3)
        return findings

    # ------------------------------------------------------ admission queue
    def _nudge(self):
        """Wake the driver for a drain attempt (freed residency or a
        released namespace can admit parked runs). Safe from any thread;
        a dead driver ignores it via the orphan flush."""
        if not self._closed and self._driver.is_alive():
            self._inbox.put(("nudge",))

    def _fits_locked(self, declared: int) -> bool:
        """Would a submission with ``declared`` budget bytes be admitted
        right now? Caller holds ``_runs_lock`` (same atomic
        check-then-reserve discipline as ``submit``)."""
        if self.max_active_runs is not None \
                and self._live >= self.max_active_runs:
            return False
        if self.mdss.over_capacity(self.admission_headroom):
            return False
        if declared and self.mdss.capacity_bytes:
            limit = self.admission_headroom * self.mdss.capacity_bytes
            reserved = sum(
                max(0, decl - self.mdss.namespace_resident_bytes(rns))
                for rns, decl in self._reserved.values())
            if self.mdss.resident_bytes() + reserved + declared > limit:
                return False
        return True

    def _drain_parked(self):
        """Driver-side: admit parked submissions oldest-deadline-first
        while the head fits. Strictly head-of-queue — when the head does
        not fit, nothing behind it is considered (a smaller latecomer
        bypassing the head is the H125 starvation hazard)."""
        if self._draining:
            return
        while True:
            with self._runs_lock:
                if not self._parked:
                    return
                p = min(self._parked, key=_park_order)
                if not self._fits_locked(p.declared):
                    return
                self._parked.remove(p)
                if p.declared and self.mdss.capacity_bytes:
                    self._reserved[p.run_id] = (p.ns, p.declared)
                self._live += 1
                depth = len(self._parked)
            try:
                self._admit_parked(p, depth)
            except BaseException as e:
                # symmetric release: an admission that fails mid-flight
                # must return its reservation + run slot, exactly like
                # the direct-submit reject path
                with self._runs_lock:
                    self._reserved.pop(p.run_id, None)
                    self._live -= 1
                p.handle._parked = False
                p.handle._finish(error=e)

    def _admit_parked(self, p: _Parked, depth: int):
        """Turn one parked entry into a live run (driver thread)."""
        if p.residency_budget:
            for tier_name, max_bytes in p.residency_budget.items():
                self.mdss.set_namespace_budget(p.ns, tier_name, max_bytes)
        waited = time.perf_counter() - p.parked_t
        try:
            run = self._materialize(
                p.pwf, p.wf, p.run_id, p.ns, p.mdss, p.init_vars, p.resume,
                p.policy, p.fetch, p.weight, p.priority, p.speculate_after,
                p.prefetch, p.checkpointer, p.handle, p.handle.events,
                p.slo_ms, p.deadline_perf)
        except BaseException:
            for tier_name in (p.residency_budget or ()):
                self.mdss.set_namespace_budget(p.ns, tier_name, None)
            raise
        run.preempt_fired = p.preempt_fired
        # waited seconds become a fair-share deficit credit when the
        # driver processes the submit message — a near-SLO latecomer
        # overtakes tenants that were running while it was parked
        run.admit_credit = waited
        p.handle._parked = False
        self.admitted_total += 1
        self.metrics.inc("frontdoor.admitted_total")
        self.metrics.observe("frontdoor.park_wait_s", waited)
        info = {"waited_s": waited, "depth": depth}
        if p.deadline_perf is not None:
            info["slack_s"] = p.deadline_perf - time.perf_counter()
        run.emit("admit", "<workflow>", **info)

    def _fail_parked(self, err: BaseException):
        """Fail every parked entry (shutdown paths). Idempotent and
        thread-safe; parked entries hold no runtime state to roll back."""
        with self._runs_lock:
            doomed, self._parked = self._parked, []
        for p in doomed:
            p.handle._parked = False
            p.handle._finish(error=err)

    def _check_slo(self):
        """Driver-side SLO guard: when an interactive run's deadline
        slack shrinks below its SLO while it is still parked — or
        admitted but lane-starved — checkpoint-abort the longest-running
        preemptible batch task on the fabric (requeued attempt-free) so
        a worker frees up. At most one preemption per run."""
        if self._draining:
            return
        broker = getattr(self._fabric, "broker", None)
        if broker is None or not hasattr(broker, "preempt_longest"):
            return
        now = time.perf_counter()
        threatened: List[Any] = []
        with self._runs_lock:
            for p in self._parked:
                if p.deadline_perf is None or p.preempt_fired:
                    continue
                if p.deadline_perf - now <= (p.slo_ms or 0.0) / 1000.0:
                    p.preempt_fired = True
                    threatened.append((p.handle.events, p.deadline_perf))
        for run in self._runs.values():
            if run.deadline_perf is None or run.preempt_fired:
                continue
            if not run.ready[True] and not run.ready[False]:
                continue        # nothing waiting on a lane
            if run.deadline_perf - now <= (run.slo_ms or 0.0) / 1000.0:
                run.preempt_fired = True
                threatened.append((run.events, run.deadline_perf))
        for sink, deadline in threatened:
            task = broker.preempt_longest()
            if task is None:
                return          # nothing preemptible in flight
            self.metrics.inc("frontdoor.preemptions")
            t = time.perf_counter()
            sink.append(Event(
                "preempt", "<workflow>", "", t,
                {"victim": f"task{task.task_id}", "step": task.step or "",
                 "slack_s": deadline - now}, time.time()))

    def publish(self, uri: str, value, tier: str = "local") -> int:
        """Write warm cross-run data into the shared namespace: every
        run's reads of ``uri`` fall through to this copy (until the run
        writes its own), so it is stored — and stays cloud-resident —
        exactly once across all tenants."""
        return self.mdss.put(f"{self.shared_namespace}/{uri}", value,
                             tier=tier)

    def warm(self, uris, tier: Optional[str] = None) -> int:
        """Pre-position shared-namespace ``uris`` on ``tier`` (default:
        the cloud tier); returns bytes moved."""
        tier = tier or self.cloud_tier
        return self.mdss.ensure(
            [f"{self.shared_namespace}/{u}" for u in uris], tier)

    def attach_fabric(self, fabric, tier_names=("cloud",)):
        """Back ``tier_names`` with an offload fabric, swap the MDSS
        transport for its RPCTransport, and point the fabric autoscaler
        (when present) at this runtime's aggregate ready backlog AND the
        store's eviction churn — residency thrash grows the pool instead
        of grinding the same bytes back and forth."""
        from repro.cloud import attach
        transport = attach(self.manager.tiers, fabric, tier_names,
                           mdss=self.mdss,
                           cost_model=self.manager.cost_model)
        if getattr(fabric, "autoscaler", None) is not None:
            fabric.autoscaler.backlog_fn = self.offload_backlog
            fabric.autoscaler.churn_fn = lambda: self.mdss.eviction_bytes
        # wire the fabric into this runtime's telemetry: the broker gets
        # the tracer (worker-reported phases re-materialise as spans) and
        # every fabric component registers its counters/gauges
        self._fabric = fabric
        broker = getattr(fabric, "broker", None)
        if broker is not None:
            broker.tracer = self.tracer
            if hasattr(broker, "register_metrics"):
                broker.register_metrics(self.metrics)
        pool = getattr(fabric, "pool", None)
        if pool is not None and hasattr(pool, "register_metrics"):
            pool.register_metrics(self.metrics)
        scaler = getattr(fabric, "autoscaler", None)
        if scaler is not None and hasattr(scaler, "register_metrics"):
            scaler.register_metrics(self.metrics)
        return transport

    # ---------------------------------------------------------------- stats
    def active_runs(self) -> int:
        with self._runs_lock:
            return len(self._runs)

    def offload_backlog(self) -> int:
        """Cross-run count of ready offload steps not yet granted a lane
        — the autoscaler's aggregate-pressure signal. Capped at the
        offload lane width: the broker can never be fed more concurrent
        tasks than the runtime has lanes, so reporting the raw heap depth
        would scale up workers the runtime cannot keep busy."""
        with self._runs_lock:
            # same eligibility filter as _dispatch_all: a failing run's
            # heap is draining dead weight, not future broker load
            ready = sum(len(r.ready[True]) for r in self._runs.values()
                        if not r.failures and not r.cancelled)
        return min(ready, self.max_workers)

    # --------------------------------------------------------- introspection
    def introspect(self, timeout: float = 10.0) -> dict:
        """Structured snapshot of the whole runtime: runs (per-step
        states, placements, retries), lane occupancy, per-(namespace,
        tier) residency vs. budget, memo table, workers, and a metrics
        snapshot.

        The snapshot is built ON the driver thread, serialised with
        every state mutation — a step can never appear simultaneously
        in-flight and completed, across any number of tenants. Falls
        back to a direct (best-effort) read when the driver is gone
        (closed runtime) or does not answer within ``timeout``.
        """
        if self._driver.is_alive() and not self._closed:
            box: dict = {}
            done = threading.Event()
            self._inbox.put(("introspect", box, done))
            if done.wait(timeout) and "snapshot" in box:
                return box["snapshot"]
        # driver gone or unresponsive: read directly. Post-close nothing
        # mutates, so this is exact; on a wedged driver it is best-effort.
        return self._introspect_unsafe()

    def attach_coalescer(self, coalescer) -> None:
        """Register a :class:`~repro.core.batching.BatchCoalescer` so its
        live bucket occupancy shows up under ``introspect()['frontdoor']``
        (and in emtop's FRONTDOOR panel)."""
        self._coalescers.append(coalescer)

    def _introspect_unsafe(self) -> dict:
        now = time.perf_counter()
        with self._runs_lock:
            runs = list(self._runs.values())
            parked_rows = [{
                "run_id": p.run_id,
                "reason": p.reason,
                "waited_s": now - p.parked_t,
                "slack_s": (p.deadline_perf - now)
                if p.deadline_perf is not None else None,
                "slo_ms": p.slo_ms,
            } for p in sorted(self._parked, key=_park_order)]
        run_rows = []
        for run in runs:
            states = {nm: "pending" for nm in run.steps}
            for h in run.ready.values():
                for _, _, nm in h:
                    states[nm] = "ready"
            # inflight/completed written LAST: _complete() moves a step
            # from _outstanding into run.completed on this same driver
            # thread, so the two sets are disjoint here by construction
            for rid, nm in list(self._outstanding):
                if rid == run.run_id and nm in states:
                    states[nm] = "inflight"
            for nm in run.completed:
                if nm in states:
                    states[nm] = "completed"
            n_ready = sum(1 for st in states.values() if st == "ready")
            run_rows.append({
                "run_id": run.run_id,
                "ns": run.ns,
                "state": ("cancelled" if run.cancelled
                          else "failing" if run.failures else "running"),
                "completed": len(run.completed),
                "inflight": run.inflight,
                "ready": n_ready,
                "pending": sum(1 for st in states.values()
                               if st == "pending"),
                "retries": run.retries,
                "weight": run.weight,
                "priority": run.priority,
                "steps": states,
                "placements": dict(run.placed),
                "fair_share_vtime": self._fair.share_of(run.run_id),
            })
        snap = {
            "runtime": {
                "pid": os.getpid(), "name": self.name,
                "telemetry": self.telemetry, "closed": self._closed,
                "draining": self._draining,
                "runs_completed": self.runs_completed,
                "trace_spans": len(self.tracer.spans())
                if self.tracer.enabled else 0,
                "trace_dropped": self.tracer.dropped,
            },
            "lanes": {
                "offload": {"busy": self._busy[True],
                            "slots": self._slots[True]},
                "local": {"busy": self._busy[False],
                          "slots": self._slots[False]},
            },
            "runs": run_rows,
            "frontdoor": {
                "depth": len(parked_rows),
                "queue_limit": self.park_limit,
                "parked": parked_rows,
                "oldest_wait_s": max(
                    (r["waited_s"] for r in parked_rows), default=0.0),
                "parked_total": self.parked_total,
                "admitted_total": self.admitted_total,
                "coalescers": [c.introspect() for c in self._coalescers],
            },
            "fair_share": self._fair.shares(),
            "mdss": self.mdss.introspect(),
            "memo": self.manager.memo_stats(),
            "workers": self._fabric_info(),
            "metrics": self.metrics.snapshot(),
        }
        return snap

    def _fabric_info(self) -> dict:
        broker = getattr(self._fabric, "broker", None)
        if broker is None:
            return {}
        try:
            return {
                "num_workers": broker.num_workers(),
                "warm": (broker.num_workers(include_warm=True)
                         - broker.num_workers()),
                "idle": broker.idle_workers(),
                "queue_depth": broker.queue_depth(),
                "inflight": broker.inflight(),
                "pids": broker.worker_pids(),
            }
        except Exception:
            return {}

    def export_trace(self, path: str, run_id: Optional[str] = None) -> str:
        """Write the Chrome trace-event JSON for ``run_id`` (or every
        recorded span) to ``path``; open it in Perfetto or
        ``chrome://tracing``."""
        return self.tracer.export_json(path, trace_id=run_id)

    # ------------------------------------------------------------- shutdown
    def close(self, timeout: Optional[float] = 60.0):
        """Drain in-flight steps, fail still-pending runs with
        :class:`RuntimeClosed`, and join the lanes + driver."""
        with self._close_lock:
            first = not self._closed
            self._closed = True
        if not first:
            # another thread (e.g. the shim's reaper) owns the teardown:
            # block until it finishes so close() always means closed
            self._close_done.wait(timeout)
            return
        self._inbox.put(("stop",))
        self._driver.join(timeout=timeout)
        self._flush_orphaned_inbox()
        # entries parked after the driver processed "stop" (or left
        # behind by a timed-out join) must still resolve
        self._fail_parked(RuntimeClosed("runtime closed"))
        self._offload_pool.shutdown(wait=True)
        self._local_pool.shutdown(wait=True)
        self._misc_pool.shutdown(wait=True)
        self._ckpt_pool.shutdown(wait=True)
        self._close_done.set()

    def _flush_orphaned_inbox(self):
        """Fail submissions enqueued after the driver exited (SimpleQueue
        is thread-safe; concurrent flushers each drain distinct items).

        Strictly a dead-driver path: while the driver lives (e.g. a close
        whose join timed out on a long in-flight step) the inbox belongs
        to it — stealing a "done"/"cancel" message here would wedge the
        drain forever."""
        if self._driver.is_alive():
            return
        while True:
            try:
                msg = self._inbox.get_nowait()
            except queue.Empty:
                return
            if msg[0] == "submit":
                with self._runs_lock:
                    self._reserved.pop(getattr(msg[1], "run_id", None), None)
                    self._live -= 1
                msg[1].handle._finish(error=RuntimeClosed("runtime closed"))
            elif msg[0] == "introspect":
                # answer directly so a caller racing close() never hangs
                msg[1]["snapshot"] = self._introspect_unsafe()
                msg[2].set()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ----------------------------------------------------------- driver loop
    def _drive(self):
        while True:
            msg = self._inbox.get()
            try:
                if self._drive_one(msg):
                    return
            except BaseException as e:
                # a driver-side fault (not a step failure — those ride the
                # done queue) must never silently hang every handle: fail
                # the active runs with it and keep serving
                for run in list(self._runs.values()):
                    self._finalize(run, e)
                if self._draining and not self._runs:
                    return

    def _drive_one(self, msg) -> bool:
        kind = msg[0]
        touched: List[_Run] = []
        if kind == "stop":
            self._draining = True
            self._fail_parked(RuntimeClosed("runtime closed"))
            for run in list(self._runs.values()):
                run.ready = {True: [], False: []}
                touched.append(run)
        elif kind == "submit":
            run = msg[1]
            if self._draining:
                with self._runs_lock:
                    self._reserved.pop(run.run_id, None)
                    self._live -= 1
                run.handle._finish(error=RuntimeClosed("runtime closed"))
                return False
            with self._runs_lock:
                self._runs[run.run_id] = run
            self._fair.add(run.run_id, run.weight)
            if run.admit_credit:
                # the park wait becomes deficit: vtime drops below the
                # field, so the admitted run is picked first until the
                # credit is consumed
                self._fair.charge(run.run_id, -run.admit_credit)
            for nm, d in run.indeg.items():
                if d == 0:
                    self._push_ready(run, nm)
            touched.append(run)
        elif kind == "done":
            run = self._complete(*msg[1:])
            if run is not None:
                touched.append(run)
        elif kind == "ckpt_done":
            run = self._runs.get(msg[1])
            if run is not None:
                run.ckpt_inflight -= 1
                if msg[2] is not None:
                    # durability is the contract: an unwritable checkpoint
                    # fails THIS run, not the whole driver
                    run.failures.append(msg[2])
                touched.append(run)
        elif kind == "cancel":
            run = self._runs.get(msg[1])
            if run is not None and not run.cancelled:
                run.cancelled = True
                run.ready = {True: [], False: []}
                touched.append(run)
            elif run is None:
                # a parked submission cancels cleanly: it holds no
                # reservation or namespace state, so removal IS the
                # whole rollback
                with self._runs_lock:
                    p = next((q for q in self._parked
                              if q.run_id == msg[1]), None)
                    if p is not None:
                        self._parked.remove(p)
                if p is not None:
                    p.handle._parked = False
                    p.handle._finish(error=RunCancelled(
                        f"run {p.run_id} cancelled"))
        elif kind == "introspect":
            # built here, between mutations — serially consistent
            msg[1]["snapshot"] = self._introspect_unsafe()
            msg[2].set()
        self._dispatch_all()
        for run in touched:
            if run.run_id in self._runs:
                self._reap(run)
        # every message is a drain opportunity — AFTER the reap, which
        # is where finalizes free run slots and reservations (nudges
        # from release()/park() land here too); admissions re-enter the
        # loop as "submit" messages, then the SLO guard runs
        self._drain_parked()
        self._check_slo()
        return self._draining and not self._runs

    def _push_ready(self, run: _Run, name: str):
        s = run.steps[name]
        prio = 0.0
        if hasattr(run.policy, "dispatch_priority"):
            prio = run.policy.dispatch_priority(s)
        place = getattr(run.policy, "place", None)
        if place is not None:
            # locality-aware lane choice, decided when the step becomes
            # ready — its inputs are final here (every producer
            # completed), so the residency map it scores is the one its
            # staging will actually see
            with self.tracer.span("place", cat="sched", track="driver",
                                  parent=run.root_ctx, step=name) as sp:
                decision = place(s)
                if sp.ctx is not None:
                    sp.set(tier=decision.tier, reason=decision.reason)
            run.placements[name] = decision
            lane = decision.offload
        else:
            lane = run.policy.should_offload(s)
        run.placed[name] = decision.tier if place is not None \
            else (self.cloud_tier if lane else "local")
        heapq.heappush(run.ready[lane], (-prio, run.order_idx[name], name))

    def _dispatch_all(self):
        """Grant free lane slots: fair share picks the run, the run's
        critical-path heap picks the step — (deficit share, -cpl)."""
        if self._draining:
            return
        for lane, pool in ((True, self._offload_pool),
                           (False, self._local_pool)):
            while self._busy[lane] < self._slots[lane]:
                cands = {r.run_id: r for r in self._runs.values()
                         if r.ready[lane] and not r.failures
                         and not r.cancelled}
                if not cands:
                    break
                chosen = None
                if self.dispatch_hook is not None:
                    chosen = self.dispatch_hook(
                        "offload" if lane else "local",
                        sorted(cands))
                if chosen is None:
                    chosen = self._fair.pick(cands)
                run = cands[chosen]
                _, _, name = heapq.heappop(run.ready[lane])
                s = run.steps[name]
                decision = run.placements.pop(name, None)
                self._fair.charge(run.run_id, self._est_cost(s, decision))
                if decision is not None:
                    run.emit("place", s.name, decision.tier,
                             reason=decision.reason, scores=decision.scores,
                             stale_bytes=decision.stale_bytes)
                self._prefetch_successors(run, s)
                if s.fanout_role == "scatter":
                    # umbrella span for the whole fan-out: allocated now
                    # so shard/gather dispatch spans can parent to it,
                    # recorded when the gather completes (_complete)
                    run.fanout_t0[s.fanout_parent] = wall_now()
                    if run.root_ctx is not None:
                        run.fanout_ctx[s.fanout_parent] = (
                            run.run_id, self.tracer.next_id())
                elif s.fanout_role == "shard":
                    self.metrics.inc("fanout.shards_dispatched")
                run.emit("dispatch", s.name, run.placed.get(name, ""),
                         lane="offload" if lane else "local")
                if lane:
                    run.emit("suspend", s.name)
                run.inflight += 1
                self._busy[lane] += 1
                self._outstanding.add((run.run_id, name))
                self.metrics.inc("runtime.steps_dispatched")
                pool.submit(self._lane, run, s, lane)

    def _est_cost(self, s: Step, decision=None) -> float:
        # fair-share charge: with a locality decision the chosen tier's
        # exec+transfer score is the run's real cost; otherwise the
        # worst-tier exec estimate (the pre-locality behaviour)
        if decision is not None:
            est = decision.scores.get(decision.tier, 0.0)
            if est > 0:
                return est
        cm = self.manager.cost_model
        est = cm.exec_time(s, "local")
        if self.cloud_tier in cm.tiers:
            est = max(est, cm.exec_time(s, self.cloud_tier))
        return est if est > 0 else 1.0

    def _complete(self, run_id: str, name: str, err, offloaded: bool
                  ) -> Optional[_Run]:
        key = (run_id, name)
        if key not in self._outstanding:
            # duplicate/late harvest — a speculation loser (or replayed
            # done message) surfacing after the winner already completed
            # the step. Decrementing again would free a lane slot that
            # was never re-taken and, worse, double-decrement successor
            # in-degrees: a successor still waiting on another input
            # would dispatch early and read a hole. Drop it.
            return None
        self._outstanding.discard(key)
        self._busy[offloaded] -= 1
        run = self._runs.get(run_id)
        if run is None:
            return None
        run.inflight -= 1
        if err is not None:
            run.failures.append(err)     # keep draining siblings
            return run
        if run.cancelled:
            return run
        if offloaded:
            run.emit("resume", name)
        run.completed.add(name)
        run.emit("step_done", name, offloaded=offloaded)
        self.metrics.inc("runtime.steps_completed")
        st = run.steps[name]
        if st.fanout_role == "scatter":
            run.emit("scatter", name, shards=st.fanout_shards,
                     parent=st.fanout_parent, uris=list(st.outputs))
            self.metrics.inc("fanout.scatters")
        elif st.fanout_role == "shard":
            run.emit("shard_done", name, shard=st.shard_index,
                     parent=st.fanout_parent)
            self.metrics.inc("fanout.shards_completed")
        elif st.fanout_role == "gather":
            run.emit("gather", name, shards=st.fanout_shards,
                     parent=st.fanout_parent)
            self.metrics.inc("fanout.gathers")
            ctx = run.fanout_ctx.pop(st.fanout_parent, None)
            t0 = run.fanout_t0.pop(st.fanout_parent, None)
            if ctx is not None and t0 is not None:
                # the umbrella span every shard dispatch parented to
                self.tracer.add_span(
                    run.run_id, f"fanout:{st.fanout_parent}", t0,
                    wall_now() - t0, span_id=ctx[1],
                    parent_id=run.root_ctx[1], cat="sched", track="driver",
                    shards=st.fanout_shards)
        if run.root_ctx is not None:
            self.tracer.add_span(run.run_id, "complete", wall_now(), 0.0,
                                 parent_id=run.root_ctx[1], cat="sched",
                                 track="driver", step=name,
                                 offloaded=offloaded)
        # outputs cached BEFORE successors dispatch (see RunCheckpointer)
        if run.checkpointer is not None:
            run.checkpointer._cache_outputs(run.steps[name])
        if not self._draining:
            # close() drains IN-FLIGHT work only: a completion during
            # shutdown must not unlock (and run) the rest of the DAG
            for m in run.succs.get(name, ()):
                if m in run.indeg and m not in run.completed:
                    run.indeg[m] -= 1
                    if run.indeg[m] == 0:
                        self._push_ready(run, m)
        run.ckpt_dirty = True
        return run

    def _reap(self, run: _Run):
        """Finalize ``run`` if it reached a terminal state. Called on the
        driver after dispatch, so a ready-but-unlaned step (heap nonempty)
        is never mistaken for a stall."""
        # durable per completion, not per wave. The pickle runs on the
        # dedicated checkpoint lane (never the driver): the driver
        # freezes a consistent (completed, vars) snapshot, queues the
        # write, and coalesces further dirt until the ckpt_done message
        # returns — at most one write in flight per run.
        if run.checkpointer is None:
            run.ckpt_dirty = False
        elif run.ckpt_dirty and run.ckpt_inflight == 0:
            run.ckpt_dirty = False
            completed = set(run.completed)
            run.checkpointer._freeze(completed)

            def write(run=run, completed=completed):
                try:
                    run.checkpointer._save_checkpoint(completed)
                    err = None
                except BaseException as e:
                    err = e
                self._inbox.put(("ckpt_done", run.run_id, err))

            try:
                run.ckpt_inflight += 1
                self._ckpt_pool.submit(write)
            except BaseException as e:
                # lane already shut (straggler completion after close's
                # join timeout): durability is the contract — fail the run
                run.ckpt_inflight -= 1
                run.failures.append(e)
        if run.ckpt_inflight > 0:
            # per-run completion fence: the handle must not resolve (nor
            # the run finalize in any direction) before its checkpoint is
            # durable — the ckpt_done message re-enters this reap
            return
        if len(run.completed) == len(run.steps) and not run.failures:
            self._finalize(run, None)
        elif run.inflight == 0:
            if run.cancelled:
                self._finalize(run, RunCancelled(
                    f"run {run.run_id} cancelled"))
            elif run.failures:
                self._finalize(run, run.failures[0])
            elif self._draining:
                self._finalize(run, RuntimeClosed("runtime closed"))
            elif not run.ready[True] and not run.ready[False]:
                self._finalize(run, WorkflowFailure(
                    "dependency cycle or failed step"))

    def _finalize(self, run: _Run, error: Optional[BaseException]):
        with self._runs_lock:
            del self._runs[run.run_id]
            self._reserved.pop(run.run_id, None)
            self._live -= 1
        self._fair.remove(run.run_id)
        self.runs_completed += 1
        if run.root_ctx is not None:
            # the run's root span, with the identity every child used
            self.tracer.add_span(
                run.run_id, "run", run.epoch_wall,
                time.perf_counter() - run.epoch_perf,
                span_id=run.root_ctx[1], cat="run",
                track=f"run:{run.run_id}", namespace=run.ns,
                steps=len(run.steps),
                outcome="error" if error is not None else "ok")
        if run.checkpointer is not None:
            run.checkpointer._ckpt_cache.clear()   # release pinned copies
        if error is not None:
            run.handle._finish(error=error)
            return

        def reintegrate():
            try:
                uris = run.fetch if run.fetch is not None else [
                    u for u in run.wf.variables if run.mdss.version(u)]
                run.handle._finish(result={
                    uri: run.mdss.get(uri, "local") for uri in uris
                    if run.mdss.version(uri)})
            except BaseException as e:
                run.handle._finish(error=e)

        try:
            self._misc_pool.submit(reintegrate)
        except BaseException as e:
            # pool already shut (e.g. a straggler finishing after close()'s
            # join timeout): the handle must still resolve, never hang
            run.handle._finish(error=e)

    # ----------------------------------------------------------- lane bodies
    def _lane(self, run: _Run, s: Step, offloaded: bool):
        try:
            # the dispatch span: everything below — staging, ship, remote
            # exec, install — nests under it via the lane thread's TLS,
            # and its ctx rides the wire so worker-side phases do too
            parent_ctx = run.root_ctx
            if s.fanout_role:
                # shard/gather (and scatter) spans nest under the fan-out
                # umbrella span allocated at scatter dispatch
                parent_ctx = run.fanout_ctx.get(s.fanout_parent, run.root_ctx)
            with self.tracer.span(
                    "dispatch", cat="sched",
                    track=f"lane:{'offload' if offloaded else 'local'}",
                    trace_id=run.run_id, parent=parent_ctx,
                    step=s.name, run=run.run_id):
                if offloaded:
                    self._offload_with_recovery(run, s)
                else:
                    self._run_local(run, s)
            err = None
        except BaseException as e:           # harvested by the driver
            err = e
        self._inbox.put(("done", run.run_id, s.name, err, offloaded))

    def _run_local(self, run: _Run, s: Step):
        rep = self.manager.execute(s, "local", mdss=run.mdss,
                                   priority=run.priority)
        run.emit("local", s.name, "local", seconds=rep.seconds,
                 memo_hit=rep.memo_hit)

    def _offload_with_recovery(self, run: _Run, s: Step):
        tiers_to_try = [self.cloud_tier] * max(1, s.retries) + ["local"]
        last_err = None
        for attempt, tier in enumerate(tiers_to_try):
            try:
                rep = self._execute_maybe_speculative(run, s, tier)
                run.emit("offload", s.name, rep.tier,
                         seconds=rep.seconds, bytes_in=rep.bytes_in,
                         bytes_out=rep.bytes_out, code_only=rep.code_only,
                         attempt=attempt, remote=rep.remote,
                         worker_pid=rep.worker_pid, staged_s=rep.staged_s,
                         memo_hit=rep.memo_hit)
                return rep
            except StepFailure as e:      # node failure -> retry / fallback
                last_err = e
                run.retries += 1
                self.metrics.inc("runtime.step_retries")
                run.emit("retry", s.name, tier, attempt=attempt,
                         error=str(e))
        raise WorkflowFailure(f"step {s.name} failed on all tiers: {last_err}")

    def _execute_maybe_speculative(self, run: _Run, s: Step, tier: str):
        alt = self._alternate_tier(s, tier)
        est = self.manager.cost_model.stats_for(s.name).measured_s.get(tier)
        if run.speculate_after is None or alt is None or est is None:
            return self.manager.execute(s, tier, mdss=run.mdss,
                                        priority=run.priority)
        timeout = est * run.speculate_after
        # no context manager: pool shutdown must NOT join the straggler
        spool = ThreadPoolExecutor(max_workers=2)
        # speculation twins run on fresh threads: re-attach the lane
        # thread's dispatch span so their ship/exec spans stay parented
        ctx = self.tracer.current_ctx()

        def execute(t, memo=None):
            with self.tracer.attach(ctx):
                return self.manager.execute(s, t, mdss=run.mdss,
                                            priority=run.priority,
                                            memoize=memo)
        try:
            primary = spool.submit(execute, tier)
            done, _ = wait([primary], timeout=timeout)
            if done:
                return primary.result()
            run.emit("speculate", s.name, alt, timeout=timeout)
            # the backup bypasses memoization: under memoize=True it
            # would otherwise become a WAITER on the primary's own
            # in-flight memo entry — a "race" that can never overtake
            backup = spool.submit(execute, alt, False)
            # first *successful* finisher wins: a primary that fails fast
            # right after the backup launches must not fail the step
            pending = {primary, backup}
            last_err, fenced_rep = None, None
            while pending:
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                for f in done:
                    try:
                        rep = f.result()
                    except StepFailure as e:
                        last_err = e
                        continue
                    if rep.fenced:
                        # the loser's report (its publish was refused) —
                        # keep only as a fallback so the recorded offload
                        # event reflects the twin that actually published
                        fenced_rep = rep
                        continue
                    return rep
            if fenced_rep is not None:
                return fenced_rep
            raise last_err                   # both twins failed
        finally:
            spool.shutdown(wait=False)

    def _alternate_tier(self, s: Step, tier: str) -> Optional[str]:
        """Best backup tier for speculation: the candidate with the lowest
        modeled/measured execution time, NOT whatever dict order yields —
        deterministic, and targeted at the fastest recovery. Unknown
        estimates (0.0) tie and fall back to declaration order."""
        cm = self.manager.cost_model
        order = {nm: i for i, nm in enumerate(self.manager.tiers)}
        cands = [nm for nm in self.manager.tiers if nm not in (tier, "local")]
        if not cands:
            return None
        return min(cands, key=lambda nm: (cm.exec_time(s, nm), order[nm]))

    def _prefetch_successors(self, run: _Run, s: Step):
        """Warm the cloud tier with a dispatched step's successors' inputs.

        Only inputs that already exist and are stale on the cloud tier
        move; outputs of still-running steps are skipped (MDSS.prefetch is
        best-effort and version-hazard-checked), so the transfer safely
        overlaps this step's compute.
        """
        if not run.prefetch or self.cloud_tier not in self.manager.tiers:
            return
        for m in run.succs.get(s.name, ()):
            succ = run.wf.steps[m]
            if not run.policy.should_offload(succ):
                continue
            # skip vars s itself is about to rewrite: their current
            # version is guaranteed dead by the time the successor reads
            uris = [u for u in succ.inputs
                    if u not in s.outputs
                    and run.mdss.version(u)
                    and not run.mdss.has_latest(u, self.cloud_tier)]
            if uris and run.mdss.prefetch(uris, self.cloud_tier) is not None:
                # emitted only for ADMITTED requests (None = shed at the
                # MDSS concurrency cap), so the event log matches reality
                run.emit("prefetch", succ.name, self.cloud_tier, uris=uris)
