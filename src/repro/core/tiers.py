"""Execution tiers — the TPU-native analogue of Emerald's local/cloud split.

The paper assumes a weak "local computer" and a strong "cloud". Here a tier
is a named compute pool with a (possibly absent) device mesh and hardware
constants for the cost model. In this single-process container every tier
executes on the host CPU, but the *runtime machinery* — per-tier compile
caches, MDSS residency, transfer accounting, offload decisions — is real and
mesh-aware; on a TPU cluster the tier's mesh is its slice.

Hardware constants (modeled):
  * local  — one workstation-class chip (paper's "resource constrained")
  * cloud  — a 16x16 v5e pod: 197 TFLOP/s bf16/chip, 819 GB/s HBM,
             ~50 GB/s/link ICI; WAN/DCN to local ~1 GB/s.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax

V5E_PEAK_FLOPS = 197e12
V5E_HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 1e9        # local <-> cloud (WAN-ish)
POD_DCI_BW = 25e9   # pod <-> pod


@dataclass
class Tier:
    name: str
    chips: int
    peak_flops_per_chip: float
    hbm_bw_per_chip: float
    mesh: Optional["jax.sharding.Mesh"] = None
    link_bw: Dict[str, float] = field(default_factory=dict)  # to other tiers
    link_latency_s: float = 1e-3
    # offload-fabric backing (repro.cloud.Fabric); when set, remotable
    # registry/picklable steps targeting this tier run in worker processes
    worker_pool: Optional[object] = None

    @property
    def peak_flops(self) -> float:
        return self.chips * self.peak_flops_per_chip

    @property
    def hbm_bw(self) -> float:
        return self.chips * self.hbm_bw_per_chip

    def bw_to(self, other: str) -> float:
        return self.link_bw.get(other, DCN_BW)


def default_tiers(cloud_mesh=None, pod2_mesh=None) -> Dict[str, Tier]:
    """local workstation + one (or two) cloud pods."""
    tiers = {
        "local": Tier("local", chips=1, peak_flops_per_chip=2e12,
                      hbm_bw_per_chip=100e9,
                      link_bw={"cloud": DCN_BW, "cloud2": DCN_BW}),
        "cloud": Tier("cloud", chips=256, peak_flops_per_chip=V5E_PEAK_FLOPS,
                      hbm_bw_per_chip=V5E_HBM_BW, mesh=cloud_mesh,
                      link_bw={"local": DCN_BW, "cloud2": POD_DCI_BW}),
    }
    if pod2_mesh is not None or True:  # second pod tier always declared
        tiers["cloud2"] = Tier(
            "cloud2", chips=256, peak_flops_per_chip=V5E_PEAK_FLOPS,
            hbm_bw_per_chip=V5E_HBM_BW, mesh=pod2_mesh,
            link_bw={"local": DCN_BW, "cloud": POD_DCI_BW})
    return tiers
