"""Offload scheduling policies.

The paper's Emerald offloads every annotated step ("annotate"); its future
work calls for smarter decisions. The executor delegates the per-step
choice to a policy object so new strategies slot in without touching the
runtime:

  * ``AnnotatePolicy``   — paper-faithful: remotable => offload.
  * ``NeverPolicy``      — the paper's baseline arm (offloading disabled).
  * ``CostModelPolicy``  — beyond-paper: offload iff the roofline cost
    model predicts net benefit, accounting for MDSS-stale input bytes
    (so a step whose data is already cloud-resident offloads more eagerly
    — the scheduler and MDSS reinforce each other).

Transfer-time estimates use *observed* wire bandwidth when the offload
fabric is attached: every RPCTransport ship feeds
``CostModel.observe_bandwidth`` and ``CostModel.transfer_time`` prefers
that EMA over the static ``DCN_BW`` link constant, so offload decisions
track what the wire actually delivers.

Policies also carry a **dispatch-priority hook** for the event-driven
executor: when more steps are ready than workers, higher-priority steps
dispatch first. The default ordering is critical-path-length-first
(``critical_path_lengths``): the long pole of a wide heterogeneous DAG
starts as early as possible, which is what bounds makespan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Protocol

from repro.core.cost_model import CostModel
from repro.core.mdss import MDSS
from repro.core.workflow import Step, Workflow


def critical_path_lengths(wf: Workflow, cost_model: Optional[CostModel] = None,
                          cloud_tier: str = "cloud",
                          default_cost: float = 1.0,
                          succ: Optional[Dict[str, set]] = None
                          ) -> Dict[str, float]:
    """Longest path (in estimated seconds) from each step to any sink.

    Step weight prefers the cost model's estimate (measured EMA, XLA cost
    analysis or developer hints); with no estimate every step weighs
    ``default_cost`` and the priority degrades to DAG depth. Workflow
    declaration order is a topological order (all dataflow edges point
    forward), so one reverse sweep suffices. Pass a precomputed ``succ``
    (from :meth:`Workflow.successors`) to avoid rebuilding the edge map.
    """
    succ = wf.successors() if succ is None else succ
    cpl: Dict[str, float] = {}
    for s in reversed(wf.toplevel()):
        w = default_cost
        if cost_model is not None:
            est = cost_model.exec_time(s, "local")
            if cloud_tier in cost_model.tiers:
                est = max(est, cost_model.exec_time(s, cloud_tier))
            if est > 0:
                w = est
        cpl[s.name] = w + max((cpl[m] for m in succ[s.name]), default=0.0)
    return cpl


class OffloadPolicy(Protocol):
    def should_offload(self, step: Step) -> bool: ...

    def dispatch_priority(self, step: Step) -> float: ...


class DispatchPriorityMixin:
    """Critical-path-first dispatch ordering, shared by all policies.

    The executor seeds ``set_priorities`` with ``critical_path_lengths``;
    until then every step ties at 0.0 and dispatch falls back to workflow
    declaration order.
    """
    _priorities: Optional[Dict[str, float]] = None

    def set_priorities(self, priorities: Dict[str, float]):
        self._priorities = dict(priorities)

    def dispatch_priority(self, step: Step) -> float:
        if not self._priorities:
            return 0.0
        return self._priorities.get(step.name, 0.0)


@dataclass
class AnnotatePolicy(DispatchPriorityMixin):
    def should_offload(self, step: Step) -> bool:
        return step.remotable


@dataclass
class NeverPolicy(DispatchPriorityMixin):
    def should_offload(self, step: Step) -> bool:
        return False


@dataclass
class CostModelPolicy(DispatchPriorityMixin):
    cost_model: CostModel
    mdss: MDSS
    cloud_tier: str = "cloud"

    def should_offload(self, step: Step) -> bool:
        if not step.remotable:
            return False
        return self.explain(step)["benefit_s"] > 0.0

    def explain(self, step: Step) -> dict:
        """Decision breakdown — which bandwidth the model used and why."""
        stale = self.mdss.stale_bytes(step.inputs, self.cloud_tier)
        benefit = self.cost_model.offload_benefit(
            step, stale_in_bytes=stale, result_bytes=step.bytes_hint or 0,
            src="local", dst=self.cloud_tier)
        return {
            "stale_in_bytes": stale,
            "benefit_s": benefit,
            "bw_bytes_per_s": self.cost_model.measured_bw.get(
                ("local", self.cloud_tier)),
            "bw_source": "observed" if ("local", self.cloud_tier)
                         in self.cost_model.measured_bw else "static",
        }


def make_policy(name: str, cost_model: CostModel, mdss: MDSS,
                cloud_tier: str = "cloud") -> OffloadPolicy:
    if name == "annotate":
        return AnnotatePolicy()
    if name == "never":
        return NeverPolicy()
    if name == "cost_model":
        return CostModelPolicy(cost_model, mdss, cloud_tier)
    raise ValueError(name)
