"""Offload scheduling policies.

The paper's Emerald offloads every annotated step ("annotate"); its future
work calls for smarter decisions. The executor delegates the per-step
choice to a policy object so new strategies slot in without touching the
runtime:

  * ``AnnotatePolicy``   — paper-faithful: remotable => offload.
  * ``NeverPolicy``      — the paper's baseline arm (offloading disabled).
  * ``CostModelPolicy``  — beyond-paper: offload iff the roofline cost
    model predicts net benefit, accounting for MDSS-stale input bytes
    (so a step whose data is already cloud-resident offloads more eagerly
    — the scheduler and MDSS reinforce each other).
  * ``LocalityPolicy``   — beyond-paper: data-locality-aware placement.
    Every candidate tier is scored ``est_exec(tier) + est_transfer(bytes
    not already resident on tier)`` (``MDSS.staleness`` supplies the
    per-input source tier and size) and the cheapest tier wins — a step
    whose inputs are warm on the cloud offloads even when raw compute
    favours local, and a step whose inputs live locally stays home even
    when the cloud is the faster chip. Unlike ``CostModelPolicy`` the
    local side is charged for staging too: residency-blind comparison
    treats locally-stale cloud-warm data as free to read, which is
    exactly the placement mistake Juve et al. measured on EC2.
    ``place()`` returns the full :class:`PlacementDecision` (scores,
    stale bytes, reason) that the runtime exposes in step events.

Transfer-time estimates use *observed* wire bandwidth when the offload
fabric is attached: every RPCTransport ship feeds
``CostModel.observe_bandwidth`` and ``CostModel.transfer_time`` prefers
that EMA over the static ``DCN_BW`` link constant, so offload decisions
track what the wire actually delivers.

Policies also carry a **dispatch-priority hook** for the event-driven
executor: when more steps are ready than workers, higher-priority steps
dispatch first. The default ordering is critical-path-length-first
(``critical_path_lengths``): the long pole of a wide heterogeneous DAG
starts as early as possible, which is what bounds makespan.

The multi-tenant runtime composes a **cross-run fair-share layer** on
top: when several workflows contend for the same worker lanes, each free
slot goes to the run with the smallest deficit-weighted share
(``FairShare``, stride-scheduling style), and *within* that run the
critical-path priority picks the step. Dispatch order is therefore
(deficit-weighted run share, -cpl) — one wide workflow cannot starve the
rest, and a heavier ``weight`` buys a run proportionally more slots.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Protocol

from repro.core.cost_model import CostModel
from repro.core.mdss import MDSS
from repro.core.workflow import Step, Workflow


def critical_path_lengths(wf: Workflow, cost_model: Optional[CostModel] = None,
                          cloud_tier: str = "cloud",
                          default_cost: float = 1.0,
                          succ: Optional[Dict[str, set]] = None
                          ) -> Dict[str, float]:
    """Longest path (in estimated seconds) from each step to any sink.

    Step weight prefers the cost model's estimate (measured EMA, XLA cost
    analysis or developer hints); with no estimate every step weighs
    ``default_cost`` and the priority degrades to DAG depth. Workflow
    declaration order is a topological order (all dataflow edges point
    forward), so one reverse sweep suffices. Pass a precomputed ``succ``
    (from :meth:`Workflow.successors`) to avoid rebuilding the edge map.
    """
    succ = wf.successors() if succ is None else succ
    cpl: Dict[str, float] = {}
    for s in reversed(wf.toplevel()):
        w = default_cost
        if cost_model is not None:
            est = cost_model.exec_time(s, "local")
            if cloud_tier in cost_model.tiers:
                est = max(est, cost_model.exec_time(s, cloud_tier))
            if est > 0:
                w = est
        cpl[s.name] = w + max((cpl[m] for m in succ[s.name]), default=0.0)
    return cpl


class FairShare:
    """Deficit-weighted cross-run scheduling (stride scheduling).

    Each run carries a virtual time that advances by ``cost / weight``
    whenever one of its steps is dispatched; every free worker slot goes
    to the eligible run with the smallest virtual time. A run that just
    burned many slots (a wide workflow flooding the ready set) accrues
    virtual time fast and yields to the others; a run with weight *w*
    receives ~*w*x the slots of a weight-1 run under contention.

    Not thread-safe by itself — the runtime mutates it only from its
    driver thread.
    """

    def __init__(self):
        self._vtime: Dict[str, float] = {}
        self._weight: Dict[str, float] = {}

    def add(self, run_id: str, weight: float = 1.0):
        # a newcomer starts at the current minimum, not at zero: joining
        # late must not grant a catch-up monopoly over long-running peers
        base = min(self._vtime.values(), default=0.0)
        self._weight[run_id] = max(float(weight), 1e-9)
        self._vtime[run_id] = base

    def remove(self, run_id: str):
        self._vtime.pop(run_id, None)
        self._weight.pop(run_id, None)

    def charge(self, run_id: str, cost: float = 1.0):
        """Account one dispatched step of estimated ``cost`` seconds."""
        if run_id in self._vtime:
            self._vtime[run_id] += max(cost, 1e-9) / self._weight[run_id]

    def pick(self, run_ids: Iterable[str]) -> Optional[str]:
        """The eligible run owed the next slot (smallest virtual time;
        ties break deterministically by run id)."""
        best = None
        for rid in run_ids:
            key = (self._vtime.get(rid, 0.0), rid)
            if best is None or key < best[0]:
                best = (key, rid)
        return None if best is None else best[1]

    def share_of(self, run_id: str) -> float:
        return self._vtime.get(run_id, 0.0)

    def shares(self) -> Dict[str, dict]:
        """Snapshot of every run's virtual time, weight and deficit
        (vtime - min vtime: how far ahead of its fair share the run is;
        0 means it is owed the next slot). For introspection/metrics."""
        base = min(self._vtime.values(), default=0.0)
        return {rid: {"vtime": vt, "weight": self._weight.get(rid, 1.0),
                      "deficit": vt - base}
                for rid, vt in self._vtime.items()}


class OffloadPolicy(Protocol):
    def should_offload(self, step: Step) -> bool: ...

    def dispatch_priority(self, step: Step) -> float: ...


class DispatchPriorityMixin:
    """Critical-path-first dispatch ordering, shared by all policies.

    The executor seeds ``set_priorities`` with ``critical_path_lengths``;
    until then every step ties at 0.0 and dispatch falls back to workflow
    declaration order.
    """
    _priorities: Optional[Dict[str, float]] = None

    def set_priorities(self, priorities: Dict[str, float]):
        self._priorities = dict(priorities)

    def dispatch_priority(self, step: Step) -> float:
        if not self._priorities:
            return 0.0
        return self._priorities.get(step.name, 0.0)


@dataclass
class AnnotatePolicy(DispatchPriorityMixin):
    def should_offload(self, step: Step) -> bool:
        return step.remotable


@dataclass
class NeverPolicy(DispatchPriorityMixin):
    def should_offload(self, step: Step) -> bool:
        return False


@dataclass
class PlacementDecision:
    """Why a step was placed on ``tier`` — attached to dispatch events."""
    tier: str
    offload: bool
    scores: Dict[str, float]        # tier -> est_exec + est_transfer (s)
    stale_bytes: Dict[str, int]     # tier -> input bytes not resident there
    reason: str


@dataclass
class LocalityPolicy(DispatchPriorityMixin):
    """Place each step on the tier where (exec + staging) is cheapest."""
    cost_model: CostModel
    mdss: MDSS
    cloud_tier: str = "cloud"

    def _score(self, step: Step, tier: str):
        staleness = self.mdss.staleness(step.inputs, tier)
        return (self.cost_model.placement_cost(step, tier, staleness),
                sum(n for _, _, n in staleness))

    def place(self, step: Step) -> PlacementDecision:
        local_s, local_b = self._score(step, "local")
        scores = {"local": local_s}
        stale = {"local": local_b}
        if step.fanout_role in ("scatter", "gather"):
            # host-side closures over partition_fn/combine_fn: they slice
            # and reassemble on the driver's tier; the shards between
            # them are what the fabric parallelises
            return PlacementDecision("local", False, scores, stale,
                                     "fan-out scatter/gather runs local")
        if not step.remotable or self.cloud_tier not in self.cost_model.tiers:
            return PlacementDecision("local", False, scores, stale,
                                     "not remotable")
        cloud_s, cloud_b = self._score(step, self.cloud_tier)
        scores[self.cloud_tier] = cloud_s
        stale[self.cloud_tier] = cloud_b
        if cloud_s != local_s:
            offload = cloud_s < local_s
            reason = "exec+transfer score"
        elif cloud_b != local_b:
            # equal modeled seconds (often both unknown-exec): prefer the
            # tier already holding more of the data
            offload = cloud_b < local_b
            reason = "resident-bytes tie-break"
        else:
            # no signal either way: the paper's annotate default
            offload = True
            reason = "no estimates: annotate default"
        tier = self.cloud_tier if offload else "local"
        return PlacementDecision(tier, offload, scores, stale, reason)

    def should_offload(self, step: Step) -> bool:
        return self.place(step).offload


@dataclass
class CostModelPolicy(DispatchPriorityMixin):
    cost_model: CostModel
    mdss: MDSS
    cloud_tier: str = "cloud"

    def should_offload(self, step: Step) -> bool:
        if not step.remotable:
            return False
        return self.explain(step)["benefit_s"] > 0.0

    def explain(self, step: Step) -> dict:
        """Decision breakdown — which bandwidth the model used and why."""
        stale = self.mdss.stale_bytes(step.inputs, self.cloud_tier)
        benefit = self.cost_model.offload_benefit(
            step, stale_in_bytes=stale, result_bytes=step.bytes_hint or 0,
            src="local", dst=self.cloud_tier)
        return {
            "stale_in_bytes": stale,
            "benefit_s": benefit,
            "bw_bytes_per_s": self.cost_model.measured_bw.get(
                ("local", self.cloud_tier)),
            "bw_source": "observed" if ("local", self.cloud_tier)
                         in self.cost_model.measured_bw else "static",
        }


POLICIES = ("annotate", "cost_model", "never", "locality")


def make_policy(name: str, cost_model: CostModel, mdss: MDSS,
                cloud_tier: str = "cloud") -> OffloadPolicy:
    if name == "annotate":
        return AnnotatePolicy()
    if name == "never":
        return NeverPolicy()
    if name == "cost_model":
        return CostModelPolicy(cost_model, mdss, cloud_tier)
    if name == "locality":
        return LocalityPolicy(cost_model, mdss, cloud_tier)
    raise ValueError(name)
