"""Offload scheduling policies.

The paper's Emerald offloads every annotated step ("annotate"); its future
work calls for smarter decisions. The executor delegates the per-step
choice to a policy object so new strategies slot in without touching the
runtime:

  * ``AnnotatePolicy``   — paper-faithful: remotable => offload.
  * ``NeverPolicy``      — the paper's baseline arm (offloading disabled).
  * ``CostModelPolicy``  — beyond-paper: offload iff the roofline cost
    model predicts net benefit, accounting for MDSS-stale input bytes
    (so a step whose data is already cloud-resident offloads more eagerly
    — the scheduler and MDSS reinforce each other).

Transfer-time estimates use *observed* wire bandwidth when the offload
fabric is attached: every RPCTransport ship feeds
``CostModel.observe_bandwidth`` and ``CostModel.transfer_time`` prefers
that EMA over the static ``DCN_BW`` link constant, so offload decisions
track what the wire actually delivers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol

from repro.core.cost_model import CostModel
from repro.core.mdss import MDSS
from repro.core.workflow import Step


class OffloadPolicy(Protocol):
    def should_offload(self, step: Step) -> bool: ...


@dataclass
class AnnotatePolicy:
    def should_offload(self, step: Step) -> bool:
        return step.remotable


@dataclass
class NeverPolicy:
    def should_offload(self, step: Step) -> bool:
        return False


@dataclass
class CostModelPolicy:
    cost_model: CostModel
    mdss: MDSS
    cloud_tier: str = "cloud"

    def should_offload(self, step: Step) -> bool:
        if not step.remotable:
            return False
        return self.explain(step)["benefit_s"] > 0.0

    def explain(self, step: Step) -> dict:
        """Decision breakdown — which bandwidth the model used and why."""
        stale = self.mdss.stale_bytes(step.inputs, self.cloud_tier)
        benefit = self.cost_model.offload_benefit(
            step, stale_in_bytes=stale, result_bytes=step.bytes_hint or 0,
            src="local", dst=self.cloud_tier)
        return {
            "stale_in_bytes": stale,
            "benefit_s": benefit,
            "bw_bytes_per_s": self.cost_model.measured_bw.get(
                ("local", self.cloud_tier)),
            "bw_source": "observed" if ("local", self.cloud_tier)
                         in self.cost_model.measured_bw else "static",
        }


def make_policy(name: str, cost_model: CostModel, mdss: MDSS,
                cloud_tier: str = "cloud") -> OffloadPolicy:
    if name == "annotate":
        return AnnotatePolicy()
    if name == "never":
        return NeverPolicy()
    if name == "cost_model":
        return CostModelPolicy(cost_model, mdss, cloud_tier)
    raise ValueError(name)
