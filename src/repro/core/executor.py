"""Emerald distributed-execution runtime (paper §3.3 + §6-scale features).

Walks a partitioned workflow's dataflow DAG:

  * non-remotable steps run on the local tier,
  * at a migration point the workflow *suspends*, the target step offloads
    through the MigrationManager, then execution *resumes* — strictly
    alternating (Property 3),
  * independent remotable steps offload **concurrently** (paper Fig 9b)
    via a thread pool,
  * offload policy: ``annotate`` (paper-faithful: every remotable step goes
    to the cloud), ``cost_model`` (beyond-paper: offload only when the
    roofline model predicts benefit), ``never`` (paper's baseline arm).

Scale features (DESIGN.md §6):
  * retry with tier fallback — a failed offload re-runs, ultimately locally,
  * straggler speculation — a remotable step that overruns
    ``speculate_after`` x its EMA runtime is duplicated on another tier;
    first finisher wins,
  * suspension points double as workflow checkpoints (crash -> resume skips
    completed steps; variables restored from the snapshot).
"""
from __future__ import annotations

import os
import pickle
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.mdss import MDSS, nbytes_of
from repro.core.migration import MigrationManager, StepFailure
from repro.core.partitioner import PartitionedWorkflow
from repro.core.scheduler import make_policy
from repro.core.workflow import Step


@dataclass
class Event:
    kind: str          # suspend | offload | resume | local | retry | speculate | checkpoint
    step: str
    tier: str = ""
    t: float = 0.0
    info: dict = field(default_factory=dict)


class WorkflowFailure(RuntimeError):
    pass


class EmeraldExecutor:
    def __init__(self, pwf: PartitionedWorkflow, manager: MigrationManager,
                 *, policy: str = "annotate", cloud_tier: str = "cloud",
                 max_workers: int = 8, speculate_after: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None):
        assert policy in ("annotate", "cost_model", "never")
        self.pwf = pwf
        self.wf = pwf.workflow
        self.manager = manager
        self.mdss = manager.mdss
        self.policy = policy
        self._policy = make_policy(policy, manager.cost_model, manager.mdss,
                                   cloud_tier)
        self.cloud_tier = cloud_tier
        self.max_workers = max_workers
        self.speculate_after = speculate_after
        self.checkpoint_dir = checkpoint_dir
        self.events: List[Event] = []
        self._lock = threading.Lock()

    # ---------------------------------------------------------------- events
    def _emit(self, kind, step, tier="", **info):
        with self._lock:
            self.events.append(Event(kind, step, tier, time.perf_counter(), info))

    # ------------------------------------------------------------ checkpoint
    def _ckpt_path(self):
        return os.path.join(self.checkpoint_dir, f"{self.wf.name}.wfckpt")

    def _save_checkpoint(self, completed):
        if not self.checkpoint_dir:
            return
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        snapshot = {}
        for uri in self.wf.variables:
            if self.mdss.version(uri):
                val = self.mdss.get(uri, "local")
                snapshot[uri] = jax.tree.map(np.asarray, val)
        tmp = self._ckpt_path() + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump({"completed": sorted(completed), "vars": snapshot}, f)
        os.replace(tmp, self._ckpt_path())
        self._emit("checkpoint", "<workflow>", info={"n": len(completed)})

    def _load_checkpoint(self):
        if not self.checkpoint_dir or not os.path.exists(self._ckpt_path()):
            return None
        with open(self._ckpt_path(), "rb") as f:
            return pickle.load(f)

    # ------------------------------------------------------------------- run
    def run(self, init_vars: Dict[str, Any], *, resume: bool = False,
            fetch=None):
        """Execute the workflow.

        ``fetch`` limits which variables are synced back to the local tier
        at re-integration (default: all). Leaving hot state (params,
        optimizer state) un-fetched keeps it resident on the cloud tier so
        the next run's offloads are code-only — the paper's MDSS saving.
        """
        return self._run(init_vars, resume=resume, fetch=fetch)

    def _run(self, init_vars: Dict[str, Any], *, resume: bool = False,
             fetch=None):
        completed: set = set()
        for uri, val in init_vars.items():
            if uri not in self.wf.variables:
                self.wf.var(uri)
            self.mdss.put(uri, val, tier="local")
        if resume:
            state = self._load_checkpoint()
            if state is not None:
                completed = set(state["completed"])
                for uri, val in state["vars"].items():
                    self.mdss.put(uri, val, tier="local")

        deps = self.wf.dependencies()
        steps = {s.name: s for s in self.wf.toplevel()}
        pool = ThreadPoolExecutor(max_workers=self.max_workers)
        try:
            while len(completed) < len(steps):
                ready = [steps[n] for n in self.wf.order
                         if n in steps and n not in completed
                         and deps[n] <= completed]
                if not ready:
                    raise WorkflowFailure("dependency cycle or failed step")
                futures: Dict[Future, Step] = {}
                for s in ready:
                    if self._should_offload(s):
                        self._emit("suspend", s.name)
                        futures[pool.submit(self._offload_with_recovery, s)] = s
                    else:
                        self._run_local(s)
                        completed.add(s.name)
                for fut, s in futures.items():
                    fut.result()  # re-raises WorkflowFailure
                    self._emit("resume", s.name)
                    completed.add(s.name)
                if futures or not ready:
                    self._save_checkpoint(completed)
        finally:
            pool.shutdown(wait=True)
        # re-integrate: requested workflow variables synced back to local
        uris = fetch if fetch is not None else [
            u for u in self.wf.variables if self.mdss.version(u)]
        return {uri: self.mdss.get(uri, "local") for uri in uris
                if self.mdss.version(uri)}

    # -------------------------------------------------------------- policies
    def _should_offload(self, s: Step) -> bool:
        return self._policy.should_offload(s)

    # ------------------------------------------------------------- execution
    def _run_local(self, s: Step):
        rep = self.manager.execute(s, "local")
        self._emit("local", s.name, "local", seconds=rep.seconds)

    def _offload_with_recovery(self, s: Step):
        tiers_to_try = [self.cloud_tier] * max(1, s.retries) + ["local"]
        last_err = None
        for attempt, tier in enumerate(tiers_to_try):
            try:
                rep = self._execute_maybe_speculative(s, tier)
                self._emit("offload", s.name, rep.tier,
                           seconds=rep.seconds, bytes_in=rep.bytes_in,
                           bytes_out=rep.bytes_out, code_only=rep.code_only,
                           attempt=attempt, remote=rep.remote,
                           worker_pid=rep.worker_pid)
                return rep
            except StepFailure as e:      # node failure -> retry / fallback
                last_err = e
                self._emit("retry", s.name, tier, attempt=attempt,
                           error=str(e))
        raise WorkflowFailure(f"step {s.name} failed on all tiers: {last_err}")

    def _execute_maybe_speculative(self, s: Step, tier: str):
        alt = self._alternate_tier(tier)
        est = self.manager.cost_model.stats_for(s.name).measured_s.get(tier)
        if self.speculate_after is None or alt is None or est is None:
            return self.manager.execute(s, tier)
        timeout = est * self.speculate_after
        # no context manager: pool shutdown must NOT join the straggler
        spool = ThreadPoolExecutor(max_workers=2)
        try:
            primary = spool.submit(self.manager.execute, s, tier)
            done, _ = wait([primary], timeout=timeout)
            if done:
                return primary.result()
            self._emit("speculate", s.name, alt, timeout=timeout)
            backup = spool.submit(self.manager.execute, s, alt)
            done, _ = wait([primary, backup], return_when=FIRST_COMPLETED)
            return done.pop().result()
        finally:
            spool.shutdown(wait=False)

    def _alternate_tier(self, tier: str) -> Optional[str]:
        for name in self.manager.tiers:
            if name not in (tier, "local"):
                return name
        return None
