"""Emerald single-workflow executor — compat shim over the runtime.

Historically this module owned the event-driven dataflow loop. That loop
now lives in :mod:`repro.core.runtime` as the multi-run dispatcher of
``EmeraldRuntime`` (one long-lived scheduler serving N concurrent
workflows over shared lanes); ``EmeraldExecutor`` keeps the original
one-workflow-at-a-time API by submitting into a runtime and blocking on
the handle:

  * constructed the classic way, ``run()`` spins up a private runtime for
    the call and tears it down after — identical lifecycle (and thread
    footprint) to the pre-runtime executor, with the same event stream
    (suspend/offload/resume alternation per step, retries, speculation,
    prefetch, per-completion checkpoints),
  * constructed with ``runtime=``, the executor becomes a typed front-end
    onto a *shared* runtime: several executors (e.g. a server's prefill
    and decode workflows) interleave over one scheduler, one fabric, one
    MDSS — see ``launch/serve.py``,
  * either way the MigrationManager is shared state, so compile caches
    and cost-model statistics survive across ``run()`` calls exactly as
    before.

Checkpoint mechanics are inherited from :class:`RunCheckpointer` — the
executor itself is the per-run checkpointer it hands to the runtime, so
the snapshot-cache invariants (and tests that instrument
``_save_checkpoint``) are preserved.

``Event`` and ``WorkflowFailure`` are defined in ``repro.core.runtime``
and re-exported here for compatibility.

Fan-out steps work through the shim unchanged: the ``PartitionedWorkflow``
handed to the constructor was built by :func:`repro.core.partitioner.
partition`, which expands every ``Fanout``-annotated step into
scatter/shard/gather before this module ever sees it — the executor
dispatches the shards as ordinary independent ready steps.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from repro.core.migration import MigrationManager
from repro.core.partitioner import PartitionedWorkflow
from repro.core.runtime import (EmeraldRuntime, Event,  # noqa: F401
                                RunCheckpointer, RunHandle, WorkflowFailure)


class EmeraldExecutor(RunCheckpointer):
    def __init__(self, pwf: PartitionedWorkflow, manager: MigrationManager,
                 *, policy: str = "annotate", cloud_tier: str = "cloud",
                 max_workers: int = 8, local_workers: int = 4,
                 speculate_after: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None,
                 prefetch: bool = True,
                 runtime: Optional[EmeraldRuntime] = None):
        from repro.core.scheduler import POLICIES
        assert policy in POLICIES
        self.pwf = pwf
        self.manager = manager
        RunCheckpointer.__init__(self, manager.mdss, pwf.workflow,
                                 checkpoint_dir)
        self.policy = policy       # resolved per run by the runtime
        self.cloud_tier = cloud_tier
        self.max_workers = max_workers
        self.local_workers = local_workers
        self.speculate_after = speculate_after
        self.prefetch = prefetch
        self._runtime = runtime           # shared runtime (None = per-run)
        # every run's events (including checkpoint events — submit rebinds
        # the checkpointer's _emit to the run's emitter) land here
        self.events: List[Event] = []
        self._live_handle: Optional[RunHandle] = None

    # ------------------------------------------------------------------- run
    def run(self, init_vars: Dict[str, Any], *, resume: bool = False,
            fetch=None):
        """Execute the workflow (blocking single-run API).

        ``fetch`` limits which variables are synced back to the local tier
        at re-integration (default: all). Leaving hot state (params,
        optimizer state) un-fetched keeps it resident on the cloud tier so
        the next run's offloads are code-only — the paper's MDSS saving.
        """
        return self.submit(init_vars, resume=resume, fetch=fetch).result()

    def submit(self, init_vars: Dict[str, Any], *, resume: bool = False,
               fetch=None, weight: float = 1.0, priority: int = 0
               ) -> RunHandle:
        """Non-blocking variant of :meth:`run` for shared-runtime use.

        With a private (per-call) runtime the handle's lifecycle owns the
        runtime teardown: the lanes are joined when the result resolves,
        exactly like the classic blocking ``run``.

        The executor is its own per-run checkpointer (one snapshot cache,
        one ``<wf>.wfckpt`` file), so with ``checkpoint_dir`` set its runs
        must not overlap — concurrent checkpointed submissions belong on
        ``EmeraldRuntime.submit`` (fresh checkpointer per run) or on
        separate executors.
        """
        if self.checkpoint_dir and self._live_handle is not None \
                and not self._live_handle.done():
            raise RuntimeError(
                "overlapping checkpointed submissions on one executor "
                "would corrupt its checkpoint; use EmeraldRuntime.submit "
                "or one executor per concurrent run")
        rt = self._runtime
        owned = rt is None
        reap = None
        if owned:
            rt = EmeraldRuntime(
                self.manager, policy=self.policy, cloud_tier=self.cloud_tier,
                max_workers=self.max_workers,
                local_workers=self.local_workers,
                speculate_after=self.speculate_after, prefetch=self.prefetch,
                name=f"emerald-{self.wf.name}")

            # tear the private runtime down when the run reaches ANY
            # terminal state (result, failure, cancel) — a caller that
            # never touches result() must not leak the driver + pools.
            # The hook is installed by submit() before the run is
            # enqueued, so even an instantly-finalizing run fires it.
            # close() joins the driver and pools, so the hook runs it on
            # a reaper thread, never on the finalizing thread itself.
            def reap(_handle, _rt=rt):
                threading.Thread(target=_rt.close, daemon=True,
                                 name=f"emerald-{self.wf.name}-reap").start()
        try:
            handle = rt.submit(self.pwf, init_vars, policy=self.policy,
                               fetch=fetch, resume=resume, weight=weight,
                               priority=priority, namespace="",
                               speculate_after=self.speculate_after,
                               prefetch=self.prefetch,
                               checkpointer=self, events=self.events,
                               on_done=reap)
        except BaseException:
            # submission itself failed (e.g. a corrupt checkpoint raising
            # in _load_checkpoint) — no run, no on_done hook, so close the
            # just-created private runtime here instead of leaking it
            if owned:
                rt.close()
            raise
        if owned:
            # result() additionally closes synchronously (idempotent) to
            # preserve the old pools-shut-before-run-returns contract
            handle._close_on_result = rt
        self._live_handle = handle
        return handle
