"""Emerald distributed-execution runtime (paper §3.3 + §6-scale features).

Event-driven dataflow executor over a partitioned workflow's DAG:

  * non-remotable steps run on the local tier,
  * at a migration point the workflow *suspends*, the target step offloads
    through the MigrationManager, then execution *resumes* — strictly
    alternating per step (Property 3),
  * scheduling is **completion-triggered**: every finished step (local or
    offloaded) immediately decrements its successors' in-degree and
    newly-ready steps dispatch at once — there is no wave barrier, so a
    1-second offload unlocks its downstream work while a 30-second sibling
    is still running (paper Fig 9b taken to its conclusion),
  * local steps run on their own worker lane, never blocking the driver's
    harvest of offload completions,
  * when more steps are ready than workers, dispatch order follows the
    scheduler policy's priority hook (critical-path-length first),
  * dispatching a step also **prefetches** its likely successors' inputs
    onto the cloud tier (``MDSS.prefetch``) so transfer overlaps compute,
  * offload policy: ``annotate`` (paper-faithful: every remotable step goes
    to the cloud), ``cost_model`` (beyond-paper: offload only when the
    roofline model predicts benefit), ``never`` (paper's baseline arm).

Scale features (DESIGN.md §6):
  * retry with tier fallback — a failed offload re-runs, ultimately locally,
  * straggler speculation — a remotable step that overruns
    ``speculate_after`` x its EMA runtime is duplicated on another tier;
    the first *successful* finisher wins (a fast failure does not beat a
    slower success), and the loser's write-back is version-fenced,
  * checkpoints are incremental: every completion is durable as soon as it
    happens, and a sibling's failure never abandons finished work — the
    runtime drains in-flight steps, checkpoints the survivors, then raises.
"""
from __future__ import annotations

import heapq
import os
import pickle
import queue
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.mdss import MDSS, nbytes_of
from repro.core.migration import MigrationManager, StepFailure
from repro.core.partitioner import PartitionedWorkflow
from repro.core.scheduler import critical_path_lengths, make_policy
from repro.core.workflow import Step


@dataclass
class Event:
    kind: str          # suspend | offload | resume | local | retry |
                       # speculate | prefetch | checkpoint
    step: str
    tier: str = ""
    t: float = 0.0
    info: dict = field(default_factory=dict)


class WorkflowFailure(RuntimeError):
    pass


class EmeraldExecutor:
    def __init__(self, pwf: PartitionedWorkflow, manager: MigrationManager,
                 *, policy: str = "annotate", cloud_tier: str = "cloud",
                 max_workers: int = 8, local_workers: int = 4,
                 speculate_after: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None,
                 prefetch: bool = True):
        assert policy in ("annotate", "cost_model", "never")
        self.pwf = pwf
        self.wf = pwf.workflow
        self.manager = manager
        self.mdss = manager.mdss
        self.policy = policy
        self._policy = make_policy(policy, manager.cost_model, manager.mdss,
                                   cloud_tier)
        self.cloud_tier = cloud_tier
        self.max_workers = max_workers
        self.local_workers = local_workers
        self.speculate_after = speculate_after
        self.checkpoint_dir = checkpoint_dir
        self.prefetch = prefetch
        self.events: List[Event] = []
        self._lock = threading.Lock()
        # uri -> (version, host snapshot), fed ONLY from init/resume vars
        # and the outputs of harvested completions. Checkpoints snapshot
        # this cache, never the live store, so a checkpoint can't capture
        # the published outputs of a step that is still in flight (which
        # resume would then double-apply on a non-idempotent step). Also
        # keeps the per-completion pull O(changed vars); the full-snapshot
        # pickle write itself remains O(vars).
        self._ckpt_cache: Dict[str, tuple] = {}

    # ---------------------------------------------------------------- events
    def _emit(self, kind, step, tier="", **info):
        with self._lock:
            self.events.append(Event(kind, step, tier, time.perf_counter(), info))

    # ------------------------------------------------------------ checkpoint
    def _ckpt_path(self):
        return os.path.join(self.checkpoint_dir, f"{self.wf.name}.wfckpt")

    def _cache_var(self, uri: str):
        """Snapshot ``uri``'s freshest value into the checkpoint cache
        (skip if the cached version is already current). Uses a reference
        read (``peek_latest``) — no cross-tier transfer lands on the
        driver thread for checkpointing."""
        val, ver = self.mdss.peek_latest(uri)
        if ver and self._ckpt_cache.get(uri, (0, None))[0] != ver:
            self._ckpt_cache[uri] = (ver, jax.tree.map(np.asarray, val))

    def _cache_outputs(self, harvested: Step):
        """Snapshot a harvested step's outputs into the checkpoint cache.

        Must run BEFORE the step's successors dispatch: the outputs are
        final right now (WAW/WAR edges keep any later writer blocked until
        this harvest), so the reference read snapshots exactly what was
        published — no transfer involved. The pickle write itself
        (``_save_checkpoint``) has no ordering constraint and runs after
        dispatch, off the critical path.
        """
        if self.checkpoint_dir:
            for uri in harvested.outputs:
                self._cache_var(uri)

    def _save_checkpoint(self, completed):
        if not self.checkpoint_dir:
            return
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        snapshot = {uri: val for uri, (_, val) in self._ckpt_cache.items()}
        tmp = self._ckpt_path() + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump({"completed": sorted(completed), "vars": snapshot}, f)
        os.replace(tmp, self._ckpt_path())
        self._emit("checkpoint", "<workflow>", n=len(completed))

    def _load_checkpoint(self):
        if not self.checkpoint_dir or not os.path.exists(self._ckpt_path()):
            return None
        with open(self._ckpt_path(), "rb") as f:
            return pickle.load(f)

    # ------------------------------------------------------------------- run
    def run(self, init_vars: Dict[str, Any], *, resume: bool = False,
            fetch=None):
        """Execute the workflow.

        ``fetch`` limits which variables are synced back to the local tier
        at re-integration (default: all). Leaving hot state (params,
        optimizer state) un-fetched keeps it resident on the cloud tier so
        the next run's offloads are code-only — the paper's MDSS saving.
        """
        return self._run(init_vars, resume=resume, fetch=fetch)

    def _run(self, init_vars: Dict[str, Any], *, resume: bool = False,
             fetch=None):
        completed: set = set()
        for uri, val in init_vars.items():
            if uri not in self.wf.variables:
                self.wf.var(uri)
            self.mdss.put(uri, val, tier="local")
        if resume:
            state = self._load_checkpoint()
            if state is not None:
                completed = set(state["completed"])
                for uri, val in state["vars"].items():
                    self.mdss.put(uri, val, tier="local")
        if self.checkpoint_dir:
            # seed from EVERY resident variable (init/resume vars and state
            # carried over from a previous run on this MDSS): nothing is in
            # flight yet, so everything resident is completed work and
            # belongs in the snapshots
            for uri in self.wf.variables:
                self._cache_var(uri)

        steps = {s.name: s for s in self.wf.toplevel()}
        completed &= set(steps)
        # one dependency-graph build feeds all three views
        deps = self.wf.dependencies()
        succs = self.wf.successors(deps=deps)
        indeg = self.wf.in_degrees(completed, deps=deps)
        order_idx = {n: i for i, n in enumerate(self.wf.order)}
        if hasattr(self._policy, "set_priorities"):
            self._policy.set_priorities(critical_path_lengths(
                self.wf, self.manager.cost_model, self.cloud_tier,
                succ=succs))

        # completion queue: worker lanes push (step, error, offloaded?),
        # the driver reacts to each completion individually — no barrier
        done_q: "queue.SimpleQueue" = queue.SimpleQueue()
        # per-lane priority heaps + busy counts: a step is SUBMITTED only
        # when its lane has a free worker, so a high-priority step that
        # becomes ready later still overtakes queued low-priority work
        ready_off: List[tuple] = []
        ready_loc: List[tuple] = []
        busy = {True: 0, False: 0}           # keyed by offloaded?
        failures: List[BaseException] = []
        offload_pool = ThreadPoolExecutor(max_workers=self.max_workers,
                                          thread_name_prefix="emerald-offload")
        local_pool = ThreadPoolExecutor(max_workers=self.local_workers,
                                        thread_name_prefix="emerald-local")

        def push_ready(name: str):
            s = steps[name]
            prio = 0.0
            if hasattr(self._policy, "dispatch_priority"):
                prio = self._policy.dispatch_priority(s)
            heap = ready_off if self._should_offload(s) else ready_loc
            heapq.heappush(heap, (-prio, order_idx[name], name))

        def dispatch():
            for heap, offload, pool, fn, slots in (
                    (ready_off, True, offload_pool,
                     self._offload_with_recovery, self.max_workers),
                    (ready_loc, False, local_pool, self._run_local,
                     self.local_workers)):
                while heap and busy[offload] < slots and not failures:
                    _, _, name = heapq.heappop(heap)
                    s = steps[name]
                    self._prefetch_successors(s, succs)
                    if offload:
                        self._emit("suspend", s.name)
                    pool.submit(self._lane, fn, s, done_q, offload)
                    busy[offload] += 1

        for n, d in indeg.items():
            if d == 0:
                push_ready(n)
        try:
            dispatch()
            while len(completed) < len(steps):
                if busy[True] + busy[False] == 0:
                    if failures:
                        raise failures[0]
                    raise WorkflowFailure("dependency cycle or failed step")
                name, err, offloaded = done_q.get()
                busy[offloaded] -= 1
                if err is not None:
                    failures.append(err)
                    continue                 # keep draining siblings
                if offloaded:
                    self._emit("resume", name)
                completed.add(name)
                self._cache_outputs(steps[name])
                for m in succs.get(name, ()):
                    if m in indeg and m not in completed:
                        indeg[m] -= 1
                        if indeg[m] == 0:
                            push_ready(m)
                dispatch()
                # durable per completion, not per wave: a later sibling
                # failure cannot lose this step's work. Written after
                # dispatch so THIS completion's successors start before the
                # pickle lands (completions arriving during the write still
                # wait — the durability-first tradeoff of sync checkpoints).
                self._save_checkpoint(completed)
        finally:
            offload_pool.shutdown(wait=True)
            local_pool.shutdown(wait=True)
            self._ckpt_cache.clear()     # release pinned host copies
        # re-integrate: requested workflow variables synced back to local
        uris = fetch if fetch is not None else [
            u for u in self.wf.variables if self.mdss.version(u)]
        return {uri: self.mdss.get(uri, "local") for uri in uris
                if self.mdss.version(uri)}

    # -------------------------------------------------------------- dispatch
    def _lane(self, fn, s: Step, done_q, offloaded: bool):
        try:
            fn(s)
            done_q.put((s.name, None, offloaded))
        except BaseException as e:           # harvested by the driver
            done_q.put((s.name, e, offloaded))

    def _prefetch_successors(self, s: Step, succs):
        """Warm the cloud tier with a dispatched step's successors' inputs.

        Only inputs that already exist and are stale on the cloud tier
        move; outputs of still-running steps are skipped (MDSS.prefetch is
        best-effort and version-hazard-checked), so the transfer safely
        overlaps this step's compute.
        """
        if not self.prefetch or self.cloud_tier not in self.manager.tiers:
            return
        for m in succs.get(s.name, ()):
            succ = self.wf.steps[m]
            if not self._should_offload(succ):
                continue
            # skip vars s itself is about to rewrite: their current
            # version is guaranteed dead by the time the successor reads
            uris = [u for u in succ.inputs
                    if u not in s.outputs
                    and self.mdss.version(u)
                    and not self.mdss.has_latest(u, self.cloud_tier)]
            if uris and self.mdss.prefetch(uris, self.cloud_tier) is not None:
                # emitted only for ADMITTED requests (None = shed at the
                # MDSS concurrency cap), so the event log matches reality
                self._emit("prefetch", succ.name, self.cloud_tier, uris=uris)

    # -------------------------------------------------------------- policies
    def _should_offload(self, s: Step) -> bool:
        return self._policy.should_offload(s)

    # ------------------------------------------------------------- execution
    def _run_local(self, s: Step):
        rep = self.manager.execute(s, "local")
        self._emit("local", s.name, "local", seconds=rep.seconds)

    def _offload_with_recovery(self, s: Step):
        tiers_to_try = [self.cloud_tier] * max(1, s.retries) + ["local"]
        last_err = None
        for attempt, tier in enumerate(tiers_to_try):
            try:
                rep = self._execute_maybe_speculative(s, tier)
                self._emit("offload", s.name, rep.tier,
                           seconds=rep.seconds, bytes_in=rep.bytes_in,
                           bytes_out=rep.bytes_out, code_only=rep.code_only,
                           attempt=attempt, remote=rep.remote,
                           worker_pid=rep.worker_pid)
                return rep
            except StepFailure as e:      # node failure -> retry / fallback
                last_err = e
                self._emit("retry", s.name, tier, attempt=attempt,
                           error=str(e))
        raise WorkflowFailure(f"step {s.name} failed on all tiers: {last_err}")

    def _execute_maybe_speculative(self, s: Step, tier: str):
        alt = self._alternate_tier(tier)
        est = self.manager.cost_model.stats_for(s.name).measured_s.get(tier)
        if self.speculate_after is None or alt is None or est is None:
            return self.manager.execute(s, tier)
        timeout = est * self.speculate_after
        # no context manager: pool shutdown must NOT join the straggler
        spool = ThreadPoolExecutor(max_workers=2)
        try:
            primary = spool.submit(self.manager.execute, s, tier)
            done, _ = wait([primary], timeout=timeout)
            if done:
                return primary.result()
            self._emit("speculate", s.name, alt, timeout=timeout)
            backup = spool.submit(self.manager.execute, s, alt)
            # first *successful* finisher wins: a primary that fails fast
            # right after the backup launches must not fail the step
            pending = {primary, backup}
            last_err, fenced_rep = None, None
            while pending:
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                for f in done:
                    try:
                        rep = f.result()
                    except StepFailure as e:
                        last_err = e
                        continue
                    if rep.fenced:
                        # the loser's report (its publish was refused) —
                        # keep only as a fallback so the recorded offload
                        # event reflects the twin that actually published
                        fenced_rep = rep
                        continue
                    return rep
            if fenced_rep is not None:
                return fenced_rep
            raise last_err                   # both twins failed
        finally:
            spool.shutdown(wait=False)

    def _alternate_tier(self, tier: str) -> Optional[str]:
        for name in self.manager.tiers:
            if name not in (tier, "local"):
                return name
        return None
