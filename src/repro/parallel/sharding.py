"""Logical-axis-rule sharding (MaxText-style), with divisibility fallback.

A *rule set* maps logical dim names (declared by ``ParamSpec.axes`` and by
activation constraints in the model code) to tuples of mesh axis names.
``resolve(rules, axes, shape, mesh)`` produces a ``PartitionSpec``:

  * mesh axes not present in the mesh are dropped,
  * a rule whose mesh-axis product does not divide the dim size is dropped
    (replicate instead) — this is what makes one rule set serve every arch
    (e.g. kv_heads=8 on a 16-way model axis falls back to replication while
    the KV *cache* stays sharded along its seq dim),
  * each mesh axis is used at most once per spec (first dim wins).

Presets:
  * ``dp_tp``  — paper-faithful baseline: batch over (pod,data); vocab/heads/
    ff/experts over model; params otherwise replicated.
  * ``fsdp``   — dp_tp + parameter/optimizer-state sharding over the data
    axis (ZeRO-3 style), the production default.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule presets.  Logical names:
#   params : embed ff heads kv_heads head_dim vocab experts q_lora kv_lora
#            ssm_inner ssm_state dt_rank conv_k layers
#   acts   : act_batch act_seq act_embed act_ff act_heads act_kv_seq act_vocab
# ---------------------------------------------------------------------------

def _mk(d):
    return {k: tuple(v) if isinstance(v, (list, tuple)) else (v,)
            for k, v in d.items()}

DP_TP_RULES: Rules = _mk({
    # parameters
    "vocab": "model",
    "heads": "model",
    "ff": "model",
    "experts": "model",
    "ssm_inner": "model",
    "q_lora": "model",
    # activations
    "act_batch": ("pod", "data"),
    "act_ff": "model",
    "act_heads": "model",
    "act_vocab": "model",
    "act_ssm_inner": "model",
    "act_kv_seq": "model",     # decode KV cache sharded along sequence
    "act_experts": "model",
    "act_moe_group": ("pod", "data"),   # MoE token-group dim
})

FSDP_RULES: Rules = dict(DP_TP_RULES, **_mk({
    # additionally shard the big param matrices over the data axis (ZeRO-3).
    "embed": ("data",),
    "moe_ff": ("model",),
    "kv_lora": ("data",),
}))

# Pure ZeRO-3 data parallelism: the model axis becomes extra batch
# parallelism; params/optimizer state shard 256-way on their leading big
# dim; no tensor parallelism (no activation collectives). The right regime
# for models whose per-layer matmuls are too small to amortize TP
# collectives (see EXPERIMENTS.md §Perf, tinyllama hillclimb).
ZERO_DP_RULES: Rules = _mk({
    "embed": ("data", "model"),
    "ff": ("data", "model"),
    "vocab": ("data", "model"),
    "moe_ff": ("data", "model"),
    "experts": ("data", "model"),
    "ssm_inner": ("data", "model"),
    "q_lora": ("data", "model"),
    "kv_lora": ("data", "model"),
    "act_batch": ("pod", "data", "model"),
    "act_kv_seq": ("model",),
})

PRESETS: Dict[str, Rules] = {"dp_tp": DP_TP_RULES, "fsdp": FSDP_RULES,
                             "zero_dp": ZERO_DP_RULES}


def get_rules(preset: str, overrides: Sequence[Tuple[str, Tuple[str, ...]]] = ()) -> Rules:
    rules = dict(PRESETS[preset])
    for k, v in overrides:
        if v is None or v == ():
            rules.pop(k, None)
        else:
            rules[k] = tuple(v) if isinstance(v, (list, tuple)) else (v,)
    return rules


# ---------------------------------------------------------------------------
# Resolution.
# ---------------------------------------------------------------------------

def resolve(rules: Rules, axes: Tuple[Optional[str], ...],
            shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Logical axes + dim sizes -> PartitionSpec, with fallbacks."""
    used = set()
    parts = []
    for name, size in zip(axes, shape):
        entry: Tuple[str, ...] = rules.get(name, ()) if name else ()
        picked = []
        prod = 1
        for ax in entry:
            if ax not in mesh.shape or ax in used:
                continue
            nax = mesh.shape[ax]
            if size % (prod * nax) != 0:
                continue
            picked.append(ax)
            prod *= nax
        for ax in picked:
            used.add(ax)
        if not picked:
            parts.append(None)
        elif len(picked) == 1:
            parts.append(picked[0])
        else:
            parts.append(tuple(picked))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_pspecs(rules: Rules, axes_tree, abstract_tree, mesh: Mesh):
    """Pytree of logical-axes tuples + abstract values -> pytree of PartitionSpec."""
    def one(axes, aval):
        return resolve(rules, axes, aval.shape, mesh)
    return jax.tree.map(one, axes_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def tree_shardings(rules: Rules, axes_tree, abstract_tree, mesh: Mesh):
    specs = tree_pspecs(rules, axes_tree, abstract_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, rules: Rules, *names: Optional[str]):
    """Sharding-constrain an activation by logical dim names (no-op w/o mesh)."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = resolve(rules, tuple(names), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def get_abstract_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            # physical mesh if inside a `with mesh:` context
            pm = getattr(m, "_raw_mesh", None)
            return pm if pm is not None else m
    except Exception:
        pass
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m
