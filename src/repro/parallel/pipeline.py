"""Pipeline parallelism over the ``pod`` axis (GPipe-style).

Cross-pod links (DCI) are the slowest in a multi-pod system. Data
parallelism over pods costs a full gradient reduction (~2 x params bytes)
per step; *pipeline* parallelism over pods costs only boundary activations
(n_micro x microbatch activation size) — far less for big models. This
module provides the PP alternative so the cross-pod axis can be chosen per
model (see EXPERIMENTS.md §Perf multi-pod analysis).

Mechanics (partial-manual ``shard_map`` over ``pod``; auto over data/model):

  * each LM stage's stacked layer params shard their leading (layers) dim
    over ``pod`` — pod *p* owns a contiguous slice of layers,
  * activations rotate pod->pod with ``ppermute`` on a GPipe schedule:
    at tick t, pod s processes microbatch t-s; pod 0 injects embeddings,
    the last pod computes loss on valid ticks,
  * reverse-mode AD transposes the ppermutes automatically, so one
    ``jax.grad`` yields the full pipelined backward,
  * embedding/head params are replicated across pods; their gradients are
    psum'd explicitly (manual region).

Constraints: every stage's layer count must divide by n_pods; global batch
must divide by n_micro.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.layers import lm_logits, rmsnorm, xent_loss
from repro.optim.optimizers import clip_by_global_norm


def _split_microbatches(batch, n_micro):
    def sp(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
    return jax.tree.map(sp, batch)


def pipeline_train_step(model, mesh, n_micro: int) -> Callable:
    """Build a pipelined train step for a decoder-only dense/MoE LM."""
    assert "pod" in mesh.shape
    n_stages = mesh.shape["pod"]
    cfg, run = model.cfg, model.run
    rules = dict(model.rules)
    rules["act_batch"] = ("data",)          # pod axis is manual here
    opt_update, schedule = model.opt_update, model.schedule
    stages = cfg.stages()
    assert not cfg.is_encoder_decoder, "PP path covers decoder-only archs"
    for _, reps in stages:
        assert reps % n_stages == 0, f"stage depth {reps} % pods {n_stages}"

    def per_pod(params, opt_state, batch):
        s_idx = jax.lax.axis_index("pod")

        def loss_fn(params):
            micro = _split_microbatches(batch, n_micro)
            B_m = micro["tokens"].shape[1]
            S = micro["tokens"].shape[2]
            T = n_micro + n_stages - 1
            buf = jnp.zeros((B_m, S, cfg.d_model), jnp.dtype(cfg.dtype))
            total = jnp.zeros((), jnp.float32)
            aux_total = jnp.zeros((), jnp.float32)
            for t in range(T):
                # stage 0 injects microbatch t (if any)
                if t < n_micro:
                    x_in = tfm.embed_inputs(
                        cfg, params, jax.tree.map(lambda v: v[t], micro),
                        rules)
                    buf = jnp.where(s_idx == 0, x_in, buf)
                # every pod applies its resident layer slice
                buf, _, aux = tfm.run_stages(cfg, run, params, buf, rules,
                                             mode="full")
                aux_total = aux_total + aux
                # last pod emits microbatch m = t - (n_stages-1)
                m = t - (n_stages - 1)
                if 0 <= m < n_micro:
                    h = rmsnorm(cfg, params["final_norm"], buf)
                    logits = lm_logits(cfg, params["embed"], h, rules)
                    loss_m = xent_loss(cfg, logits[:, :-1],
                                       micro["labels"][m][:, 1:])
                    total = total + jnp.where(s_idx == n_stages - 1,
                                              loss_m, 0.0)
                # rotate the pipe
                buf = jax.lax.ppermute(
                    buf, "pod",
                    [(i, (i + 1) % n_stages) for i in range(n_stages)])
            loss = jax.lax.psum(total, "pod") / n_micro
            return loss + jax.lax.psum(aux_total, "pod") / n_micro, loss

        (loss, xent), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        # layer grads are pod-resident; replicated params (embed, norms)
        # need the explicit cross-pod reduction
        def sync_replicated(path, g):
            name = path[0].key if path else ""
            if name.startswith("stage_"):
                return g
            # f32 cast: direct bf16 psum trips an XLA:CPU crash under
            # partial-manual shard_map (same bug as grad_compress.py)
            return jax.lax.psum(g.astype(jnp.float32), "pod").astype(g.dtype)
        grads = jax.tree_util.tree_map_with_path(sync_replicated, grads)
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        lr = schedule(opt_state["step"] + 1)
        params, opt_state = opt_update(params, grads, opt_state, lr=lr)
        metrics = {"loss": loss, "xent": xent, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    def param_specs(tree):
        """stage params: layers dim manual over pod; rest replicated."""
        def leaf_spec(path, leaf):
            name = path[0].key if path else ""
            return P("pod") if name.startswith("stage_") else P()
        return jax.tree_util.tree_map_with_path(leaf_spec, tree)

    assert run.optimizer == "adamw", "PP path wires adamw state sharding"
    p_specs = param_specs(model.abstract_params())
    o_specs = {"mu": p_specs, "nu": p_specs, "step": P()}

    return jax.shard_map(
        per_pod, mesh=mesh,
        in_specs=(p_specs, o_specs, P()),
        out_specs=(p_specs, o_specs, P()),
        axis_names={"pod"}, check_vma=False)
