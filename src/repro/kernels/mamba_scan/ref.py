"""Pure-jnp oracle for the Mamba-1 selective scan (chunked associative scan).

Recurrence (diagonal SSM):
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t        h: (di, N)
    y_t = <h_t, C_t> + D * x_t

The chunked form keeps the materialized (B, Lc, di, N) working set bounded:
within a chunk an associative scan computes (prefix-decay, state) pairs with
h0 = 0; the true state is  h_t = scan_t + prefix_decay_t * h_chunk_start.
The chunk loop is a *python* loop (unrolled in HLO) by design — XLA's
cost_analysis does not multiply while-loop bodies by trip count, and the
dry-run roofline reads from it (see DESIGN.md / EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _chunk_scan(a, b):
    """Associative scan over axis 1 of (decay, value) pairs."""
    def op(l, r):
        return l[0] * r[0], r[0] * l[1] + r[1]
    return jax.lax.associative_scan(op, (a, b), axis=1)


def selective_scan_ref(x, dt, A, B, C, D, h0, *, chunk: int = 512):
    """x,dt: (Bt,L,di); A: (di,N); B,C: (Bt,L,N); D: (di,); h0: (Bt,di,N).

    Returns (y: (Bt,L,di) x.dtype, h_last: (Bt,di,N) f32).
    """
    Bt, L, di = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    # ragged final chunk is handled by the slice bounds below
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    h = h0.astype(jnp.float32)
    ys = []
    for c0 in range(0, L, chunk):
        sl = slice(c0, c0 + chunk)
        dt_c, x_c = dtf[:, sl], xf[:, sl]
        a = jnp.exp(dt_c[..., None] * Af)                      # (Bt,Lc,di,N)
        b = (dt_c * x_c)[..., None] * Bf[:, sl][:, :, None, :]
        a_cum, s = _chunk_scan(a, b)
        hc = s + a_cum * h[:, None]                            # (Bt,Lc,di,N)
        y = jnp.einsum("blds,bls->bld", hc, Cf[:, sl])
        ys.append(y + D.astype(jnp.float32) * x_c)
        h = hc[:, -1]
    return jnp.concatenate(ys, axis=1).astype(x.dtype), h


def selective_scan_blocked(x, dt, A, B, C, D, h0, *, block: int = 32,
                           chunk: int = 8192):
    """Two-level blocked scan — the memory-lean lowerable formulation.

    The associative scan costs ~log2(L) full-tensor passes over the
    materialized (B, L, d, N) pair tensors. Splitting time into blocks of
    ``block`` and doing the *within-block* recurrence as a python loop over
    block-position SLICES (each 1/block of the tensor) costs ~O(1)
    full-tensor passes for level 1, a tiny boundary scan at level 2 (one
    element per block), and one broadcast pass at level 3 — ~3-5x less HBM
    traffic than the associative scan for typical L (the §Perf falcon
    hillclimb measures it). Same math, validated against selective_scan_ref.
    """
    Bt, L, di = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    h = h0.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)
    ys = []
    for c0 in range(0, L, chunk):
        Lc = min(chunk, L - c0)
        bs = min(block, Lc)
        nb = Lc // bs
        rem = Lc - nb * bs                      # ragged tail handled below
        sl = slice(c0, c0 + nb * bs)
        dt_c = dt[:, sl].astype(jnp.float32)
        x_c = x[:, sl].astype(jnp.float32)
        a = jnp.exp(dt_c[..., None] * Af).reshape(Bt, nb, bs, di, N)
        b = ((dt_c * x_c)[..., None]
             * B[:, sl].astype(jnp.float32)[:, :, None, :]
             ).reshape(Bt, nb, bs, di, N)
        # level 1: sequential within block over slices (vectorized over nb)
        As = [a[:, :, 0]]
        Bs = [b[:, :, 0]]
        for t in range(1, bs):
            As.append(a[:, :, t] * As[-1])
            Bs.append(a[:, :, t] * Bs[-1] + b[:, :, t])
        A_cum = jnp.stack(As, axis=2)           # (Bt, nb, bs, d, N)
        B_cum = jnp.stack(Bs, axis=2)
        # level 2: exclusive prefix over block boundary states (tiny)
        Ab, Bb = A_cum[:, :, -1], B_cum[:, :, -1]    # (Bt, nb, d, N)
        Ap, Bp = _chunk_scan(Ab, Bb)                 # inclusive over nb
        Ap = jnp.concatenate([jnp.ones_like(Ap[:, :1]), Ap[:, :-1]], 1)
        Bp = jnp.concatenate([jnp.zeros_like(Bp[:, :1]), Bp[:, :-1]], 1)
        h_start = Bp + Ap * h[:, None]               # h at each block start
        # level 3: combine
        hc = B_cum + A_cum * h_start[:, :, None]
        h = hc[:, -1, -1]
        hc = hc.reshape(Bt, nb * bs, di, N)
        y = jnp.einsum("blds,bls->bld", hc,
                       C[:, sl].astype(jnp.float32))
        ys.append(y + Df * x_c)
        if rem:                                  # sequential ragged tail
            tail = slice(c0 + nb * bs, c0 + Lc)
            y_t, h = selective_scan_ref(x[:, tail], dt[:, tail], A,
                                        B[:, tail], C[:, tail], D, h,
                                        chunk=rem)
            ys.append(y_t.astype(jnp.float32))
    return jnp.concatenate(ys, axis=1).astype(x.dtype), h


def selective_step_ref(x, dt, A, B, C, D, h):
    """Single-token decode step. x,dt: (Bt,di); B,C: (Bt,N); h: (Bt,di,N)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A.astype(jnp.float32))
    h = a * h + (dtf * xf)[..., None] * B.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, C.astype(jnp.float32))
    return (y + D.astype(jnp.float32) * xf).astype(x.dtype), h
