"""Jitted wrapper for the selective scan: Pallas on TPU, closed-form-VJP
associative scan elsewhere (and for dry-run lowering).

The linear recurrence  h_t = a_t h_{t-1} + b_t  has a closed-form adjoint:

    lam_t = g_t + a_{t+1} lam_{t+1}        (reverse linear scan)
    db_t  = lam_t
    da_t  = lam_t * h_{t-1}
    dh_0  = a_1 lam_1

so the backward pass is ONE more associative scan plus elementwise ops —
letting JAX differentiate *through* the associative scan instead costs
~100 tensor passes (measured; see EXPERIMENTS.md §Perf falcon iteration).
This is the same structure the original Mamba CUDA kernel uses; here it is
the jnp/XLA path, and the TPU Pallas kernel slots into the same custom_vjp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import ref
from repro.kernels.mamba_scan.kernel import selective_scan_fwd


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def _scan(x, dt, A, B, C, D, h0, chunk):
    return selective_scan_fwd(x, dt, A, B, C, D, h0, chunk=chunk)


def _scan_fwd(x, dt, A, B, C, D, h0, chunk):
    return _scan(x, dt, A, B, C, D, h0, chunk), (x, dt, A, B, C, D, h0)


def _scan_bwd(chunk, res, g):
    x, dt, A, B, C, D, h0 = res
    return _closed_form_bwd(x, dt, A, B, C, D, h0, g,
                            chunk=_mem_chunk(chunk, x))


_scan.defvjp(_scan_fwd, _scan_bwd)


def _mem_chunk(chunk: int, x) -> int:
    """Outer chunk bounding the (B, chunk, d, N) working set."""
    return min(x.shape[1], max(chunk, 4096))


# ---------------------------------------------------------------------------
# Closed-form-adjoint selective scan (the jnp / lowering path).
# ---------------------------------------------------------------------------

def _ab(x, dt, A, B, sdt=jnp.float32):
    a = jnp.exp(dt[..., None] * A).astype(sdt)             # (Bt,L,d,N)
    b = ((dt * x)[..., None] * B[:, :, None, :]).astype(sdt)
    return a, b


def _fwd_states(x, dt, A, B, h0, chunk, sdt=jnp.float32):
    """All states h_{1..T} plus h_{0..T-1}, chunked associative scans.

    ``sdt`` sets the materialization dtype of the (B,L,d,N) scan tensors —
    bf16 halves the dominant HBM traffic of SSM training at a measured
    ~1e-2 relative output error (see EXPERIMENTS.md §Perf falcon)."""
    Bt, L, di = x.shape
    hs = []
    h = h0.astype(sdt)
    for c0 in range(0, L, chunk):
        sl = slice(c0, min(c0 + chunk, L))
        a, b = _ab(x[:, sl], dt[:, sl], A, B[:, sl], sdt)
        a_cum, s = ref._chunk_scan(a, b)
        hc = s + a_cum * h[:, None]
        hs.append(hc)
        h = hc[:, -1]
    return jnp.concatenate(hs, axis=1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8))
def _cf_scan(x, dt, A, B, C, D, h0, chunk, sdt):
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    h = _fwd_states(xf, dtf, A.astype(jnp.float32),
                    B.astype(jnp.float32), h0.astype(jnp.float32), chunk,
                    sdt)
    y = jnp.einsum("blds,bls->bld", h.astype(jnp.float32),
                   C.astype(jnp.float32))
    y = y + D.astype(jnp.float32) * xf
    return y.astype(x.dtype), h[:, -1].astype(jnp.float32)


def _cf_fwd(x, dt, A, B, C, D, h0, chunk, sdt):
    return _cf_scan(x, dt, A, B, C, D, h0, chunk, sdt), (x, dt, A, B, C, D, h0)


def _closed_form_bwd(x, dt, A, B, C, D, h0, cotangents, *, chunk,
                     sdt=jnp.float32):
    y_bar, hlast_bar = cotangents
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af, Bf, Cf = (t.astype(jnp.float32) for t in (A, B, C))
    yb = y_bar.astype(jnp.float32)
    Bt, L, di = x.shape

    h = _fwd_states(xf, dtf, Af, Bf, h0.astype(jnp.float32), chunk, sdt)
    h_prev = jnp.concatenate([h0.astype(sdt)[:, None], h[:, :-1]], 1)
    a, _ = _ab(xf, dtf, Af, Bf, sdt)

    # g_t = ybar_t (x) C_t  (+ final-state cotangent at T)
    g = (yb[..., None] * Cf[:, :, None, :]).astype(sdt)
    g = g.at[:, -1].add(hlast_bar.astype(sdt))
    # lam_t = g_t + a_{t+1} lam_{t+1}: reverse linear scan with shifted decay
    a_shift = jnp.concatenate([a[:, 1:], jnp.ones_like(a[:, :1])], axis=1)
    lam_chunks = []
    lam_carry = jnp.zeros(h0.shape, sdt)
    for c0 in reversed(range(0, L, chunk)):
        sl = slice(c0, min(c0 + chunk, L))
        ar = jnp.flip(a_shift[:, sl], 1)
        gr = jnp.flip(g[:, sl], 1)
        a_cum, s = ref._chunk_scan(ar, gr)
        lam_r = s + a_cum * lam_carry[:, None]
        lam_carry = lam_r[:, -1]
        lam_chunks.append(jnp.flip(lam_r, 1))
    lam = jnp.concatenate(lam_chunks[::-1], axis=1)        # (Bt,L,d,N)

    lam = lam.astype(jnp.float32) if lam.dtype != jnp.float32 else lam
    h_prev = h_prev.astype(jnp.float32)
    a = a.astype(jnp.float32)
    h = h.astype(jnp.float32)
    a_bar = lam * h_prev
    # a = exp(dt A):  dt_bar += sum_n a_bar a A ;  A_bar += sum_t a_bar a dt
    aa = a_bar * a
    dt_bar = jnp.einsum("blds,ds->bld", aa, Af)
    A_bar = jnp.einsum("blds,bld->ds", aa, dtf)
    # b = (dt x) (x) B: lam is b_bar
    lamB = jnp.einsum("blds,bls->bld", lam, Bf)
    dt_bar = dt_bar + xf * lamB
    x_bar = dtf * lamB + D.astype(jnp.float32) * yb
    B_bar = jnp.einsum("blds,bld->bls", lam, dtf * xf)
    C_bar = jnp.einsum("blds,bld->bls", h, yb)
    D_bar = jnp.einsum("bld,bld->d", yb, xf)
    h0_bar = a[:, 0] * lam[:, 0]
    return (x_bar.astype(x.dtype), dt_bar.astype(dt.dtype),
            A_bar.astype(A.dtype), B_bar.astype(B.dtype),
            C_bar.astype(C.dtype), D_bar.astype(D.dtype),
            h0_bar.astype(h0.dtype))


def _cf_bwd(chunk, sdt, res, cot):
    x, dt, A, B, C, D, h0 = res
    return _closed_form_bwd(x, dt, A, B, C, D, h0, cot, chunk=chunk, sdt=sdt)


_cf_scan.defvjp(_cf_fwd, _cf_bwd)


def selective_scan(x, dt, A, B, C, D, h0, *, chunk: int = 512,
                   scan_dtype: str = "float32"):
    """Public op; see ref.selective_scan_ref for shapes.

    TPU: Pallas sequential-in-VMEM kernel. Elsewhere (and for the dry-run
    lowering): associative scan with the closed-form adjoint.
    """
    if _on_tpu():
        return _scan(x, dt, A, B, C, D, h0, chunk)
    return _cf_scan(x, dt, A, B, C, D, h0, _mem_chunk(chunk, x),
                    jnp.dtype(scan_dtype))


selective_step = ref.selective_step_ref
