"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation (vs. the CUDA selective-scan): the sequence is chunked on the
*grid* — grid = (B, n_dblocks, n_chunks) with chunks innermost so the SSM
state for one (batch, channel-block) stays resident in VMEM scratch across
chunk steps; within a chunk the recurrence runs as a ``fori_loop`` over
timesteps on (bd, N) tiles. Channels are blocked (``block_d``) so the
working set (chunk x bd inputs + bd x N state) fits VMEM.

NOTE on layout: N (ssm state, typically 16) rides the lane dim; production
tuning would pad N->128 or interleave channels into lanes. Correctness is
validated in interpret mode (this container is CPU-only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                 y_ref, hlast_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)      # (bd, N)

    A = a_ref[...].astype(jnp.float32)                  # (bd, N)
    Dv = d_ref[...].astype(jnp.float32)                 # (bd,)

    def body(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)         # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)       # (bd,)
        Bt = b_ref[0, t, :].astype(jnp.float32)         # (N,)
        Ct = c_ref[0, t, :].astype(jnp.float32)         # (N,)
        h = jnp.exp(dtt[:, None] * A) * h + (dtt * xt)[:, None] * Bt[None, :]
        yt = jnp.sum(h * Ct[None, :], axis=1) + Dv * xt
        y_ref[0, pl.dslice(t, 1), :] = yt[None].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == nc - 1)
    def _finish():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def selective_scan_fwd(x, dt, A, B, C, D, h0, *, chunk: int = 512,
                       block_d: int = 512, interpret: bool = False):
    """Shapes as in ref.selective_scan_ref. Returns (y, h_last)."""
    Bt, L, di = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    block_d = min(block_d, di)
    assert L % chunk == 0 and di % block_d == 0
    grid = (Bt, di // block_d, L // chunk)

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # x
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),            # A
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # C
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),                # D
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, L, di), x.dtype),
            jax.ShapeDtypeStruct((Bt, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D, h0)
    return y, h_last
