from repro.kernels.mamba_scan.ops import selective_scan  # noqa: F401
