"""Pallas TPU flash-attention forward kernel (blockwise online softmax).

Layout: inputs are pre-transposed to head-major — q (B,H,Sq,dq),
k/v (B,KV,Skv,d*) — so each grid step streams one (Bq x d) query tile
against (Bk x d) key/value tiles held in VMEM. Grid = (B, H, nq, nk) with
the kv dim innermost; the running max / denominator / accumulator live in
VMEM scratch across kv steps (TPU grid execution is sequential).

Block sizes are MXU-aligned (128 multiples); ``ops.py`` pads seq/head dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # causal: skip kv blocks strictly above the diagonal
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (Bq, dq)
        k = k_ref[0, 0].astype(jnp.float32)            # (Bk, dq)
        v = v_ref[0, 0].astype(jnp.float32)            # (Bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale: float, causal: bool = True,
                        kv_len: int | None = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B,H,Sq,dq), k: (B,KV,Skv,dq), v: (B,KV,Skv,dv) -> (B,H,Sq,dv).

    Caller guarantees Sq % block_q == 0, Skv % block_k == 0 and dq/dv are
    lane-aligned (ops.py pads).
    """
    B, H, Sq, dq = q.shape
    _, KV, Skv, dv = v.shape
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq, nk = Sq // block_q, Skv // block_k
    kv_len = Skv if kv_len is None else kv_len

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dq), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, dq), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max
            pltpu.VMEM((block_q,), jnp.float32),        # running denom
            pltpu.VMEM((block_q, dv), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
