"""Pure-jnp oracle for flash attention (GQA, causal or full).

Two implementations:
  * ``attention_ref``          — direct (materializes S_q x S_kv scores);
    the oracle for kernel tests and the small-seq path.
  * ``attention_ref_chunked``  — q-chunked streaming with causal KV
    truncation per chunk: peak score memory is q_chunk x S_kv and causal
    chunks only read KV up to their diagonal, so compiled FLOPs/memory
    match what the Pallas kernel does on TPU. The chunk loop is a *python*
    loop (unrolled in HLO) so ``cost_analysis`` counts every chunk
    (see DESIGN.md on scan trip-count accounting).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  kv_len=None):
    """q: (B,Sq,H,dq) k: (B,Skv,KV,dq) v: (B,Skv,KV,dv) -> (B,Sq,H,dv).

    Sq == Skv when causal (positions aligned); grouped so KV never expands.
    """
    B, Sq, H, dq = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dq)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(k.shape[1])
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if kv_len is not None:
        ok = ok & (k_pos[None, :] < kv_len)
    if causal:
        q_pos = jnp.arange(Sq)
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskv->bqkgv", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, -1)


def attention_ref_chunked(q, k, v, *, scale: float, causal: bool = True,
                          q_chunk: int = 512):
    """Streaming attention; same signature/semantics as ``attention_ref``."""
    B, Sq, H, dq = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    outs = []
    for q0 in range(0, Sq, q_chunk):
        qc = min(q_chunk, Sq - q0)
        # causal: this chunk only attends to keys [0, q0+qc)
        kv_end = min(q0 + qc, Skv) if causal else Skv
        qg = q[:, q0:q0 + qc].reshape(B, qc, KV, G, dq)
        ks, vs = k[:, :kv_end], v[:, :kv_end]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ks,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q0 + jnp.arange(qc)
            k_pos = jnp.arange(kv_end)
            s = jnp.where((k_pos[None, :] <= q_pos[:, None])[None, None, None],
                          s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskv->bqkgv", p.astype(v.dtype), vs)
        outs.append(o.reshape(B, qc, H, -1))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
