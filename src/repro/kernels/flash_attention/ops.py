"""Jitted wrapper: dispatches to the Pallas kernel on TPU, ref elsewhere.

Handles padding (seq to block multiples, head dims to 128 lanes) and the
(B,S,H,d) <-> (B,H,S,d) transposes the kernel wants. The backward pass uses
the jnp reference via ``jax.custom_vjp`` (flash recompute-style bwd kernel
is future work; on this CPU container the ref path is what lowers anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def flash_attention_kernel_call(q, k, v, *, scale, causal=True, kv_len=None,
                                block_q=128, block_k=128, interpret=False):
    """(B,S,H,d)-layout entry point around the Pallas kernel."""
    B, Sq, H, dq = q.shape
    Skv, dv = k.shape[1], v.shape[-1]
    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3), 2, block_q), 3, 128)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 2, block_k), 3, 128)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 2, block_k), 3, 128)
    eff_kv = Skv if kv_len is None else kv_len
    o = flash_attention_fwd(qt, kt, vt, scale=scale, causal=causal,
                            kv_len=eff_kv, block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return o[:, :, :Sq, :dv].transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _fa(q, k, v, scale, causal):
    return flash_attention_kernel_call(q, k, v, scale=scale, causal=causal)


def _fa_fwd(q, k, v, scale, causal):
    return _fa(q, k, v, scale, causal), (q, k, v)


def _fa_bwd(scale, causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.attention_ref(q, k, v, scale=scale, causal=causal),
        q, k, v)
    return vjp(g)


_fa.defvjp(_fa_fwd, _fa_bwd)


CHUNK_THRESHOLD = 1024


def flash_attention(q, k, v, *, scale: float, causal: bool = True):
    """Public op: (B,Sq,H,dq) x (B,Skv,KV,dq) x (B,Skv,KV,dv) -> (B,Sq,H,dv)."""
    if _on_tpu():
        return _fa(q, k, v, scale, causal)
    if q.shape[1] > CHUNK_THRESHOLD:
        return ref.attention_ref_chunked(q, k, v, scale=scale, causal=causal)
    return ref.attention_ref(q, k, v, scale=scale, causal=causal)
