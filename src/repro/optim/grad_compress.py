"""Cross-pod gradient synchronization with compression (beyond-paper).

Multi-pod data parallelism pays its gradient all-reduce over the slow
pod-to-pod links (DCI, ~25 GB/s vs 50 GB/s/link ICI in-pod). This module
makes that reduction explicit — ``jax.shard_map`` manual over the ``pod``
axis only, auto over (data, model) — so the wire format is controllable:

  * ``none``  — plain psum (bf16 wire at param dtype; the pjit baseline),
  * ``bf16``  — cast to bf16 before the psum (2x vs fp32 grads),
  * ``int8``  — per-tensor max-scale int8 quantization; int8 all-gather
    over the pod axis + local dequant-sum (4x vs fp32, 2x vs bf16 wire),
    with deterministic rounding so every pod computes identical updates.

The int8 path is exact up to quantization error; EXPERIMENTS.md §Perf
quantifies both the HLO wire-bytes reduction and the gradient error.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-20) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def sync_grads(grads, axis_name: str, method: str = "none"):
    """Average gradients across ``axis_name`` with the chosen wire format."""
    n = jax.lax.axis_size(axis_name)

    def none_(g):
        return jax.lax.psum(g, axis_name) / n

    def bf16_(g):
        # all-gather keeps bf16 as the wire dtype; direct bf16 psum trips an
        # XLA:CPU crash ("Invalid binary instruction opcode copy") under
        # partial-manual shard_map, and ring-AR wire bytes are equivalent.
        gs = jax.lax.all_gather(g.astype(jnp.bfloat16), axis_name)
        return (jnp.sum(gs.astype(jnp.float32), axis=0) / n).astype(g.dtype)

    def int8_(g):
        q, scale = quantize_int8(g)
        qs = jax.lax.all_gather(q, axis_name)          # int8 on the wire
        ss = jax.lax.all_gather(scale, axis_name)      # (n,) f32 scales
        deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * g.ndim)
        return (jnp.sum(deq, axis=0) / n).astype(g.dtype)

    fn = {"none": none_, "bf16": bf16_, "int8": int8_}[method]
    return jax.tree.map(fn, grads)


def multipod_train_step(model, mesh, method: str = "bf16"):
    """Wrap a Model's train step with explicit compressed cross-pod sync.

    Requires a mesh with a ``pod`` axis. Params/opt-state are replicated
    across pods (their data/model sharding stays with the auto axes);
    the batch is split across pods; each pod computes local gradients, the
    compressed sync averages them, and every pod applies the identical
    update.
    """
    from jax.sharding import PartitionSpec as P

    from repro.models import transformer as tfm
    from repro.optim.optimizers import clip_by_global_norm

    assert "pod" in mesh.shape, "multipod_train_step needs a 'pod' axis"
    cfg, run, rules = model.cfg, model.run, dict(model.rules)
    # inside the manual-pod region, activation constraints must not
    # reference the pod axis
    rules["act_batch"] = ("data",)
    opt_update, schedule = model.opt_update, model.schedule

    def per_pod(params, opt_state, batch):
        def loss_fn(p):
            return tfm.forward_train(cfg, run, p, batch, rules)

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads = sync_grads(grads, "pod", method)
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        lr = schedule(opt_state["step"] + 1)
        params, opt_state = opt_update(params, grads, opt_state, lr=lr)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return jax.shard_map(
        per_pod, mesh=mesh,
        in_specs=(P(), P(), P("pod")),
        out_specs=(P(), P(), P()),
        axis_names={"pod"}, check_vma=False)
