from repro.optim.optimizers import (adamw_init, adamw_update, adafactor_init,
                                    adafactor_update, make_optimizer)  # noqa: F401
from repro.optim.schedules import cosine_schedule  # noqa: F401
