"""Optimizers from scratch (no optax): AdamW and Adafactor.

Both operate on arbitrary pytrees; optimizer state mirrors the param tree so
the same logical-axis sharding rules apply leaf-wise (FSDP shards optimizer
state exactly like its parameter — ZeRO). ``opt_state_dtype=bfloat16`` halves
state HBM for the biggest archs (deepseek-v3).

Adafactor keeps factored second moments (row/col) for matrices — O(n+m)
instead of O(nm) state — the memory-sane choice for 671B-class models.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), grads), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, state_dtype: str = "float32"):
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
        update = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + eps)
        # decoupled weight decay on >=2-D weights only
        if p.ndim >= 2:
            update = update + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return newp, mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; state ~ params/edge-dims)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    # ndim-only so it agrees with opt_state_axes (which sees axes, not sizes)
    return len(shape) >= 2


def adafactor_init(params, state_dtype: str = "float32"):
    dt = jnp.dtype(state_dtype)

    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], dt),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
        return {"v": jnp.zeros(p.shape, dt)}

    return {"v": jax.tree.map(init, params), "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, *, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    beta = 1.0 - sf ** (-decay)

    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p.shape):
            vr = beta * v["vr"].astype(jnp.float32) + (1 - beta) * jnp.mean(g2, -1)
            vc = beta * v["vc"].astype(jnp.float32) + (1 - beta) * jnp.mean(g2, -2)
            denom = jnp.sqrt(vr[..., None] * vc[..., None, :]
                             / jnp.maximum(jnp.mean(vr, -1, keepdims=True), eps)[..., None])
            nv = {"vr": vr.astype(v["vr"].dtype), "vc": vc.astype(v["vc"].dtype)}
        else:
            vf = beta * v["v"].astype(jnp.float32) + (1 - beta) * g2
            denom = jnp.sqrt(vf)
            nv = {"v": vf.astype(v["v"].dtype)}
        u = g32 / jnp.maximum(denom, eps)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        if p.ndim >= 2 and weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_p, {"v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

def make_optimizer(name: str, *, state_dtype="float32", weight_decay=0.1):
    if name == "adamw":
        init = functools.partial(adamw_init, state_dtype=state_dtype)
        update = functools.partial(adamw_update, weight_decay=weight_decay)
    elif name == "adafactor":
        init = functools.partial(adafactor_init, state_dtype=state_dtype)
        update = functools.partial(adafactor_update, weight_decay=weight_decay)
    else:
        raise ValueError(name)
    return init, update


def opt_state_axes(opt_name: str, param_axes):
    """Logical axes for the optimizer state tree (mirrors params)."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    if opt_name == "adamw":
        return {"mu": param_axes, "nu": param_axes, "step": ()}
    # adafactor: factored leaves drop the last / second-to-last axis
    def fac(ax):
        if len(ax) >= 2:
            return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
        return {"v": ax}
    return {"v": jax.tree.map(fac, param_axes, is_leaf=is_axes), "step": ()}
