"""Text renderer for :meth:`EmeraldRuntime.introspect` snapshots.

The snapshot itself is built inside the runtime's driver thread (so it
is serially consistent with every state mutation); this module only
formats it. ``scripts/emtop.py`` is the CLI wrapper.
"""
from __future__ import annotations

from typing import Any, Dict, List


def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024.0 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024.0
    return f"{n:.1f}GiB"


def _bar(frac: float, width: int = 20) -> str:
    frac = max(0.0, min(1.0, frac))
    filled = int(round(frac * width))
    return "#" * filled + "." * (width - filled)


def render(snap: Dict[str, Any]) -> str:
    """Render an introspection snapshot as a multi-section text report."""
    lines: List[str] = []
    rt = snap.get("runtime", {})
    lines.append(f"emerald runtime  pid={rt.get('pid', '?')}  "
                 f"runs={len(snap.get('runs', []))}  "
                 f"telemetry={'on' if rt.get('telemetry') else 'off'}")

    lanes = snap.get("lanes", {})
    if lanes:
        lines.append("")
        lines.append("LANES")
        for name, lane in sorted(lanes.items()):
            busy, slots = lane.get("busy", 0), lane.get("slots", 0)
            frac = busy / slots if slots else 0.0
            lines.append(f"  {name:<10} [{_bar(frac)}] {busy}/{slots} busy")

    runs = snap.get("runs", [])
    if runs:
        lines.append("")
        lines.append("RUNS")
        lines.append(f"  {'run':<20} {'ns':<8} {'state':<10} "
                     f"{'done':>5} {'inflt':>5} {'ready':>5} {'pend':>5} "
                     f"{'retry':>5}  vtime")
        for r in runs:
            lines.append(
                f"  {r.get('run_id', '?'):<20} {r.get('ns', ''):<8} "
                f"{r.get('state', ''):<10} "
                f"{r.get('completed', 0):>5} {r.get('inflight', 0):>5} "
                f"{r.get('ready', 0):>5} {r.get('pending', 0):>5} "
                f"{r.get('retries', 0):>5}  "
                f"{r.get('fair_share_vtime', 0.0):.3f}")
        for r in runs:
            placements = r.get("placements") or {}
            if placements:
                placed = ", ".join(f"{s}->{t}" for s, t
                                   in sorted(placements.items()))
                lines.append(f"    {r.get('run_id', '?')}: {placed}")

    fd = snap.get("frontdoor", {})
    if fd and (fd.get("depth") or fd.get("parked_total")
               or fd.get("coalescers")):
        lines.append("")
        lines.append(f"FRONTDOOR  queued={fd.get('depth', 0)}/"
                     f"{fd.get('queue_limit', '?')} "
                     f"oldest_wait={fd.get('oldest_wait_s', 0.0):.3f}s "
                     f"parked_total={fd.get('parked_total', 0)} "
                     f"admitted={fd.get('admitted_total', 0)}")
        for p in fd.get("parked", []):
            slack = p.get("slack_s")
            slack_s = f"{slack:+.3f}s" if slack is not None else "-"
            lines.append(f"  {p.get('run_id', '?'):<20} "
                         f"{p.get('reason', ''):<10} "
                         f"waited={p.get('waited_s', 0.0):.3f}s "
                         f"slack={slack_s}")
        for c in fd.get("coalescers", []):
            lines.append(
                f"  coalescer {c.get('name', '?'):<12} "
                f"flushes={c.get('flushes', 0)} "
                f"avg_batch={c.get('avg_batch', 0.0):.1f} "
                f"ema={c.get('exec_ema_s', 0.0):.4f}s")
            for b in c.get("buckets", []):
                frac = (b.get("pending", 0) / c["max_batch"]) \
                    if c.get("max_batch") else 0.0
                lines.append(f"    {b.get('key', '?'):<32} "
                             f"[{_bar(frac, 12)}] {b.get('pending', 0)}"
                             f"/{c.get('max_batch', '?')} "
                             f"wait={b.get('oldest_wait_s', 0.0):.3f}s")

    mdss = snap.get("mdss", {})
    resid = mdss.get("residency", [])
    if resid:
        lines.append("")
        lines.append("RESIDENCY (namespace x tier)")
        for row in resid:
            budget = row.get("budget_bytes")
            used = row.get("resident_bytes", 0)
            if budget:
                pct = f"[{_bar(used / budget, 12)}] " \
                      f"{_fmt_bytes(used)}/{_fmt_bytes(budget)}"
            else:
                pct = f"{_fmt_bytes(used)} (no budget)"
            lines.append(f"  {row.get('namespace', '?'):<10} "
                         f"{row.get('tier', '?'):<8} {pct}")
    tiers = mdss.get("tiers", [])
    if tiers:
        lines.append("")
        lines.append("TIERS")
        for t in tiers:
            lines.append(
                f"  {t.get('name', '?'):<8} objs={t.get('objects', 0):<6} "
                f"resident={_fmt_bytes(t.get('resident_bytes'))} "
                f"cap={_fmt_bytes(t.get('capacity_bytes'))} "
                f"chunks={t.get('chunks', 0)} "
                f"chunk_bytes={_fmt_bytes(t.get('chunk_bytes'))}")

    memo = snap.get("memo", {})
    if memo:
        lines.append("")
        lines.append(f"MEMO  entries={memo.get('entries', 0)} "
                     f"bytes={_fmt_bytes(memo.get('bytes'))} "
                     f"hits={memo.get('hits', 0)} "
                     f"waits={memo.get('waits', 0)}")

    workers = snap.get("workers", {})
    if workers:
        lines.append("")
        lines.append(f"WORKERS  total={workers.get('num_workers', 0)} "
                     f"idle={workers.get('idle', 0)} "
                     f"warm={workers.get('warm', 0)} "
                     f"queue={workers.get('queue_depth', 0)} "
                     f"inflight={workers.get('inflight', 0)}")
        pids = workers.get("pids", [])
        if pids:
            lines.append(f"  pids: {', '.join(str(p) for p in pids)}")

    metrics = snap.get("metrics", {})
    if metrics:
        lines.append("")
        lines.append("METRICS")
        for name in sorted(metrics):
            v = metrics[name]
            if isinstance(v, dict):  # histogram
                avg = v.get("avg")
                lines.append(
                    f"  {name:<40} n={v.get('count', 0)} "
                    f"avg={avg:.4f}s" if avg is not None else
                    f"  {name:<40} n={v.get('count', 0)}")
            else:
                lines.append(f"  {name:<40} {v}")
    return "\n".join(lines)
