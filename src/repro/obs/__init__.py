"""Emerald observability: tracing, metrics, event schema, introspection.

``obs`` is stdlib-only and import-light so any layer (driver, broker
reader threads, scripts) can use it; worker child processes never import
it — they report raw phase timings in the reply frame and the broker
re-materialises those as spans driver-side.
"""
from repro.obs.events import EVENT_SCHEMA, validate_event
from repro.obs.introspect import render
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.tracing import Span, Tracer, chrome_trace, wall_now, wall_of

__all__ = [
    "EVENT_SCHEMA", "validate_event", "render",
    "REGISTRY", "MetricsRegistry",
    "Span", "Tracer", "chrome_trace", "wall_now", "wall_of",
]
