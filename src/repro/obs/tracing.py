"""Structured tracing for the Emerald runtime (stdlib-only on purpose).

A :class:`Span` is one timed phase of a run — submit, dispatch, place,
ship, exec, install, complete — identified by ``(trace_id, span_id)``
and parented to the span that was *current on the emitting thread* when
it opened (or to an explicit parent). The runtime assigns one trace per
run (``trace_id == run_id``), so a multi-tenant process interleaves N
traces through one :class:`Tracer` and exports any of them separately.

Two clocks, by design:

  * ``t0_wall`` is a wall-clock epoch timestamp (seconds since the Unix
    epoch) — the only timestamp comparable across *processes*: driver
    and worker both derive it from the system clock, so worker-side
    phases land on the same exported timeline as driver-side spans;
  * ``dur_s`` is a monotonic duration (``perf_counter`` delta) — wall
    clock can step, monotonic deltas cannot.

Cross-process propagation: the driver passes ``ctx()`` — a
``(trace_id, span_id)`` pair — in the task frame header (the broker's
message dict); the worker reports its phase timings back in the reply
and the broker re-materialises them as child spans via
:meth:`Tracer.add_span`. Workers therefore never import this module.

Export is Chrome trace-event JSON (``{"traceEvents": [...]}``) loadable
in Perfetto / ``chrome://tracing``: one *track* (pid, tid) per
lane/worker/tenant, ``X`` (complete) events carrying
``trace_id``/``span_id``/``parent_id`` in ``args`` so parentage survives
even when time-nesting is ambiguous, and ``M`` metadata events naming
every process and track.

Overhead: a disabled tracer's ``span()`` returns a shared no-op context
manager — one attribute load and one ``if`` on the hot path. An enabled
tracer appends finished spans to a bounded ring (oldest spans drop
first; ``dropped`` counts them), so a long-lived service never grows an
unbounded trace log.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# Shared wall/monotonic epoch pair: every conversion in this process uses
# the SAME anchor, so two spans' wall timestamps differ by exactly their
# monotonic offset — no per-call clock skew inside a process.
_EPOCH_WALL = time.time()
_EPOCH_PERF = time.perf_counter()

SpanCtx = Tuple[str, int]          # (trace_id, span_id)


def wall_of(perf_t: float) -> float:
    """Wall-clock epoch seconds for a ``perf_counter`` reading."""
    return _EPOCH_WALL + (perf_t - _EPOCH_PERF)


def wall_now() -> float:
    return wall_of(time.perf_counter())


@dataclass
class Span:
    trace_id: str
    span_id: int
    parent_id: int                 # 0 = root (no parent)
    name: str
    cat: str = ""
    track: str = "driver"          # one timeline row per track at export
    t0_wall: float = 0.0           # wall-clock epoch seconds
    dur_s: float = 0.0             # monotonic duration
    pid: int = 0                   # 0 -> this process
    attrs: Dict[str, Any] = field(default_factory=dict)


class _NoopSpan:
    """Context manager returned by a disabled tracer — near-zero cost."""
    __slots__ = ()
    ctx: Optional[SpanCtx] = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _ActiveSpan:
    """An open span: records on exit, exposes ``ctx`` for propagation."""
    __slots__ = ("tracer", "span", "_t0_perf", "_stack")

    def __init__(self, tracer: "Tracer", span: Span, stack: list):
        self.tracer = tracer
        self.span = span
        self._stack = stack
        self._t0_perf = 0.0

    @property
    def ctx(self) -> SpanCtx:
        return (self.span.trace_id, self.span.span_id)

    def set(self, **attrs):
        self.span.attrs.update(attrs)

    def __enter__(self):
        self._t0_perf = time.perf_counter()
        self.span.t0_wall = wall_of(self._t0_perf)
        self._stack.append(self.ctx)
        return self

    def __exit__(self, exc_type, exc, tb):
        self.span.dur_s = time.perf_counter() - self._t0_perf
        if exc_type is not None:
            self.span.attrs["error"] = repr(exc)
        stack = self._stack
        if stack and stack[-1] == self.ctx:
            stack.pop()
        self.tracer._record(self.span)
        return False


class _Attach:
    """Push a foreign ctx as the thread's current span (no recording) —
    how a helper thread (speculation twin, prefetch) inherits the
    dispatching span's identity."""
    __slots__ = ("_stack", "_ctx")

    def __init__(self, stack: list, ctx: Optional[SpanCtx]):
        self._stack = stack
        self._ctx = ctx

    def __enter__(self):
        if self._ctx is not None:
            self._stack.append(self._ctx)
        return self

    def __exit__(self, *exc):
        if self._ctx is not None and self._stack \
                and self._stack[-1] == self._ctx:
            self._stack.pop()
        return False


class Tracer:
    """Thread-safe collector of finished spans with TLS parenting."""

    def __init__(self, enabled: bool = True, cap: int = 65536):
        self.enabled = enabled
        self.cap = cap
        self._lock = threading.Lock()
        self._spans: "deque[Span]" = deque(maxlen=cap)
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self.dropped = 0
        self.pid = os.getpid()

    # ------------------------------------------------------------- recording
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current_ctx(self) -> Optional[SpanCtx]:
        """(trace_id, span_id) of this thread's innermost open span."""
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else None

    def next_id(self) -> int:
        return next(self._ids)

    def span(self, name: str, cat: str = "", track: str = "driver",
             trace_id: Optional[str] = None, parent: Optional[SpanCtx] = None,
             **attrs):
        """Open a span as a context manager. Parent defaults to the
        thread's current span; ``trace_id`` defaults to the parent's
        (``"-"`` for an unparented span — e.g. a bare ``manager.execute``
        outside any run)."""
        if not self.enabled:
            return _NOOP
        stack = self._stack()
        if parent is None and stack:
            parent = stack[-1]
        if trace_id is None:
            trace_id = parent[0] if parent is not None else "-"
        sp = Span(trace_id, self.next_id(),
                  parent[1] if parent is not None else 0,
                  name, cat=cat, track=track, pid=self.pid, attrs=attrs)
        return _ActiveSpan(self, sp, stack)

    def attach(self, ctx: Optional[SpanCtx]):
        """Context manager making ``ctx`` this thread's current span."""
        if not self.enabled:
            return _NOOP
        return _Attach(self._stack(), ctx)

    def add_span(self, trace_id: str, name: str, t0_wall: float, dur_s: float,
                 *, parent_id: int = 0, cat: str = "", track: str = "driver",
                 pid: int = 0, span_id: Optional[int] = None,
                 **attrs) -> Optional[int]:
        """Record an externally-measured span (e.g. worker-reported
        timings). ``span_id`` records under a pre-allocated identity
        (how the run root span keeps the id its children parented to).
        Returns the span id (None when disabled)."""
        if not self.enabled:
            return None
        sp = Span(trace_id, span_id if span_id is not None
                  else self.next_id(), parent_id, name, cat=cat,
                  track=track, t0_wall=t0_wall, dur_s=dur_s,
                  pid=pid or self.pid, attrs=attrs)
        self._record(sp)
        return sp.span_id

    def _record(self, sp: Span):
        if not self.enabled:
            return
        with self._lock:
            if len(self._spans) == self.cap:
                self.dropped += 1
            self._spans.append(sp)

    # --------------------------------------------------------------- reading
    def spans(self, trace_id: Optional[str] = None) -> List[Span]:
        with self._lock:
            snap = list(self._spans)
        if trace_id is None:
            return snap
        return [s for s in snap if s.trace_id == trace_id]

    def clear(self):
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    # ---------------------------------------------------------------- export
    def export(self, trace_id: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (a dict; ``json.dump``-able) with one
        track per distinct (pid, track) pair."""
        return chrome_trace(self.spans(trace_id))

    def export_json(self, path: str, trace_id: Optional[str] = None) -> str:
        doc = self.export(trace_id)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


def chrome_trace(spans: List[Span]) -> dict:
    """Render ``spans`` as a Chrome trace-event document.

    ``X`` (complete) events carry ``ts``/``dur`` in microseconds;
    ``args`` keeps the explicit span identity (``trace_id``/``span_id``/
    ``parent_id``) plus user attrs, so consumers can rebuild the exact
    parent tree rather than inferring it from time nesting. ``M``
    metadata events name each process and each track.
    """
    own_pid = os.getpid()
    events: List[dict] = []
    tids: Dict[Tuple[int, str], int] = {}
    pids_named: set = set()
    for sp in spans:
        pid = sp.pid or own_pid
        key = (pid, sp.track)
        tid = tids.get(key)
        if tid is None:
            tid = tids[key] = len(tids) + 1
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": sp.track}})
        if pid not in pids_named:
            pids_named.add(pid)
            role = "driver" if pid == own_pid else "worker"
            events.append({"ph": "M", "pid": pid, "tid": 0,
                           "name": "process_name",
                           "args": {"name": f"{role} (pid {pid})"}})
        args = {"trace_id": sp.trace_id, "span_id": sp.span_id,
                "parent_id": sp.parent_id}
        for k, v in sp.attrs.items():
            args[k] = v if isinstance(v, (int, float, str, bool,
                                          type(None))) else repr(v)
        events.append({"ph": "X", "pid": pid, "tid": tid, "name": sp.name,
                       "cat": sp.cat or "span",
                       "ts": sp.t0_wall * 1e6, "dur": sp.dur_s * 1e6,
                       "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms"}
