"""Event-schema registry: the contract for ``run.emit(kind, ...)``.

Every event kind the runtime emits is enumerated here with its required
``info`` keys, so event consumers (the trace exporter, emtop, user
post-processing) can rely on a stable schema instead of reverse-
engineering call sites. A lint test (``tests/test_obs.py``) greps the
source tree for ``emit(`` call sites and fails if any kind is missing
from this table — adding a new event kind without documenting it here is
a test failure, not a silent drift.

``required`` keys must be present in the event's ``info`` dict;
``optional`` keys may appear. :func:`validate_event` enforces this for
tests and for strict consumers.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, NamedTuple


class EventSchema(NamedTuple):
    kind: str
    required: FrozenSet[str]
    optional: FrozenSet[str]
    doc: str


def _s(kind: str, required=(), optional=(), doc: str = "") -> EventSchema:
    return EventSchema(kind, frozenset(required), frozenset(optional), doc)


#: kind -> schema, one row per ``emit(`` call-site kind in src/.
EVENT_SCHEMA: Dict[str, EventSchema] = {e.kind: e for e in [
    _s("dispatch",
       required=("lane",),
       doc="Driver granted the step a lane slot — the happens-before "
           "anchor the hazard sanitizer pairs with step_done."),
    _s("place",
       required=("reason",),
       optional=("scores", "stale_bytes"),
       doc="Locality policy chose a tier for a ready step."),
    _s("suspend", doc="Run suspended (admission/residency pressure)."),
    _s("resume", doc="Run resumed after suspension."),
    _s("step_done",
       required=("offloaded",),
       doc="Step result published and committed; DAG successors unblock."),
    _s("local",
       required=("seconds",),
       optional=("memo_hit",),
       doc="Step executed in-process on the local tier."),
    _s("offload",
       required=("seconds",),
       optional=("bytes_in", "bytes_out", "code_only", "attempt", "remote",
                 "worker_pid", "staged_s", "memo_hit"),
       doc="Step executed on the offload fabric (or fell back after "
           "retries; see attempt/remote)."),
    _s("retry",
       required=("attempt",),
       optional=("error",),
       doc="Offload attempt failed; the step is being retried."),
    _s("speculate",
       required=("timeout",),
       doc="Straggler guard launched a local twin of an offloaded step."),
    _s("prefetch",
       optional=("uris", "n"),
       doc="MDSS prefetch of predicted-next inputs kicked off."),
    _s("checkpoint",
       required=("n",),
       doc="Run checkpoint persisted (n = completed steps captured)."),
    _s("scatter",
       required=("shards", "parent"),
       optional=("uris",),
       doc="Fan-out scatter completed: the parent step's inputs were "
           "partitioned into per-shard content-addressed values uri#k."),
    _s("shard_done",
       required=("shard", "parent"),
       doc="One fan-out shard (shard = k, parent = the original step) "
           "finished and published its out#k value."),
    _s("gather",
       required=("shards", "parent"),
       doc="Fan-out gather completed: shard outputs were combined into "
           "the parent step's declared outputs."),
    _s("park",
       required=("reason",),
       optional=("deadline_s", "slo_ms", "depth"),
       doc="Submission could not be admitted immediately and was parked "
           "in the front door's bounded admission queue."),
    _s("admit",
       required=("waited_s",),
       optional=("slack_s", "depth"),
       doc="A parked run was admitted by the drain loop (oldest deadline "
           "first) once residency and lane capacity freed."),
    _s("coalesce",
       required=("key", "pending"),
       optional=("deadline_s",),
       doc="A decode request joined a BatchCoalescer bucket and is "
           "waiting for the flush window."),
    _s("flush",
       required=("key", "batch"),
       optional=("waited_s", "reason", "seconds"),
       doc="A coalescer bucket flushed: k requests were stacked along "
           "the batch axis and dispatched as ONE fused task."),
    _s("preempt",
       required=("victim",),
       optional=("slack_s", "step"),
       doc="An interactive run's SLO was threatened; the longest-running "
           "preemptible batch task was checkpoint-aborted and requeued "
           "attempt-free."),
]}


def validate_event(kind: str, info: dict) -> None:
    """Raise ``ValueError`` if ``kind`` is unregistered or ``info`` is
    missing a required key / carries an undeclared key."""
    schema = EVENT_SCHEMA.get(kind)
    if schema is None:
        raise ValueError(f"unregistered event kind: {kind!r}")
    missing = schema.required - set(info)
    if missing:
        raise ValueError(f"event {kind!r} missing required info keys: "
                         f"{sorted(missing)}")
    unknown = set(info) - schema.required - schema.optional
    if unknown:
        raise ValueError(f"event {kind!r} carries undeclared info keys: "
                         f"{sorted(unknown)}")
