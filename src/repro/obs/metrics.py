"""Unified metrics registry: counters, gauges, histograms.

One process-wide (or per-runtime) :class:`MetricsRegistry` absorbs the
stats that were previously scattered as private attributes across the
broker, worker pool, MDSS, wire channels, memo table, fair-share
scheduler and autoscaler. Components register themselves via their
``register_metrics(registry)`` methods; consumers read everything with
one :meth:`MetricsRegistry.snapshot` call.

Design points:

  * **Lock-striped counters** — ``inc()`` takes one of 16 stripe locks
    chosen by the metric's name hash, so hot-path increments from lane
    threads, broker reader threads and the driver loop rarely contend on
    the same lock. A counter caches its stripe lock at construction;
    after the first ``counter()`` lookup the increment is just
    ``with lock: value += n``.
  * **Pull gauges** — a gauge is a callback sampled at ``snapshot()``
    time (e.g. ``broker.queue_depth``). Sampling never throws: a failing
    callback yields ``None`` for that gauge. Re-registering a gauge name
    replaces the callback (last wins), which makes repeated
    ``attach_fabric``-style wiring idempotent.
  * **Consistent snapshot** — ``snapshot()`` takes all stripe locks in a
    fixed order while copying counter/histogram values, so a reader
    never observes a torn multi-field histogram; gauges are sampled
    after release (they read component state under those components'
    own locks).
  * **Opt-out** — a registry built with ``enabled=False`` turns ``inc``
    / ``observe`` into no-ops (one ``if`` each) and ``snapshot()``
    returns an empty dict, for minimum-overhead runs.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

_N_STRIPES = 16

# Default histogram buckets (seconds-ish scale; upper bounds, +inf last).
_DEFAULT_BUCKETS = (0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0)


class Counter:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1):
        with self._lock:
            self.value += n

    def set(self, v: int):
        """Absolute set — for mirroring an externally-maintained total."""
        with self._lock:
            self.value = v


class Histogram:
    __slots__ = ("name", "count", "sum", "min", "max", "buckets",
                 "bucket_counts", "_lock")

    def __init__(self, name: str, lock: threading.Lock,
                 buckets=_DEFAULT_BUCKETS):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self.buckets = tuple(buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)
        self._lock = lock

    def observe(self, v: float):
        with self._lock:
            self.count += 1
            self.sum += v
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v
            for i, ub in enumerate(self.buckets):
                if v <= ub:
                    self.bucket_counts[i] += 1
                    break
            else:
                self.bucket_counts[-1] += 1


class MetricsRegistry:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._stripes = [threading.Lock() for _ in range(_N_STRIPES)]
        self._meta = threading.Lock()       # guards the name->metric maps
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Callable[[], Any]] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _stripe(self, name: str) -> threading.Lock:
        return self._stripes[hash(name) % _N_STRIPES]

    # ---------------------------------------------------------- registration
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._meta:
                c = self._counters.get(name)
                if c is None:
                    c = self._counters[name] = Counter(name,
                                                       self._stripe(name))
        return c

    def gauge(self, name: str, fn: Callable[[], Any]):
        """Register (or replace) a pull gauge. Last registration wins."""
        with self._meta:
            self._gauges[name] = fn

    def histogram(self, name: str, buckets=_DEFAULT_BUCKETS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._meta:
                h = self._histograms.get(name)
                if h is None:
                    h = self._histograms[name] = Histogram(
                        name, self._stripe(name), buckets)
        return h

    # ------------------------------------------------------------- hot paths
    def inc(self, name: str, n: int = 1):
        if not self.enabled:
            return
        self.counter(name).inc(n)

    def set(self, name: str, v: int):
        if not self.enabled:
            return
        self.counter(name).set(v)

    def observe(self, name: str, v: float):
        if not self.enabled:
            return
        self.histogram(name).observe(v)

    # --------------------------------------------------------------- reading
    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time view of every metric: ``{name: value}`` for
        counters and gauges, ``{name: {count,sum,min,max,avg,buckets}}``
        for histograms. Counter/histogram reads are torn-free (all
        stripe locks held while copying); gauges sample afterwards."""
        if not self.enabled:
            return {}
        with self._meta:
            counters = list(self._counters.values())
            gauges = list(self._gauges.items())
            histograms = list(self._histograms.values())
        out: Dict[str, Any] = {}
        for lk in self._stripes:
            lk.acquire()
        try:
            for c in counters:
                out[c.name] = c.value
            for h in histograms:
                out[h.name] = {
                    "count": h.count, "sum": h.sum,
                    "min": h.min, "max": h.max,
                    "avg": (h.sum / h.count) if h.count else None,
                    "buckets": dict(zip(
                        [str(b) for b in h.buckets] + ["+inf"],
                        list(h.bucket_counts))),
                }
        finally:
            for lk in self._stripes:
                lk.release()
        for name, fn in gauges:
            try:
                out[name] = fn()
            except Exception:
                out[name] = None
        return out

    def names(self) -> List[str]:
        with self._meta:
            return sorted(set(self._counters) | set(self._gauges)
                          | set(self._histograms))


# Process-wide default registry; runtimes default to their own private
# registry (cross-test isolation) but share this one when asked.
REGISTRY = MetricsRegistry()


#: name -> one-line doc, one row per metric name used in src/. The
#: ``emlint --self`` L002 rule (``repro.analysis.selfcheck``) greps the
#: source tree for ``inc("/observe("/gauge("/set("`` call sites and fails
#: on any dotted metric name missing from this table — same contract as
#: ``EVENT_SCHEMA`` for event kinds.
METRIC_CATALOG: Dict[str, str] = {
    "autoscaler.desired_workers": "Autoscaler's current target pool size.",
    "autoscaler.scale_ups": "Scale-up decisions taken.",
    "autoscaler.scale_downs": "Scale-down decisions taken.",
    "autoscaler.ticks": "Autoscaler control-loop iterations.",
    "broker.queue_depth": "Tasks waiting for a worker.",
    "broker.inflight": "Tasks currently executing on workers.",
    "broker.num_workers": "Live workers attached to the broker.",
    "broker.num_workers_with_warm": "Workers holding a warm module set.",
    "broker.idle_workers": "Workers with no task in flight.",
    "broker.tasks_done": "Tasks completed successfully.",
    "broker.tasks_requeued": "Tasks requeued after worker loss/failure.",
    "broker.tasks_cancelled": "Tasks cancelled before completion.",
    "broker.workers_lost": "Workers declared dead by heartbeat.",
    "broker.tasks_preempted": "In-flight tasks checkpoint-aborted for SLO.",
    "broker.warm_hits": "Tasks routed to a warm worker.",
    "compile_cache.entries": "Compiled-executable cache entries.",
    "compile_cache.hits": "Compiled-executable cache hits.",
    "emcheck.schedules_explored": "Complete interleavings model-checked.",
    "emcheck.states_deduped": "Explorer prefixes cut by visited-state dedup.",
    "emcheck.por_pruned": "Branches collapsed by partial-order reduction.",
    "emcheck.hazards_found": "Findings raised across explored schedules.",
    "emcheck.replays": "Reproducer schedules replayed.",
    "fanout.scatters": "Fan-out scatter steps completed.",
    "fanout.shards_dispatched": "Fan-out shard steps granted a lane.",
    "fanout.shards_completed": "Fan-out shard steps completed.",
    "fanout.gathers": "Fan-out gather steps completed.",
    "frontdoor.parked_depth": "Submissions currently parked for admission.",
    "frontdoor.parked_total": "Submissions ever parked by the front door.",
    "frontdoor.admitted_total": "Parked submissions drained into the runtime.",
    "frontdoor.queue_full": "Submissions refused because the queue was full.",
    "frontdoor.park_wait_s": "Seconds parked submissions waited for admission.",
    "frontdoor.preemptions": "SLO-driven preemptions of in-flight batch work.",
    "frontdoor.coalesced": "Decode requests absorbed into a fused batch.",
    "frontdoor.flushes": "Coalescer buckets flushed as one fused task.",
    "frontdoor.fused_batch": "Request count of fused batches (histogram).",
    "mdss.resident_bytes": "Bytes resident across tiers.",
    "mdss.bytes_moved": "Bytes transferred between tiers.",
    "mdss.modeled_seconds": "Cost-model seconds charged to transfers.",
    "mdss.prefetch_ops": "Prefetch operations issued.",
    "mdss.prefetch_bytes": "Bytes moved by prefetch.",
    "mdss.fenced_puts": "Fenced put_many publishes.",
    "mdss.evictions": "Replicas evicted by residency budgets.",
    "mdss.eviction_bytes": "Bytes reclaimed by eviction.",
    "mdss.dedup_bytes_elided": "Bytes elided by content-chunk dedup.",
    "mdss.entries": "Distinct URIs tracked by the store.",
    "mdss.chunk_index_bytes": "Bytes held by the chunk dedup index.",
    "memo.entries": "Cross-run memo table entries.",
    "memo.bytes": "Bytes held by the memo table.",
    "memo.hits": "Step executions answered from the memo table.",
    "memo.waits": "Executions that waited on an in-flight memo twin.",
    "pool.spawned_total": "Worker processes spawned over the pool's life.",
    "pool.pending_hellos": "Spawned workers not yet handshaken.",
    "runtime.active_runs": "Admitted, unfinished runs.",
    "runtime.offload_backlog": "Ready offload-lane steps awaiting a slot.",
    "runtime.lane_busy.offload": "Busy offload-lane slots.",
    "runtime.lane_busy.local": "Busy local-lane slots.",
    "runtime.runs_completed": "Runs finished (done/failed/cancelled).",
    "runtime.steps_dispatched": "Steps handed to a lane executor.",
    "runtime.steps_completed": "Steps whose results were committed.",
    "runtime.step_retries": "Step re-executions after failure.",
    "runtime.submissions_rejected": "Workflows rejected by the verifier.",
    "scheduler.fair_share": "Fair-share scheduler pass statistics.",
    "wire.bytes_sent": "Bytes written to worker sockets.",
    "wire.bytes_received": "Bytes read from worker sockets.",
    "wire.dedup_saved_bytes": "Wire bytes elided by chunk dedup.",
    "wire.dedup_chunks": "Chunks answered from the receiver's cache.",
    "wire.dedup_hit_rate": "Fraction of chunks deduped on the wire.",
}
