"""Post-SPMD HLO analysis: collective-byte accounting + cost extraction.

``compiled.cost_analysis()`` gives per-device FLOPs / bytes but (a) counts
while-loop (``lax.scan``) bodies ONCE regardless of trip count and (b) has
no collective information. This module provides:

  * ``collective_bytes(hlo_text)`` — per-device bytes moved over links,
    summed per collective kind with standard ring-algorithm accounting,
  * the scan-slope machinery lives in ``dryrun.py``: a model is compiled
    once normally and once per stage with that stage's scan unrolled by a
    known factor; costs are affine in the unroll factor, so the per-layer
    slope recovers exact totals (validated: slope(1->2) == slope(2->4) to
    <0.1%, and matches analytic FLOPs).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<restype>\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device link bytes by collective kind (ring accounting):

      all-gather          (g-1)/g * result
      all-reduce          2 (g-1)/g * result
      reduce-scatter      (g-1) * result           (operand = g * result)
      all-to-all          (g-1)/g * result
      collective-permute  result
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("restype"))
        g = _group_size(line)
        if g <= 1 and op != "collective-permute":
            continue
        if op == "all-gather":
            moved = nbytes * (g - 1) / g
        elif op == "all-reduce":
            moved = 2.0 * nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            moved = float(nbytes) * (g - 1)
        elif op == "all-to-all":
            moved = nbytes * (g - 1) / g
        else:  # collective-permute
            moved = float(nbytes)
        out[op] = out.get(op, 0.0) + moved
    out["total"] = sum(out.values())
    return out


_GROUPS_FULL_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_GROUPS_FULL_LIST_RE = re.compile(r"replica_groups=\{(\{[\d,]+\}(?:,\{[\d,]+\})*)\}")


def _expand_groups(line: str):
    """Materialize replica groups as an (n_groups, size) int array."""
    m = _GROUPS_FULL_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(p) for p in m.group(4).split(",")])
        return arr.reshape(g, s)
    m = _GROUPS_FULL_LIST_RE.search(line)
    if m:
        rows = [[int(v) for v in grp.strip("{}").split(",")]
                for grp in m.group(1).split("},{")]
        return np.asarray(rows)
    return None


def collective_bytes_by_span(hlo_text: str, pod_size: int) -> Dict[str, float]:
    """Split per-device collective bytes into intra-pod vs cross-pod.

    Devices [0, pod_size) are pod 0 etc. (the pod axis is the leading mesh
    dim). A collective whose replica groups mix pods pays cross-pod links.
    """
    out = {"intra": 0.0, "cross": 0.0}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("restype"))
        g = _group_size(line)
        if g <= 1 and op != "collective-permute":
            continue
        if op == "all-gather":
            moved = nbytes * (g - 1) / g
        elif op == "all-reduce":
            moved = 2.0 * nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            moved = float(nbytes) * (g - 1)
        elif op == "all-to-all":
            moved = nbytes * (g - 1) / g
        else:
            moved = float(nbytes)
        groups = _expand_groups(line)
        cross = False
        if op == "collective-permute":
            pairs = re.search(r"source_target_pairs=\{([^}]*)\}", line)
            if pairs:
                for pair in pairs.group(1).split("},{"):
                    a, b = [int(v) for v in pair.strip("{}").split(",")]
                    if a // pod_size != b // pod_size:
                        cross = True
        elif groups is not None:
            pods = groups // pod_size
            cross = bool(np.any(pods != pods[:, :1]))
        out["cross" if cross else "intra"] += moved
    return out


def cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def memory_dict(compiled) -> Dict[str, int]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
