"""Roofline terms + analytic MODEL_FLOPS per (arch x shape) cell.

Terms (per the assignment; TPU v5e constants):

    compute    = HLO_FLOPs  / (chips * 197 TFLOP/s)
    memory     = HLO_bytes  / (chips * 819 GB/s)
    collective = coll_bytes / (chips * 50 GB/s)

``cost_analysis`` numbers are PER-DEVICE post-SPMD, so the global quantity
is per_device * chips and the terms reduce to per_device / per_chip_peak —
that's what we compute. MODEL_FLOPS is the *useful* global compute,
6*N_active*D (train) or 2*N_active*D (inference) + exact attention terms,
derived from the UNPADDED config — the MODEL/HLO ratio therefore exposes
padding waste, remat recompute and dispatch overhead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs.base import ATTN_DENSE, ATTN_MOE, ModelConfig, ShapeProfile

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)


# ---------------------------------------------------------------------------
# Analytic parameter / FLOP accounting (unpadded).
# ---------------------------------------------------------------------------

def matmul_param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active-per-token) matmul params, unpadded, incl. lm_head."""
    from repro.models.params import ParamSpec, tree_map_specs
    from repro.models.transformer import model_template

    true_cfg = dataclasses.replace(cfg, pad_multiple=1)
    template = model_template(true_cfg)
    total = active = 0.0

    def walk(node, in_moe_routed):
        nonlocal total, active
        if isinstance(node, ParamSpec):
            if len(node.shape) < 2:
                return
            n = 1.0
            for d in node.shape:
                n *= d
            if "vocab" in (node.axes or ()) and node.axes[0] == "vocab":
                return  # embedding gather, not a matmul
            total += n
            if in_moe_routed and "experts" in (node.axes or ()):
                active += n * cfg.experts_per_token / max(cfg.n_experts, 1)
            else:
                active += n
            return
        for k, v in node.items():
            walk(v, in_moe_routed or k == "moe")

    walk(template, False)
    return total, active


def attention_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> float:
    """Exact score/value matmul FLOPs (global), causal-halved."""
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.block_type(i) in (ATTN_DENSE, ATTN_MOE))
    if cfg.is_encoder_decoder:
        n_attn = cfg.n_layers  # decoder self-attn
    if cfg.attn_type == "mla":
        dqk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        dv = cfg.v_head_dim
    else:
        dqk = dv = cfg.hdim
    H = cfg.n_heads
    if kind == "train":
        per_layer = 3 * 2 * B * (S * S / 2) * H * (dqk + dv)
    elif kind == "prefill":
        per_layer = 2 * B * (S * S / 2) * H * (dqk + dv)
    else:  # decode: one query against S cached keys
        per_layer = 2 * B * S * H * (dqk + dv)
    fl = n_attn * per_layer
    if cfg.is_encoder_decoder:
        # encoder self-attn (full, not causal) + decoder cross-attn
        enc = cfg.n_encoder_layers * 2 * B * S * S * H * 2 * cfg.hdim
        if kind == "train":
            fl += 3 * enc + 3 * cfg.n_layers * 2 * B * S * S * H * 2 * cfg.hdim / 2
        elif kind == "prefill":
            fl += enc + cfg.n_layers * 2 * B * S * S * H * 2 * cfg.hdim / 2
        else:
            fl += cfg.n_layers * 2 * B * S * H * 2 * cfg.hdim  # cross decode
    return fl


def model_flops(cfg: ModelConfig, shape: ShapeProfile) -> float:
    """Useful global FLOPs for one step of this cell (6ND / 2ND convention)."""
    B, S = shape.global_batch, shape.seq_len
    _, n_active = matmul_param_counts(cfg)
    if shape.kind == "train":
        tokens = B * S
        return 6.0 * n_active * tokens + attention_flops(cfg, B, S, "train")
    if shape.kind == "prefill":
        tokens = B * S
        return 2.0 * n_active * tokens + attention_flops(cfg, B, S, "prefill")
    return 2.0 * n_active * B + attention_flops(cfg, B, S, "decode")


# ---------------------------------------------------------------------------
# Term computation from dry-run measurements.
# ---------------------------------------------------------------------------

def roofline_terms(per_dev_flops: float, per_dev_bytes: float,
                   per_dev_coll_bytes: float) -> Dict[str, float]:
    compute = per_dev_flops / PEAK_FLOPS
    memory = per_dev_bytes / HBM_BW
    collective = per_dev_coll_bytes / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms
