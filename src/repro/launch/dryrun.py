import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax-importing import): jax
locks the device count at first init, and only the dry-run may see 512
placeholder devices.

For each cell this produces:
  * proof the sharding config compiles (the deliverable's pass/fail),
  * ``memory_analysis`` (bytes/device — fits-or-not),
  * per-device HLO FLOPs / bytes / collective bytes with scan trip-count
    correction: one baseline compile + one compile per scanned stage with
    that stage unrolled by a known factor; costs are affine in the factor
    so the slope recovers exact per-layer costs (see hlo_analysis.py),
  * roofline terms + MODEL_FLOPS ratio (launch/roofline.py).

Results land in benchmarks/dryrun_results/*.json; EXPERIMENTS.md §Dry-run
and §Roofline are generated from them.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import all_cells, get_config, make_run
from repro.configs.base import SHAPES, shape_applicable
from repro.launch import hlo_analysis as ha
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import Model

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/dryrun_results")


def input_specs(arch: str, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    run = make_run(arch, shape)
    model = Model(run)
    if run.shape.kind == "train":
        return {"params": model.abstract_params(),
                "opt_state": model.abstract_opt_state(),
                "batch": model.abstract_batch()}
    if run.shape.kind == "prefill":
        b = model.abstract_batch()
        b.pop("labels", None)
        return {"params": model.abstract_params(), "batch": b,
                "cache": model.abstract_cache()}
    return {"params": model.abstract_params(),
            "tokens": jax.ShapeDtypeStruct((run.shape.global_batch,), jax.numpy.int32),
            "cache": model.abstract_cache()}


def _unroll_divisor(reps: int, above: int = 1) -> int:
    """Smallest divisor of reps strictly greater than ``above``."""
    if reps <= above:
        return reps
    for u in range(above + 1, reps + 1):
        if reps % u == 0:
            return u
    return reps


def _compile_cell(run, mesh):
    model = Model(run)
    fn, args, in_sh, out_sh = model.dryrun_case(mesh)
    lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
    return model, lowered.compile()


def stage_plan(run) -> Dict[str, int]:
    """stage key -> scan reps (for trip-count correction)."""
    plan = {f"stage_{i}": reps
            for i, (_, reps) in enumerate(run.model.stages())}
    if run.model.is_encoder_decoder:
        plan["enc_stage"] = run.model.n_encoder_layers
    return plan


def run_cell(arch: str, shape: str, mesh_kind: str, *, slopes: bool = True,
             run_overrides: Optional[dict] = None) -> dict:
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "ok": False}
    t_start = time.time()
    try:
        run = make_run(arch, shape, **(run_overrides or {}))
    except ValueError as e:   # inapplicable cell (long_500k on full attention)
        rec.update(skipped=True, reason=str(e))
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        with mesh:
            model, compiled = _compile_cell(run, mesh)
            base_cost = ha.cost_dict(compiled)
            base_coll = ha.collective_bytes(compiled.as_text())
            rec["memory"] = ha.memory_dict(compiled)
            rec["base_cost"] = base_cost
            rec["base_collectives"] = base_coll

            flops = base_cost["flops"]
            byts = base_cost["bytes"]
            coll = base_coll["total"]
            rec["stages"] = {}
            if slopes:
                u1 = run.scan_unroll      # F(u) is affine in the unroll u
                for key, reps in stage_plan(run).items():
                    if reps <= u1:
                        continue          # stage already fully unrolled
                    u = _unroll_divisor(reps, above=u1)
                    run_u = run.with_(unroll_stage=key, unroll_factor=u)
                    _, comp_u = _compile_cell(run_u, mesh)
                    cost_u = ha.cost_dict(comp_u)
                    coll_u = ha.collective_bytes(comp_u.as_text())["total"]
                    sl_f = (cost_u["flops"] - base_cost["flops"]) / (u - u1)
                    sl_b = (cost_u["bytes"] - base_cost["bytes"]) / (u - u1)
                    sl_c = (coll_u - base_coll["total"]) / (u - u1)
                    # SPMD may choose a cheaper collective strategy at the
                    # larger unroll (cross-layer CSE) — affinity holds for
                    # flops/bytes but can break for collectives; clamp.
                    clamped = sl_c < 0
                    sl_c = max(sl_c, 0.0)
                    flops += sl_f * (reps - u1)
                    byts += sl_b * (reps - u1)
                    coll += sl_c * (reps - u1)
                    rec["stages"][key] = {"reps": reps, "unroll": u,
                                          "base_unroll": u1,
                                          "slope_flops": sl_f,
                                          "slope_bytes": sl_b,
                                          "slope_coll": sl_c,
                                          "coll_slope_clamped": clamped}
            rec["per_device"] = {"flops": flops, "bytes": byts,
                                 "collective_bytes": coll}
            chips = 1
            for n in mesh.shape.values():
                chips *= n
            rec["chips"] = chips
            rec["roofline"] = rf.roofline_terms(flops, byts, coll)
            mf = rf.model_flops(run.model, run.shape)
            rec["model_flops"] = mf
            rec["hlo_flops_global"] = flops * chips
            rec["model_vs_hlo"] = mf / (flops * chips) if flops else 0.0
            rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=10)
    rec["wall_s"] = round(time.time() - t_start, 1)
    return rec


def save(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json".replace("/", "-")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-slopes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch, shape, ok, why in all_cells(include_inapplicable=True):
            cells.append((arch, shape))
    else:
        shapes = [args.shape] if args.shape else list(SHAPES)
        archs = [args.arch] if args.arch else []
        for a in archs:
            for s in shapes:
                cells.append((a, s))
    n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            fname = os.path.join(
                args.out, f"{arch}_{shape}_{mk}.json".replace("/", "-"))
            if args.skip_existing and os.path.exists(fname):
                try:
                    old = json.load(open(fname))
                    if old.get("ok") or old.get("skipped"):
                        print(f"[{arch:>20s} x {shape:<11s} x {mk:<6s}] cached",
                              flush=True)
                        continue
                except Exception:
                    pass
            # multi-pod pass proves sharding; slopes only needed single-pod
            slopes = (mk == "single") and not args.no_slopes
            rec = run_cell(arch, shape, mk, slopes=slopes)
            save(rec, args.out)
            if rec.get("skipped"):
                status = "SKIP (" + rec["reason"][:60] + ")"
            elif rec["ok"]:
                r = rec["roofline"]
                status = (f"ok {rec['wall_s']:6.1f}s  dominant={r['dominant']}"
                          f" bound={r['bound_s']*1e3:.1f}ms"
                          f" model/hlo={rec['model_vs_hlo']:.2f}")
            else:
                status = "FAIL " + rec["error"][:110]
                n_fail += 1
            print(f"[{arch:>20s} x {shape:<11s} x {mk:<6s}] {status}",
                  flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
