"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; only the dry-run sets
``xla_force_host_platform_device_count`` (its first two lines, before any
import) to obtain the 512 placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """1-device mesh for smoke tests / local-tier execution."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
