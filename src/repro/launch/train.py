"""End-to-end trainer: LM training orchestrated as an Emerald workflow.

The training loop IS a scientific workflow (paper §2): the data step runs
locally, the computation-intensive ``train_step`` is a *remotable* step the
Emerald runtime offloads to the cloud tier. MDSS keeps params/optimizer
state resident on the cloud between iterations, so after the first offload
every iteration is **code-only** — only the batch crosses the link, the
paper's §3.4 saving measured for real by ``mdss.bytes_moved``.

Checkpoints are written locally (Property 1: disk is local hardware), which
pulls params back through MDSS only at checkpoint cadence.

CLI (CPU-sized by default — deliverable (b)'s ~100M model):
  python -m repro.launch.train --arch tinyllama-1.1b --reduced --steps 200
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, make_run
from repro.configs.base import ModelConfig, RunConfig, ShapeProfile, reduced
from repro.core import (CostModel, EmeraldExecutor, EmeraldRuntime, MDSS,
                        MigrationManager, Workflow, default_tiers, partition)
from repro.data.pipeline import SyntheticLMData
from repro.models.model_zoo import Model


@dataclass
class Trainer:
    run: RunConfig
    policy: str = "annotate"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    seed: int = 0
    async_ckpt: bool = True

    def __post_init__(self):
        self.model = Model(self.run)
        self.data = SyntheticLMData(self.run.model, self.run.shape, self.seed)
        self.tiers = default_tiers()
        self.cost_model = CostModel(self.tiers)
        self.mdss = MDSS(self.tiers, cost_model=self.cost_model)
        self.manager = MigrationManager(self.tiers, self.mdss, self.cost_model)
        self.ckpt = (Checkpointer(self.ckpt_dir, mdss=self.mdss,
                                  async_save=self.async_ckpt)
                     if self.ckpt_dir else None)
        self.history: list = []
        self._live = False       # params/opt resident in MDSS across fit()s
        self._step = 0
        self._build_workflow()

    def _build_workflow(self):
        wf = Workflow("lm-train")
        wf.var("params").var("opt_state").var("batch")
        n_params = sum(int(np.prod(s.shape)) for s in
                       jax.tree.leaves(self.model.abstract_params()))
        tokens = self.run.shape.global_batch * self.run.shape.seq_len
        wf.step("train_step", self._step_fn(),
                inputs=("params", "opt_state", "batch"),
                outputs=("params", "opt_state", "metrics"),
                remotable=True, flops_hint=6.0 * n_params * tokens,
                bytes_hint=2.0 * n_params)
        self.workflow = wf
        # one long-lived runtime across the whole fit loop: lanes, driver
        # and compile caches are set up once, not once per training step
        self.runtime = EmeraldRuntime(self.manager, policy=self.policy,
                                      name="train")
        self.executor = EmeraldExecutor(
            partition(wf), self.manager, policy=self.policy,
            runtime=self.runtime)

    def close(self):
        self.runtime.close()

    def _step_fn(self):
        step = self.model.train_step

        def fn(params, opt_state, batch):
            p, o, m = step(params, opt_state, batch)
            return {"params": p, "opt_state": o, "metrics": m}

        return fn

    # ------------------------------------------------------------------ api
    def fit(self, steps: int, *, resume: bool = False, log_every: int = 20):
        start = self._step
        init = {}
        if not self._live:
            params = opt_state = None
            if resume and self.ckpt and self.ckpt.latest_step("train") is not None:
                tmpl = {"params": self.model.abstract_params(),
                        "opt_state": self.model.abstract_opt_state()}
                state, meta = self.ckpt.restore("train", tmpl)
                params, opt_state = state["params"], state["opt_state"]
                start = meta["step"]
            if params is None:
                params = self.model.init_params(jax.random.PRNGKey(self.seed))
                opt_state = self.model.opt_init(params)
            init = {"params": params, "opt_state": opt_state}
            self._live = True
        t0 = time.time()
        for i in range(start, start + steps):
            init["batch"] = self.data.batch(i)
            out = self.executor.run(init, fetch=("metrics",))
            init = {}          # params/opt stay resident on the cloud tier
            m = {k: float(v) for k, v in out["metrics"].items()}
            m["step"] = i
            self.history.append(m)
            if log_every and (i % log_every == 0 or i == start + steps - 1):
                print(f"step {i:5d} loss {m['loss']:.4f} "
                      f"grad_norm {m['grad_norm']:.3f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
            if self.ckpt and (i + 1) % self.ckpt_every == 0:
                self.save_checkpoint(i + 1)
        self._step = start + steps
        if self.ckpt:
            self.ckpt.wait()
        return self.history

    def save_checkpoint(self, step: int):
        tree = {"params": self.mdss.get("params", "local"),
                "opt_state": self.mdss.get("opt_state", "local")}
        self.ckpt.save("train", step, tree,
                       topology={"mesh": "host", "arch": self.run.model.name})

    # ------------------------------------------------------------- reporting
    def transfer_report(self) -> Dict:
        offloads = [e for e in self.executor.events if e.kind == "offload"]
        return {
            "offloads": len(offloads),
            "code_only": sum(1 for e in offloads if e.info.get("code_only")),
            "bytes_moved": dict(self.mdss.bytes_moved),
            "modeled_transfer_s": self.mdss.modeled_seconds,
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--policy", default="annotate",
                    choices=["annotate", "cost_model", "never"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeProfile("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape, remat="none",
                    learning_rate=args.lr)
    tr = Trainer(run, policy=args.policy, ckpt_dir=args.ckpt_dir)
    tr.fit(args.steps, resume=args.resume)
    print("transfer report:", tr.transfer_report())
    tr.close()


if __name__ == "__main__":
    main()
