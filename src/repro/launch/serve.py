"""Batched serving: prefill + decode loop as Emerald remotable steps.

A miniature continuous-batching server:

  * requests (token prompts) queue up; the scheduler packs up to
    ``max_batch`` into a slot-based batch,
  * ``prefill`` (remotable) builds the KV caches on the serving tier,
  * ``decode`` (remotable) advances every active slot one token per call;
    finished slots (EOS or length budget) free up,
  * params + caches stay resident on the serving tier via MDSS — decode
    offloads are code-only; only the sampled tokens cross the link,
  * both workflows execute over **one shared** :class:`EmeraldRuntime`
    (the server is a tenant of the long-lived scheduler, not the owner of
    per-call pools): decode submissions carry an *interactive* priority
    class, so on a fabric-backed tier they overtake batch tenants' queued
    tasks sharing the same runtime.

:class:`FrontDoor` is the many-tenant entry point on top: concurrent
single-request ``decode()`` calls from independent client threads
coalesce (``repro.core.batching.BatchCoalescer``) into ONE fused
interactive dispatch per flush window — per-task scheduling overhead is
paid once per batch, per-request deadlines can force an early flush, and
each participant is charged 1/k of the fused cost.

CLI demo (CPU-sized):
  python -m repro.launch.serve --arch tinyllama-1.1b --reduced
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, RunConfig, ShapeProfile, reduced
from repro.core import (CostModel, EmeraldExecutor, EmeraldRuntime, MDSS,
                        MigrationManager, Workflow, default_tiers, partition)
from repro.models.model_zoo import Model

INTERACTIVE = 1          # broker dispatch class for latency-bound decodes


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new: int = 16
    tokens: List[int] = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, run: RunConfig, params, *, policy: str = "annotate",
                 max_batch: Optional[int] = None,
                 runtime: Optional[EmeraldRuntime] = None):
        self.run = run
        self.model = Model(run)
        self.policy = policy
        self.max_batch = max_batch or run.shape.global_batch
        self._owns_runtime = runtime is None
        if runtime is None:
            self.tiers = default_tiers()
            self.cost_model = CostModel(self.tiers)
            self.mdss = MDSS(self.tiers, cost_model=self.cost_model)
            self.manager = MigrationManager(self.tiers, self.mdss,
                                            self.cost_model)
            runtime = EmeraldRuntime(self.manager, policy=policy,
                                     name="serve")
        else:                    # tenant of an existing multi-tenant runtime
            self.manager = runtime.manager
            self.tiers = self.manager.tiers
            self.cost_model = self.manager.cost_model
            self.mdss = runtime.mdss
        self.runtime = runtime
        self._build_workflows()
        self.params = params
        self.queue: List[Request] = []
        self.stats = {"prefills": 0, "decode_calls": 0, "tokens_out": 0}

    def close(self):
        # a tenant never tears down a shared runtime it doesn't own
        if self._owns_runtime:
            self.runtime.close()

    def _build_workflows(self):
        prefill, decode = self.model.prefill, self.model.decode_step

        def prefill_fn(params, batch, cache):
            logits, cache = prefill(params, batch, cache)
            return {"logits": logits, "cache": cache}

        def decode_fn(params, tokens, cache):
            logits, cache = decode(params, tokens, cache)
            return {"logits": logits, "cache": cache}

        wfp = Workflow("serve-prefill")
        for v in ("params", "batch", "cache"):
            wfp.var(v)
        wfp.step("prefill", prefill_fn, inputs=("params", "batch", "cache"),
                 outputs=("logits", "cache"), remotable=True)
        wfd = Workflow("serve-decode")
        for v in ("params", "tokens", "cache"):
            wfd.var(v)
        wfd.step("decode", decode_fn, inputs=("params", "tokens", "cache"),
                 outputs=("logits", "cache"), remotable=True)
        # two typed front-ends over the ONE shared runtime: prefill and
        # decode interleave with each other (and any co-tenant workflows)
        # on the same lanes, fabric, and MDSS
        self.ex_prefill = EmeraldExecutor(partition(wfp), self.manager,
                                          policy=self.policy,
                                          runtime=self.runtime)
        self.ex_decode = EmeraldExecutor(partition(wfd), self.manager,
                                         policy=self.policy,
                                         runtime=self.runtime)

    # ------------------------------------------------------------------ api
    def submit(self, req: Request):
        self.queue.append(req)

    def _pack(self, reqs: List[Request]):
        """Left-pad-free packing: common prefix length = min prompt len."""
        B = self.max_batch
        plen = min(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.prompt[:plen]
        return jnp.asarray(toks), plen

    def step_batch(self) -> List[Request]:
        """Serve one packed batch from the queue to completion."""
        if not self.queue:
            return []
        reqs = self.queue[: self.max_batch]
        self.queue = self.queue[self.max_batch:]
        toks, plen = self._pack(reqs)
        out = self.ex_prefill.run(
            {"params": self.params, "batch": {"tokens": toks},
             "cache": self.model.init_cache()},
            fetch=("logits",))
        self.stats["prefills"] += 1
        last = jnp.argmax(out["logits"], -1)
        for i, r in enumerate(reqs):
            r.tokens.append(int(last[i]))
        max_new = max(r.max_new for r in reqs)
        budget = min(max_new - 1, self.run.shape.seq_len - plen - 1)
        for _ in range(budget):
            # latency-bound: decode tasks overtake batch tenants' queued
            # work when the runtime's cloud tier is fabric-backed
            out = self.ex_decode.submit({"tokens": last}, fetch=("logits",),
                                        priority=INTERACTIVE).result()
            self.stats["decode_calls"] += 1
            last = jnp.argmax(out["logits"], -1)
            for i, r in enumerate(reqs):
                if not r.done and len(r.tokens) < r.max_new:
                    r.tokens.append(int(last[i]))
                    self.stats["tokens_out"] += 1
                else:
                    r.done = True
            if all(r.done or len(r.tokens) >= r.max_new for r in reqs):
                break
        for r in reqs:
            r.done = True
        return reqs

    def transfer_report(self) -> Dict:
        offloads = [e for e in self.ex_decode.events if e.kind == "offload"]
        return {"decode_offloads": len(offloads),
                "decode_code_only": sum(1 for e in offloads
                                        if e.info.get("code_only")),
                "bytes_moved": dict(self.mdss.bytes_moved)}


class FrontDoor:
    """Coalescing decode entry point over one shared runtime.

    ``decode_fn(stacked_tokens)`` must be a *batched, row-independent*
    decode: it receives the (k, ...) stack of k concurrent requests'
    inputs and returns an array whose row i is request i's output —
    that row-independence is what makes cross-tenant fusion safe (see
    ``core/batching``). Each flush becomes ONE interactive-priority
    submission through the runtime, so k tenants' decodes pay one
    partition/validate/dispatch round trip instead of k.

    Client threads call ``decode(tokens, deadline_s=...)`` and block on
    the returned ticket; a request's deadline can flush the bucket
    early, and ``slo_ms`` arms the runtime's preemption guard for the
    fused runs themselves.
    """

    def __init__(self, runtime: EmeraldRuntime, decode_fn, *,
                 window_s: float = 0.004, max_batch: int = 32,
                 policy: str = "annotate", remotable: bool = False,
                 slo_ms: Optional[float] = None, name: str = "frontdoor"):
        from repro.core.batching import BatchCoalescer
        self.runtime = runtime
        self.slo_ms = slo_ms
        self._fp = getattr(decode_fn, "__name__", "decode")

        def fused_decode_fn(tokens):
            return {"logits": decode_fn(tokens)}

        wf = Workflow(f"{name}-fused-decode")
        wf.var("tokens")
        wf.step("decode", fused_decode_fn, inputs=("tokens",),
                outputs=("logits",), remotable=remotable, jax_step=False,
                slo_ms=slo_ms)
        self._ex = EmeraldExecutor(partition(wf), runtime.manager,
                                   policy=policy, runtime=runtime)
        self.coalescer = BatchCoalescer(
            self._fuse, window_s=window_s, max_batch=max_batch,
            metrics=runtime.metrics, tracer=runtime.tracer, name=name)
        runtime.attach_coalescer(self.coalescer)

    def _fuse(self, key, stacked: np.ndarray, k: int) -> np.ndarray:
        out = self._ex.submit({"tokens": stacked}, fetch=("logits",),
                              priority=INTERACTIVE).result()
        return np.asarray(out["logits"])

    # ------------------------------------------------------------------ api
    def decode(self, tokens, *, deadline_s: Optional[float] = None,
               charge=None):
        """Join the current batch for this (code, shape, dtype) bucket;
        returns a ticket — ``ticket.result()`` is this request's logits
        row. Requests with different shapes/dtypes never fuse."""
        arr = np.asarray(tokens)
        key = (self._fp, arr.shape, str(arr.dtype))
        return self.coalescer.submit(key, arr, deadline_s=deadline_s,
                                     charge=charge)

    def stats(self) -> dict:
        return self.coalescer.introspect()

    def close(self):
        self.coalescer.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)) if args.reduced else get_config(args.arch)
    run = RunConfig(model=cfg, shape=ShapeProfile("serve", 128, 4, "decode"),
                    remat="none")
    model = Model(run)
    params = model.init_params(jax.random.PRNGKey(0))
    srv = Server(run, params)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        srv.submit(Request(rid, rng.integers(
            0, cfg.vocab_size, rng.integers(8, 32)).astype(np.int32),
            max_new=args.max_new))
    t0 = time.time()
    done: List[Request] = []
    while srv.queue:
        done += srv.step_batch()
    dt = time.time() - t0
    for r in done:
        print(f"req {r.rid}: {len(r.tokens)} tokens -> {r.tokens[:8]}...")
    print(f"{srv.stats} in {dt:.2f}s; transfers: {srv.transfer_report()}")
    srv.close()


if __name__ == "__main__":
    main()
