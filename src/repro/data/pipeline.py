"""Deterministic synthetic LM data pipeline.

Produces sharded global batches for any (arch, shape) cell:
  * ``tokens``/``labels`` (B, S) int32
  * modality-stub tensors for vlm/audio archs (``frontend_embeds`` /
    ``encoder_embeds``) per the assignment spec (frontends are stubs).

Deterministic per (seed, step) so restarts resume bit-identically — the
property checkpoint/restart tests rely on. The generator is a stateless
``step -> batch`` map (no hidden iterator state to checkpoint).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeProfile


def token_batch_shapes(cfg: ModelConfig, shape: ShapeProfile) -> Dict[str, tuple]:
    """Shapes of one global training batch for this (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if cfg.is_encoder_decoder:
        out["encoder_embeds"] = (B, S, cfg.d_model)
        out["tokens"] = (B, S)
        out["labels"] = (B, S)
    elif cfg.frontend:
        F = cfg.frontend_tokens
        out["frontend_embeds"] = (B, F, cfg.d_model)
        out["tokens"] = (B, S - F)
        out["labels"] = (B, S - F)
    else:
        out["tokens"] = (B, S)
        out["labels"] = (B, S)
    return out


def batch_logical_axes(cfg: ModelConfig, shape: ShapeProfile):
    shapes = token_batch_shapes(cfg, shape)
    axes = {}
    for k, shp in shapes.items():
        axes[k] = ("act_batch",) + (None,) * (len(shp) - 1)
    return axes


def make_batch_specs(cfg: ModelConfig, shape: ShapeProfile):
    """Abstract batch (ShapeDtypeStruct pytree) for lowering."""
    shapes = token_batch_shapes(cfg, shape)
    out = {}
    for k, shp in shapes.items():
        dt = jnp.dtype(cfg.dtype) if "embeds" in k else jnp.int32
        out[k] = jax.ShapeDtypeStruct(shp, dt)
    return out


@dataclass
class SyntheticLMData:
    """Stateless deterministic batch source (markov-ish token stream)."""

    cfg: ModelConfig
    shape: ShapeProfile
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        shapes = token_batch_shapes(self.cfg, self.shape)
        rng = np.random.default_rng((self.seed, step))
        out = {}
        for k, shp in shapes.items():
            if "embeds" in k:
                out[k] = jnp.asarray(
                    rng.standard_normal(shp, dtype=np.float32) * 0.02,
                    jnp.dtype(self.cfg.dtype))
            elif k == "tokens":
                # low-entropy stream so tiny models show loss decrease
                base = rng.integers(0, self.cfg.vocab_size, shp[0])[:, None]
                drift = rng.integers(0, 7, shp)
                out[k] = jnp.asarray(
                    (base + np.cumsum(drift, -1)) % self.cfg.vocab_size,
                    jnp.int32)
        if "labels" in shapes:
            out["labels"] = out["tokens"]
        return out
