"""Data-parallel scatter/gather fan-out over the content-addressed plane.

Covers the PR's acceptance surface:

  * submit-time expansion (partitioner): scatter + N shard steps + gather,
    shard URIs ``uri#k``, arg/out name remapping, hint splitting,
  * end-to-end correctness on the multi-worker local lane, with custom
    partition/combine fns, broadcast inputs and multiple outputs,
  * fan-out telemetry: scatter/shard_done/gather events, fanout.*
    counters, and shard dispatch spans nesting under one umbrella span,
  * fair share: a 32-shard batch tenant is charged per shard and cannot
    starve an interactive tenant sharing the lanes,
  * shard-level fault isolation on a real fabric: one shard's worker is
    hard-killed mid-run; the broker requeues that shard invisibly and
    siblings are untouched,
  * per-shard memoization: re-running after mutating 1 of 8 shard inputs
    re-executes exactly that shard,
  * verifier admission: an illegal fan-out spec is rejected with W060.
"""
import threading
import time

import numpy as np
import pytest

from repro.analysis import WorkflowRejected, verify
from repro.cloud import Fabric
from repro.core import (CostModel, EmeraldExecutor, EmeraldRuntime, MDSS,
                        MigrationManager, Workflow, default_tiers, partition)
from repro.core.partitioner import expand_fanouts
from repro.core.workflow import Fanout, WorkflowError


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def double(P, c):
    return {"out": np.asarray(P) * 2 + c}


def fan_wf(shards=4, name="fan"):
    wf = Workflow(name)
    wf.var("P")
    wf.var("c")
    wf.step("big", double, inputs=("P", "c"), outputs=("out",),
            jax_step=False, flops_hint=8e9, bytes_hint=8e6,
            fanout=Fanout(shards=shards, scatter=("P",)))
    return wf


# ---------------------------------------------------------------- expansion
def test_expansion_structure():
    ewf = expand_fanouts(fan_wf(shards=4))
    assert list(ewf.order) == ["big.scatter", "big#0", "big#1", "big#2",
                               "big#3", "big.gather"]
    sc = ewf.steps["big.scatter"]
    assert sc.fanout_role == "scatter" and sc.fanout_parent == "big"
    assert sc.inputs == ("P",)
    assert sc.outputs == ("P#0", "P#1", "P#2", "P#3")
    assert sc.memoizable is False
    for k in range(4):
        sh = ewf.steps[f"big#{k}"]
        assert sh.fanout_role == "shard" and sh.shard_index == k
        assert sh.inputs == (f"P#{k}", "c")       # c broadcasts whole
        assert sh.arg_names == ("P", "c")         # fn still sees P=, c=
        assert sh.outputs == (f"out#{k}",)
        assert sh.out_names == ("out",)
        assert sh.fn is double                    # unwrapped: stable code key
        assert sh.flops_hint == pytest.approx(2e9)
        assert sh.bytes_hint == pytest.approx(2e6)
    ga = ewf.steps["big.gather"]
    assert ga.fanout_role == "gather"
    assert ga.inputs == ("out#0", "out#1", "out#2", "out#3")
    assert ga.outputs == ("out",)
    assert ga.memoizable is False
    # the expanded form admits cleanly (W005 honours arg_names)
    assert [f for f in verify(ewf, provided={"P", "c"})
            if f.severity == "error"] == []


def test_expansion_is_identity_without_fanout():
    wf = Workflow("plain")
    wf.var("x")
    wf.step("s", lambda **kw: {}, inputs=("x",), outputs=("y",))
    assert expand_fanouts(wf) is wf


def test_nested_fanout_rejected():
    wf = Workflow("nested")
    wf.var("P")
    wf.step("outer", None, inputs=("P",), outputs=("o",),
            fanout=Fanout(shards=2))
    wf.step("inner", double, inputs=("P",), outputs=("q",), parent="outer")
    with pytest.raises(WorkflowError, match="nested"):
        expand_fanouts(wf)


def test_illegal_spec_rejected_at_admission_with_w060():
    wf = Workflow("badspec")
    wf.var("P")
    wf.step("big", double, inputs=("P",), outputs=("out",), jax_step=False,
            fanout=Fanout(shards=0))
    with EmeraldRuntime(emerald(), telemetry=False) as rt:
        with pytest.raises(WorkflowRejected, match="W060"):
            rt.submit(wf, {"P": np.arange(4)})


# ------------------------------------------------------------- end to end
def test_fanout_end_to_end_local():
    P = np.arange(37, dtype=np.float64)       # deliberately not divisible
    with EmeraldRuntime(emerald(), local_workers=4) as rt:
        h = rt.submit(fan_wf(shards=8), {"P": P, "c": 3.0})
        out = h.result(60)["out"]
        np.testing.assert_array_equal(out, P * 2 + 3.0)
        kinds = [e.kind for e in h.events]
        assert kinds.count("shard_done") == 8
        assert kinds.count("scatter") == 1 and kinds.count("gather") == 1
        snap = rt.metrics.snapshot()
        assert snap["fanout.scatters"] == 1
        assert snap["fanout.shards_dispatched"] == 8
        assert snap["fanout.shards_completed"] == 8
        assert snap["fanout.gathers"] == 1


def _halves(v, n):
    return np.array_split(np.asarray(v) ** 2, n)     # square while splitting


def _summed(parts):
    return np.sum([np.asarray(p).sum() for p in parts])


def stats(P, w):
    arr = np.asarray(P)
    return {"total": arr.sum() * w, "count": np.float64(arr.size)}


def test_custom_partition_combine_and_multi_output():
    wf = Workflow("custom")
    wf.var("P")
    wf.var("w")
    wf.step("agg", stats, inputs=("P", "w"), outputs=("total", "count"),
            jax_step=False,
            fanout=Fanout(shards=3, scatter=("P",),
                          partition_fn=_halves, combine_fn=_summed))
    P = np.arange(10, dtype=np.float64)
    with EmeraldRuntime(emerald(), local_workers=3) as rt:
        res = rt.submit(wf, {"P": P, "w": 2.0}).result(60)
    assert float(res["total"]) == pytest.approx(float((P ** 2).sum() * 2))
    assert float(res["count"]) == 10.0


def test_fanout_through_executor_shim():
    mgr = emerald()
    ex = EmeraldExecutor(partition(fan_wf(shards=4)), mgr, local_workers=4)
    out = ex.run({"P": np.arange(12, dtype=np.float64), "c": 0.0})
    np.testing.assert_array_equal(out["out"], np.arange(12) * 2.0)


# ---------------------------------------------------------------- tracing
def test_shard_spans_nest_under_fanout_umbrella():
    with EmeraldRuntime(emerald(), local_workers=4) as rt:
        h = rt.submit(fan_wf(shards=4), {"P": np.arange(8.0), "c": 0.0})
        h.result(60)
        spans = rt.tracer.spans(h.trace_id)
        by_id = {s.span_id: s for s in spans}
        (fan,) = [s for s in spans if s.name == "fanout:big"]
        (root,) = [s for s in spans if s.name == "run"]
        assert fan.parent_id == root.span_id
        nested = {s.attrs.get("step") for s in spans
                  if s.name == "dispatch" and s.parent_id == fan.span_id}
        assert nested == {"big.scatter", "big#0", "big#1", "big#2",
                          "big#3", "big.gather"}
        # worker-free sanity: every dispatch span still roots at the run
        for s in spans:
            if s.name != "dispatch":
                continue
            cur = s
            while cur.parent_id and cur.parent_id in by_id:
                cur = by_id[cur.parent_id]
            assert cur.name == "run"


# ---------------------------------------------------- cost model fallback
def test_shard_exec_estimate_falls_back_to_parent_stats():
    cm = CostModel(default_tiers())
    cm.stats_for("big").observe("local", 0.8)
    ewf = expand_fanouts(fan_wf(shards=8))
    sh = ewf.steps["big#0"]
    # hints would win; strip them to isolate the parent-stats path
    sh.flops_hint = 0.0
    sh.bytes_hint = 0.0
    assert cm.exec_time(sh, "local") == pytest.approx(0.1)
    # the fan-out's aggregate fair-share charge is the sum over shards
    total = 0.0
    for k in range(8):
        s = ewf.steps[f"big#{k}"]
        s.flops_hint = s.bytes_hint = 0.0
        total += cm.exec_time(s, "local")
    assert total == pytest.approx(0.8)


# -------------------------------------------------------------- fair share
def _slow_shard(P):
    time.sleep(0.03)
    return {"bout": np.asarray(P)}


def _quick(x):
    time.sleep(0.005)
    return {"x": np.asarray(x) + 1}


def test_32_shard_tenant_cannot_starve_interactive_tenant():
    """Regression: fan-out cost is charged per shard (sum of shard
    placement scores), so a 32-shard batch tenant accrues fair-share
    vtime per dispatched shard and an interactive tenant's steps
    interleave instead of queueing behind the whole fan-out."""
    batch = Workflow("batch")
    batch.var("P")
    batch.step("wide", _slow_shard, inputs=("P",), outputs=("bout",),
               jax_step=False, fanout=Fanout(shards=32))
    inter = Workflow("interactive")
    inter.var("x")
    prev = "x"
    for i in range(3):
        inter.step(f"q{i}", _quick, inputs=(prev,), outputs=(f"x{i}",),
                   jax_step=False, arg_names=("x",), out_names=("x",))
        prev = f"x{i}"
    with EmeraldRuntime(emerald(), local_workers=2, telemetry=False) as rt:
        hb = rt.submit(batch, {"P": np.arange(32.0)})
        hi = rt.submit(inter, {"x": np.float64(0.0)})
        hi.result(120)
        hb.result(120)
    t_inter_done = max(e.t for e in hi.events if e.kind == "step_done")
    shards_before = sum(1 for e in hb.events
                        if e.kind == "shard_done" and e.t <= t_inter_done)
    assert shards_before <= 16, \
        (f"interactive tenant waited behind {shards_before}/32 batch "
         "shards — fan-out fair-share charging regressed")


# ------------------------------------------------------- shard fault paths
@pytest.mark.slow
def test_shard_worker_crash_requeues_only_that_shard(tmp_path):
    """Kill one shard's worker mid-run: the broker requeues that shard
    invisibly (attempt stays 0, no runtime retry), siblings complete
    undisturbed, and the gathered result is exact."""
    wf = Workflow("crashy-fan")
    wf.var("counter_file")
    wf.var("n_crashes")
    wf.var("x")
    wf.step("big", None, inputs=("counter_file", "n_crashes", "x"),
            outputs=("y",), remotable=True, jax_step=False,
            remote_impl="crash_n_times",
            fanout=Fanout(shards=8, scatter=("x",)))
    x = np.arange(8, dtype=np.float64)
    with Fabric(workers=2) as fabric:
        with EmeraldRuntime(emerald(), max_workers=4) as rt:
            rt.attach_fabric(fabric)
            before = fabric.broker.tasks_requeued
            h = rt.submit(wf, {
                "counter_file": str(tmp_path / "fancrash"),
                "n_crashes": 1, "x": x})
            out = h.result(120)["y"]
            np.testing.assert_array_equal(out, x + 1.0)
            assert fabric.broker.tasks_requeued >= before + 1
            # the crash stayed below the runtime: no retry event, every
            # shard offload reports attempt 0, all 8 siblings completed
            assert [e for e in h.events if e.kind == "retry"] == []
            offs = [e for e in h.events if e.kind == "offload"]
            assert offs and all(e.info["attempt"] == 0 for e in offs)
            assert sum(1 for e in h.events if e.kind == "shard_done") == 8


# ------------------------------------------------------- per-shard memo
SHARD_CALLS = []
_calls_lock = threading.Lock()


def counted_shard(P):
    with _calls_lock:
        SHARD_CALLS.append(np.asarray(P).copy())
    return {"out": np.asarray(P) * 2}


def memo_wf():
    wf = Workflow("memo-fan")
    wf.var("P")
    wf.step("big", counted_shard, inputs=("P",), outputs=("out",),
            jax_step=False, fanout=Fanout(shards=8))
    return wf


def test_per_shard_memo_reexecutes_only_mutated_shard():
    SHARD_CALLS.clear()
    P1 = np.arange(64, dtype=np.float64)
    P2 = P1.copy()
    P2[27] += 100.0                    # lands in shard 3 of np.array_split
    with EmeraldRuntime(emerald(), local_workers=4, memoize=True) as rt:
        h1 = rt.submit(memo_wf(), {"P": P1})
        np.testing.assert_array_equal(h1.result(60)["out"], P1 * 2)
        assert len(SHARD_CALLS) == 8
        h2 = rt.submit(memo_wf(), {"P": P2})
        np.testing.assert_array_equal(h2.result(60)["out"], P2 * 2)
    assert len(SHARD_CALLS) == 9, \
        "mutating one shard's rows must re-execute exactly that shard"
    np.testing.assert_array_equal(SHARD_CALLS[-1],
                                  np.array_split(P2, 8)[3])
    hits = [e.info["memo_hit"] for e in h2.events
            if e.kind == "local" and "#" in e.step]
    assert sorted(hits) == [False] + [True] * 7
