"""Roofline/HLO-analysis unit tests (parser correctness on crafted HLO)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch.hlo_analysis import collective_bytes, _shape_bytes
from repro.launch.roofline import (attention_flops, matmul_param_counts,
                                   model_flops, roofline_terms)


def test_shape_bytes():
    assert _shape_bytes("f32[16,256]{1,0}") == 16 * 256 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8
    assert _shape_bytes("pred[10]") == 10


HLO = """
  %ag = f32[1024,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %ar = bf16[128]{0} all-reduce(%y), replica_groups=[4,64]<=[256], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = f32[32,32]{1,0} all-to-all(%w), replica_groups=[2,8]<=[16]
  %cp = f32[16]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %agd = f32[9]{0} all-gather-done(%ag2)
  %dot = f32[16,256]{1,0} dot(%a, %b)
"""


def test_collective_parser():
    out = collective_bytes(HLO)
    g = 16
    assert np.isclose(out["all-gather"], 1024 * 256 * 4 * (g - 1) / g)
    assert np.isclose(out["all-reduce"], 2 * 128 * 2 * 63 / 64)
    assert np.isclose(out["reduce-scatter"], 64 * 4 * 3)     # (g-1)*result
    assert np.isclose(out["all-to-all"], 32 * 32 * 4 * 7 / 8)
    assert np.isclose(out["collective-permute"], 16 * 4)
    # -done lines are not double counted
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_collective_parser_start_counted_once():
    txt = "%s = f32[8]{0} all-reduce-start(%x), replica_groups=[2,2]<=[4]\n" \
          "%d = f32[8]{0} all-reduce-done(%s)\n"
    out = collective_bytes(txt)
    assert np.isclose(out["all-reduce"], 2 * 32 * 1 / 2)


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e7, 50e7)     # 1s compute, 0.01s others
    assert t["dominant"] == "compute_s"
    assert np.isclose(t["compute_s"], 1.0)
    t2 = roofline_terms(1, 1, 50e9)
    assert t2["dominant"] == "collective_s" and np.isclose(t2["collective_s"], 1.0)


def test_param_counts_sane():
    total, active = matmul_param_counts(get_config("tinyllama-1.1b"))
    assert 0.9e9 < total < 1.3e9
    assert total == active                      # dense: all params active
    t_moe, a_moe = matmul_param_counts(get_config("deepseek-v3-671b"))
    assert 600e9 < t_moe < 750e9
    assert 25e9 < a_moe < 50e9                  # ~37B active


def test_model_flops_train_scale():
    cfg = get_config("tinyllama-1.1b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    # 6*N*D with N~1.1e9, D=1.05e6 -> ~6.9e15 plus attention
    assert 6e15 < mf < 1.1e16


def test_model_flops_decode_much_smaller():
    cfg = get_config("tinyllama-1.1b")
    mf_d = model_flops(cfg, SHAPES["decode_32k"])
    mf_t = model_flops(cfg, SHAPES["train_4k"])
    assert mf_d < mf_t / 1000


def test_attention_flops_quadratic_in_seq():
    cfg = get_config("llama3.2-3b")
    f1 = attention_flops(cfg, 1, 1024, "train")
    f2 = attention_flops(cfg, 1, 2048, "train")
    assert np.isclose(f2 / f1, 4.0, rtol=0.01)
