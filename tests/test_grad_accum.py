"""Gradient accumulation: accum=k must reproduce the full-batch step."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeProfile, reduced
from repro.data.pipeline import SyntheticLMData
from repro.models.model_zoo import Model

# compile-heavy: excluded from the smoke fast lane (-m "not slow"),
# still part of tier-1 (plain pytest runs everything)
pytestmark = pytest.mark.slow


def test_grad_accum_matches_full_batch():
    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    shape = ShapeProfile("t", 32, 4, "train")
    run1 = RunConfig(model=cfg, shape=shape, remat="none", grad_accum=1)
    run2 = run1.with_(grad_accum=2)
    m1, m2 = Model(run1), Model(run2)
    params = m1.init_params(jax.random.PRNGKey(0))
    opt = m1.opt_init(params)
    batch = SyntheticLMData(cfg, shape).batch(0)
    p1, o1, met1 = jax.jit(m1.train_step)(params, opt, batch)
    p2, o2, met2 = jax.jit(m2.train_step)(params, opt, batch)
    np.testing.assert_allclose(float(met1["loss"]), float(met2["loss"]),
                               rtol=1e-5)
    err = max(float(jax.numpy.max(jax.numpy.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert err < 1e-5, f"accumulated update diverges: {err}"


def test_grad_accum_four_way():
    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    shape = ShapeProfile("t", 16, 8, "train")
    run = RunConfig(model=cfg, shape=shape, remat="none", grad_accum=4)
    m = Model(run)
    params = m.init_params(jax.random.PRNGKey(1))
    opt = m.opt_init(params)
    batch = SyntheticLMData(cfg, shape).batch(0)
    p, o, met = jax.jit(m.train_step)(params, opt, batch)
    assert np.isfinite(float(met["loss"]))
