"""Partitioner unit tests: legality properties P1-P3 + migration points."""
import pytest

from repro.core import (MigrationPoint, PartitionError, Workflow, partition)


def simple_wf(remotables=("b",)):
    wf = Workflow("w")
    wf.var("x")
    wf.step("a", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
            remotable="a" in remotables)
    wf.step("b", lambda y: {"z": y}, inputs=("y",), outputs=("z",),
            remotable="b" in remotables)
    wf.step("c", lambda z: {"w": z}, inputs=("z",), outputs=("w",),
            remotable="c" in remotables)
    return wf


def test_migration_point_inserted_before_each_remotable():
    pwf = partition(simple_wf(remotables=("a", "c")))
    names = [s.name for s in pwf.sequence]
    assert names == ["__migrate__a", "a", "b", "__migrate__c", "c"]
    assert len(pwf.migration_points) == 2


def test_no_remotable_no_migration_points():
    pwf = partition(simple_wf(remotables=()))
    assert pwf.migration_points == []
    assert [s.name for s in pwf.sequence] == ["a", "b", "c"]


def test_property1_local_hardware():
    wf = Workflow("w")
    wf.var("x")
    wf.step("gpu_step", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
            remotable=True, requires_local_hardware=True)
    with pytest.raises(PartitionError) as e:
        partition(wf)
    assert e.value.prop == 1


def test_property1_local_hardware_ok_when_not_remotable():
    wf = Workflow("w")
    wf.var("x")
    wf.step("gpu_step", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
            remotable=False, requires_local_hardware=True)
    partition(wf)  # fine


def test_property2_variable_scope():
    wf = Workflow("w")
    wf.var("x")
    wf.step("s1", lambda x: {"hidden": x}, inputs=("x",), outputs=("hidden",))
    wf.variables["hidden"].scope = ("s1",)       # declared inside s1
    wf.step("s2", lambda hidden: {"o": hidden}, inputs=("hidden",),
            outputs=("o",), remotable=True)
    with pytest.raises(PartitionError) as e:
        partition(wf)
    assert e.value.prop == 2


def test_property2_nested_step_sibling_vars_ok():
    wf = Workflow("w")
    wf.var("x")
    wf.step("outer", lambda x: {"y": x}, inputs=("x",), outputs=("y",))
    wf.step("inner", lambda: {"v": 1}, parent="outer", outputs=("v",),
            remotable=True)
    # v is declared at inner's level (inside outer) -> legal for inner
    partition(wf)


def test_property3_nested_offloading():
    wf = Workflow("w")
    wf.var("x")
    wf.step("outer", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
            remotable=True)
    wf.step("inner", lambda: {"v": 1}, parent="outer", outputs=("v",),
            remotable=True)
    with pytest.raises(PartitionError) as e:
        partition(wf)
    assert e.value.prop == 3


def test_undeclared_input_rejected():
    wf = Workflow("w")
    wf.step("s", lambda q: {"y": q}, inputs=("q",), outputs=("y",))
    with pytest.raises(Exception):
        partition(wf)


def test_partition_idempotent_structure():
    wf = simple_wf()
    p1 = partition(wf)
    p2 = partition(wf)
    assert [s.name for s in p1.sequence] == [s.name for s in p2.sequence]


def test_dependencies_dataflow():
    wf = Workflow("w")
    wf.var("x")
    wf.step("a", lambda x: {"y": x}, inputs=("x",), outputs=("y",))
    wf.step("b", lambda x: {"z": x}, inputs=("x",), outputs=("z",))
    wf.step("c", lambda y, z: {"w": y}, inputs=("y", "z"), outputs=("w",))
    deps = wf.dependencies()
    assert deps["a"] == set() and deps["b"] == set()
    assert deps["c"] == {"a", "b"}


def test_write_after_write_ordering():
    wf = Workflow("w")
    wf.var("m")
    wf.step("a", lambda m: {"m": m}, inputs=("m",), outputs=("m",))
    wf.step("b", lambda m: {"m": m}, inputs=("m",), outputs=("m",))
    deps = wf.dependencies()
    assert deps["b"] == {"a"}
