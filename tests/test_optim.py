"""Optimizer tests: AdamW/Adafactor correctness + state layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (adafactor_init, adafactor_update,
                                    adamw_init, adamw_update,
                                    clip_by_global_norm, global_norm,
                                    opt_state_axes)


def quadratic_params():
    return {"w": jnp.asarray([[3.0, -2.0], [1.5, 0.5]]),
            "b": jnp.asarray([1.0, -1.0])}


def loss_fn(p):
    return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)


def test_adamw_converges_on_quadratic():
    p = quadratic_params()
    s = adamw_init(p)
    for _ in range(300):
        g = jax.grad(loss_fn)(p)
        p, s = adamw_update(p, g, s, lr=0.05, weight_decay=0.0)
    assert float(loss_fn(p)) < 1e-3


def test_adafactor_converges_on_quadratic():
    p = quadratic_params()
    s = adafactor_init(p)
    for _ in range(300):
        g = jax.grad(loss_fn)(p)
        p, s = adafactor_update(p, g, s, lr=0.05)
    assert float(loss_fn(p)) < 1e-2


def test_adamw_first_step_matches_reference():
    """One step against a hand-computed Adam update."""
    p = {"w": jnp.asarray([[1.0]])}
    g = {"w": jnp.asarray([[0.5]])}
    s = adamw_init(p)
    newp, s2 = adamw_update(p, g, s, lr=0.1, b1=0.9, b2=0.95, eps=1e-8,
                            weight_decay=0.0)
    mu_hat = 0.1 * 0.5 / (1 - 0.9)
    nu_hat = 0.05 * 0.25 / (1 - 0.95)
    expected = 1.0 - 0.1 * (mu_hat / (np.sqrt(nu_hat) + 1e-8))
    np.testing.assert_allclose(float(newp["w"][0, 0]), expected, rtol=1e-6)
    assert int(s2["step"]) == 1


def test_weight_decay_only_on_matrices():
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    s = adamw_init(p)
    newp, _ = adamw_update(p, g, s, lr=0.1, weight_decay=0.5)
    assert float(newp["w"][0, 0]) < 1.0      # decayed
    np.testing.assert_allclose(np.asarray(newp["b"]), 1.0)  # not decayed


def test_adafactor_state_is_factored():
    p = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
    s = adafactor_init(p)
    assert s["v"]["w"]["vr"].shape == (8,)
    assert s["v"]["w"]["vc"].shape == (16,)
    assert s["v"]["b"]["v"].shape == (16,)
    # stacked (layer) params factor over the trailing two dims
    p2 = {"w": jnp.ones((4, 8, 16))}
    s2 = adafactor_init(p2)
    assert s2["v"]["w"]["vr"].shape == (4, 8)
    assert s2["v"]["w"]["vc"].shape == (4, 16)


def test_opt_state_axes_mirror_params():
    axes = {"w": ("layers", "embed", "ff"), "b": ("ff",)}
    a = opt_state_axes("adamw", axes)
    assert a["mu"]["w"] == ("layers", "embed", "ff")
    f = opt_state_axes("adafactor", axes)
    assert f["v"]["w"]["vr"] == ("layers", "embed")
    assert f["v"]["w"]["vc"] == ("layers", "ff")
    assert f["v"]["b"]["v"] == ("ff",)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit: unchanged
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 3.0)


def test_bf16_state_dtype():
    p = {"w": jnp.ones((4, 4), jnp.float32)}
    s = adamw_init(p, state_dtype="bfloat16")
    assert s["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1)}
    newp, s2 = adamw_update(p, g, s, lr=0.01)
    assert s2["mu"]["w"].dtype == jnp.bfloat16
    assert newp["w"].dtype == jnp.float32
