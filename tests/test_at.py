"""Adjoint-tomography integration tests (the paper's evaluation app)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.adjoint_tomography import (ATConfig, build_workflow,
                                           make_observations, simulate,
                                           starting_model, true_model)
from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        default_tiers, partition)

CFG = ATConfig(nx=32, ny=12, nz=12, nt=80)


def run_at(policy, iters=3, cfg=CFG):
    obs = make_observations(cfg)
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    ex = EmeraldExecutor(partition(build_workflow(cfg)), mgr, policy=policy)
    model = starting_model(cfg)
    chis = []
    for _ in range(iters):
        res = ex.run({"model": model, "obs": obs})
        model = res["model"]
        chis.append(float(res["chi"]))
    return chis, model, ex, mdss


def test_simulation_stable():
    seis = simulate(true_model(CFG), CFG)
    assert np.isfinite(np.asarray(seis)).all()
    assert float(jnp.max(jnp.abs(seis))) > 1e-6   # wave actually reaches
    assert seis.shape == (CFG.nt, CFG.n_receivers)


def test_misfit_decreases():
    chis, _, _, _ = run_at("never", iters=4)
    assert chis[-1] < chis[0] * 0.9


def test_offload_equals_local_execution():
    """Paper's correctness claim: offloading must not change results."""
    chis_local, m_local, _, _ = run_at("never", iters=3)
    chis_cloud, m_cloud, ex, _ = run_at("annotate", iters=3)
    np.testing.assert_allclose(chis_local, chis_cloud, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m_local), np.asarray(m_cloud),
                               rtol=1e-5)
    # and steps 2-4 were actually offloaded each iteration
    offl = [e for e in ex.events if e.kind == "offload"]
    assert len(offl) == 3 * 3


def test_mdss_residency_saves_transfer():
    """obs moves to the cloud once; later iterations reuse the copy."""
    cfg = CFG
    obs = make_observations(cfg)
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    ex = EmeraldExecutor(partition(build_workflow(cfg)), mgr)
    model = starting_model(cfg)
    per_iter = []
    init = {"model": model, "obs": obs}
    for _ in range(3):
        mdss.reset_accounting()
        ex.run(init, fetch=("chi",))
        init = {}      # model/obs stay MDSS-resident between iterations
        per_iter.append(sum(v for (s, d), v in mdss.bytes_moved.items()
                            if d == "cloud"))
    # first iteration pays obs+model upload; later ones ship only the
    # locally-computed synthetics (forward runs on the local tier)
    assert per_iter[1] < per_iter[0]
    assert per_iter[2] == per_iter[1]


def test_true_model_recovery_direction():
    """Gradient points toward the true anomaly (sign sanity)."""
    cfg = CFG
    chis, model, _, _ = run_at("never", iters=5)
    err0 = float(jnp.mean((starting_model(cfg) - true_model(cfg)) ** 2))
    err1 = float(jnp.mean((model - true_model(cfg)) ** 2))
    assert err1 < err0
