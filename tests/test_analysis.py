"""Static workflow verifier + happens-before hazard sanitizer.

Covers the analysis subsystem's acceptance surface:

  * the seeded defect corpus (tests/defects/): every lint rule and every
    hazard class has a minimal defective artifact that fires exactly its
    rule id, and a clean twin that stays silent,
  * submit(validate=...) admission semantics — "error" rejects with
    WorkflowRejected (naming the rule ids), "warn" admits and attaches
    handle.findings, "off" skips analysis entirely,
  * kinded dependency edges (RAW/WAR/WW) and their equivalence with the
    legacy call shape,
  * construction-time duplicate step-name / duplicate-output errors that
    name both definition sites,
  * a real fabric-backed run whose event + replica logs replay clean
    through the sanitizer.
"""
import numpy as np
import pytest

from defects import CASES
from repro.analysis import (ERROR, RULES, WorkflowRejected, explorer,
                            sanitizer, verify)
from repro.analysis.selfcheck import check_snippet
from repro.core import (CostModel, EmeraldRuntime, MDSS, MigrationManager,
                        Workflow, default_tiers)
from repro.core.workflow import WorkflowError


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def run_case(kind, kwargs):
    kwargs = dict(kwargs)
    if kind == "verify":
        return verify(kwargs.pop("wf"), **kwargs)
    if kind == "events":
        return sanitizer.check(kwargs["events"],
                               completed_run=kwargs.get("completed_run", True))
    if kind == "store":
        return sanitizer.check_store(kwargs["installs"], kwargs["evictions"])
    if kind == "trace":
        return explorer.check_trace(kwargs)
    if kind == "source":
        return check_snippet(kwargs["text"])
    raise AssertionError(f"unknown case kind {kind}")


# ------------------------------------------------------------------ corpus
@pytest.mark.parametrize("rule", sorted(CASES))
def test_defect_corpus_fires_exact_rule(rule):
    kind, make_defective, make_clean = CASES[rule]
    fired = {f.rule for f in run_case(kind, make_defective())}
    assert rule in fired, f"{rule} did not fire on its defective artifact"
    clean = {f.rule for f in run_case(kind, make_clean())}
    assert rule not in clean, f"{rule} fired on its clean twin: {clean}"


def test_corpus_covers_every_registered_rule():
    # L001/L002 are exercised by the drift canary in test_obs;
    # everything else — verifier rules, sanitizer hazards, explorer
    # cross-schedule hazards, lock lints — must have a seeded defect
    # + clean twin here.
    expected = {r for r in RULES if r not in ("L001", "L002")}
    assert set(CASES) == expected


def test_findings_carry_metadata():
    kind, make_defective, _ = CASES["W001"]
    (f,) = [x for x in run_case(kind, make_defective()) if x.rule == "W001"]
    assert f.severity == ERROR
    assert f.steps and f.hint
    assert "W001" in str(f) and "->" in f.message  # witness path


# ------------------------------------------------- submit(validate=...)
def _racy_wf():
    """Two blind writers of one URI — a W010 warning, no errors."""
    wf = Workflow("racy")
    wf.var("x")
    wf.step("w1", lambda x: {"r": x}, inputs=("x",), outputs=("r",),
            jax_step=False)
    wf.step("w2", lambda x: {"r": x + 1}, inputs=("x",), outputs=("r",),
            jax_step=False)
    wf.step("read", lambda r: {"out": r}, inputs=("r",), outputs=("out",),
            jax_step=False)
    return wf


def _broken_wf():
    wf = Workflow("broken")
    wf.var("obs")
    wf.step("fit", lambda obs: {"chi": obs}, inputs=("obs",),
            outputs=("chi",), jax_step=False)
    return wf  # submitted with no init_vars -> W002 unbound-input


def test_submit_validate_error_rejects_and_names_rules():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        with pytest.raises(WorkflowRejected) as ei:
            rt.submit(_broken_wf(), {})
        assert "W002" in str(ei.value)
        assert any(f.rule == "W002" for f in ei.value.findings)
        # the rejected run must not leak into the scheduler
        h = rt.submit(_broken_wf(), {"obs": np.float64(1.0)})
        assert float(h.result()["chi"]) == 1.0
    finally:
        rt.close()


def test_submit_validate_warn_admits_and_attaches_findings():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        with pytest.warns(UserWarning, match="W002"):
            h = rt.submit(_broken_wf(), {}, validate="warn")
        assert any(f.rule == "W002" for f in h.findings)
        with pytest.raises(Exception):
            h.result()  # it was genuinely broken — the lint was right
    finally:
        rt.close()


def test_submit_validate_off_skips_analysis():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        h = rt.submit(_broken_wf(), {}, validate="off")
        assert h.findings == []
        with pytest.raises(Exception):
            h.result()
    finally:
        rt.close()


def test_submit_warnings_do_not_block():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        h = rt.submit(_racy_wf(), {"x": np.float64(1.0)})
        assert h.result()["out"] is not None
        assert any(f.rule == "W010" for f in h.findings)
    finally:
        rt.close()


def test_submit_validate_rejects_unknown_mode():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        with pytest.raises(ValueError, match="validate"):
            rt.submit(_racy_wf(), {"x": np.float64(1.0)}, validate="maybe")
    finally:
        rt.close()


def test_resident_uris_count_as_provided():
    """Warm resubmission into a namespace whose inputs are already
    resident must not trip W002."""
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        h1 = rt.submit(_broken_wf(), {"obs": np.float64(2.0)},
                       namespace="warm")
        assert float(h1.result()["chi"]) == 2.0
        h2 = rt.submit(_broken_wf(), {}, namespace="warm")
        assert float(h2.result()["chi"]) == 2.0
    finally:
        rt.close()


# ------------------------------------------------------- kinded edges
def test_dependencies_kinds():
    wf = Workflow("kinds")
    wf.var("x")
    wf.step("w1", lambda **kw: {}, inputs=("x",), outputs=("v",))
    wf.step("read", lambda **kw: {}, inputs=("v",), outputs=("out",))
    wf.step("w2", lambda **kw: {}, inputs=("x",), outputs=("v",))
    kd = wf.dependencies(kinds=True)
    assert kd["read"]["w1"] == frozenset({"RAW"})
    assert "WW" in kd["w2"]["w1"]
    assert "WAR" in kd["w2"]["read"]
    # legacy shape is the kinded graph with kinds erased
    plain = wf.dependencies()
    assert plain == {n: set(e) for n, e in kd.items()}


def test_duplicate_step_name_names_both_sites():
    wf = Workflow("dup")
    wf.step("s", lambda **kw: {}, outputs=("a",))
    with pytest.raises(WorkflowError) as ei:
        wf.step("s", lambda **kw: {}, outputs=("b",))
    msg = str(ei.value)
    assert "redefined at" in msg and "first defined at" in msg
    assert msg.count("test_analysis.py") == 2


def test_duplicate_variable_names_both_sites():
    wf = Workflow("dupvar")
    wf.var("x")
    with pytest.raises(WorkflowError, match="first declared at"):
        wf.var("x")


def test_duplicate_output_uri_rejected():
    wf = Workflow("dupout")
    with pytest.raises(WorkflowError, match="more than once"):
        wf.step("s", lambda **kw: {}, outputs=("a", "a"))


# ------------------------------------------------------ real-run replay
def test_real_run_replays_clean_through_sanitizer():
    rt = EmeraldRuntime(emerald(), max_workers=4, telemetry=False)
    try:
        wf = Workflow("clean-run")
        wf.var("x")
        wf.step("a", lambda x: {"u": x * 2}, inputs=("x",), outputs=("u",),
                remotable=True, jax_step=False)
        wf.step("b", lambda x: {"v": x + 1}, inputs=("x",), outputs=("v",),
                remotable=True, jax_step=False)
        wf.step("c", lambda u, v: {"out": u + v}, inputs=("u", "v"),
                outputs=("out",), jax_step=False)
        h = rt.submit(wf, {"x": np.float64(3.0)})
        assert float(h.result()["out"]) == 10.0
        assert sanitizer.check(h.events, completed_run=True) == []
        assert sanitizer.check_store(rt.mdss) == []
        assert sanitizer.check_runtime(rt, [h]) == []
    finally:
        rt.close()


def test_dispatch_events_emitted_per_step():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        h = rt.submit(_racy_wf(), {"x": np.float64(1.0)})
        h.result()
        dispatched = [e.step for e in h.events if e.kind == "dispatch"]
        assert sorted(dispatched) == ["read", "w1", "w2"]
        lanes = {e.info.get("lane") for e in h.events
                 if e.kind == "dispatch"}
        assert lanes <= {"local", "offload"}
    finally:
        rt.close()
