"""Locality-aware dispatch + namespace residency budgets.

Covers the data-locality scheduling signal and its bounds:

  * ``LocalityPolicy`` placement units on synthetic residency maps —
    warm-on-cloud inputs flip a compute-favoured-local step to the
    offload lane and vice versa, with tie-breaks and the annotate
    fallback,
  * runtime integration: ``policy="locality"`` dispatches by per-tier
    (exec + transfer) score and emits the chosen-tier rationale as a
    ``place`` event,
  * per-(namespace, tier) residency budgets: incremental resident-byte
    accounting, LRU eviction with write-back to local, background
    enforcement, eviction vs. fence epochs (an evicted-then-redropped
    entry refuses a stale write-back),
  * admission control at ``submit`` against the store capacity ceiling,
  * autoscaler churn pressure from the evicted-bytes counter.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (AdmissionRefused, CostModel, EmeraldRuntime,
                        LocalityPolicy, MDSS, MigrationManager, Workflow,
                        default_tiers, nbytes_of)
from repro.cloud.autoscaler import Autoscaler, AutoscalerConfig


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def one_step_wf(name="loc", inputs=("a",), remotable=True):
    wf = Workflow(name)
    for u in inputs:
        wf.var(u)
    s = wf.step("s", lambda **kw: {"y": np.float64(0.0)}, inputs=inputs,
                outputs=("y",), remotable=remotable, jax_step=False)
    return wf, s


# ------------------------------------------------------- placement units
def test_locality_prefers_tier_holding_the_data():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    pol = LocalityPolicy(cm, mdss, "cloud")
    _, s = one_step_wf()
    big = np.ones((1024, 512), np.float64)            # 4 MiB
    # raw compute favours local...
    cm.stats_for("s").measured_s.update(local=0.002, cloud=0.003)
    # ...but the input is warm on cloud only
    mdss.put("a", big, tier="cloud")
    d = pol.place(s)
    assert d.offload and d.tier == "cloud"
    assert d.scores["cloud"] < d.scores["local"]
    assert d.stale_bytes["local"] == big.nbytes
    assert d.stale_bytes["cloud"] == 0
    # once the data is staged home, compute-favoured local wins again
    mdss.ensure(["a"], "local")
    d2 = pol.place(s)
    assert not d2.offload and d2.tier == "local"
    assert d2.stale_bytes["local"] == 0


def test_locality_keeps_local_data_local_despite_faster_cloud():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    pol = LocalityPolicy(cm, mdss, "cloud")
    _, s = one_step_wf()
    # the cloud chip is faster, but not by enough to pay for staging
    cm.stats_for("s").measured_s.update(local=0.004, cloud=0.003)
    mdss.put("a", np.ones((2048, 512), np.float64), tier="local")  # 8 MiB
    d = pol.place(s)
    assert not d.offload, \
        "residency-blind choice: staged 8 MiB to chase a 1 ms exec win"


def test_locality_fallbacks_and_tie_breaks():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    pol = LocalityPolicy(cm, mdss, "cloud")
    # no data, no estimates -> the paper's annotate default (offload)
    _, s = one_step_wf()
    d = pol.place(s)
    assert d.offload and "annotate" in d.reason
    # non-remotable is never offloaded, whatever the residency map says
    _, s2 = one_step_wf("loc2", remotable=False)
    mdss.put("a", np.ones(1024), tier="cloud")
    d2 = pol.place(s2)
    assert not d2.offload and d2.reason == "not remotable"
    # warm-on-cloud data with no exec estimates: the transfer component
    # alone decides
    d3 = pol.place(s)
    assert d3.offload and d3.reason == "exec+transfer score"
    # equal modeled seconds but unequal residency (a cost model that
    # charges nothing for the wire): resident bytes break the tie
    class _FreeWire(CostModel):
        def transfer_time(self, nbytes, src, dst):
            return 0.0

    pol2 = LocalityPolicy(_FreeWire(tiers), mdss, "cloud")
    d4 = pol2.place(s)
    assert d4.offload and d4.reason == "resident-bytes tie-break"


def test_runtime_locality_dispatch_emits_rationale():
    mgr = emerald()
    cm = mgr.cost_model
    big = np.ones((1024, 512), np.float64)            # 4 MiB
    with EmeraldRuntime(mgr, policy="locality", max_workers=2) as rt:
        rt.publish("C", big, tier="cloud")            # cloud-resident only
        cm.stats_for("use").measured_s.update(local=0.002, cloud=0.003)
        wf = Workflow("warmloc")
        wf.var("C")
        wf.step("use", lambda C: {"out": np.float64(C.sum())},
                inputs=("C",), outputs=("out",), remotable=True,
                jax_step=False)
        h = rt.submit(wf, {})
        out = h.result(30)
        assert float(out["out"]) == big.sum()
        places = [e for e in h.events if e.kind == "place"]
        assert places and places[0].tier == "cloud"
        assert places[0].info["scores"]["cloud"] \
            < places[0].info["scores"]["local"]
        assert places[0].info["stale_bytes"]["cloud"] == 0
        # the step really took the offload lane, and staged nothing
        off = [e for e in h.events if e.kind == "offload"]
        assert off and off[0].info["code_only"] is True
        dones = [e for e in h.events if e.kind == "step_done"]
        assert len(dones) == 1 and dones[0].info["offloaded"] is True


# --------------------------------------------------- budgets and eviction
def test_budget_eviction_is_lru_with_writeback():
    tiers = default_tiers()
    base = MDSS(tiers, cost_model=CostModel(tiers))
    arr = np.ones(1024, np.float64)                   # 8 KiB each
    for name in ("a", "b", "c", "d"):
        base.put(f"job/{name}", arr, tier="cloud")
    assert base.namespace_tier_bytes("job", "cloud") == 4 * arr.nbytes
    base.get("job/a", "cloud")                        # refresh a's LRU slot
    budget = int(2.5 * arr.nbytes)
    base._budgets[("job", "cloud")] = budget          # no auto-kick: direct
    evicted_n, evicted_b = base.enforce_budget("job", "cloud")
    assert evicted_n == 2 and evicted_b == 2 * arr.nbytes
    assert base.namespace_tier_bytes("job", "cloud") <= budget
    # LRU: the two oldest-untouched entries (b, c) went; a survived its
    # refresh and d is the most recent write
    assert base.has_latest("job/a", "cloud")
    assert base.has_latest("job/d", "cloud")
    # write-back: evicted entries stay fully readable from local
    for name in ("b", "c"):
        assert base.has_latest(f"job/{name}", "local")
        np.testing.assert_array_equal(base.get(f"job/{name}", "local"), arr)
    assert base.evictions == 2 and base.eviction_bytes == 2 * arr.nbytes
    assert len(base.eviction_events) == 2
    # counters stayed consistent with a full scan
    assert base.namespace_resident_bytes("job") == sum(
        nbytes_of(v) for u in base.namespace_entries("job")
        for _, v in base._entries[u].copies.values())


def test_over_budget_put_triggers_background_eviction():
    tiers = default_tiers()
    base = MDSS(tiers, cost_model=CostModel(tiers))
    arr = np.ones(1024, np.float64)
    base.set_namespace_budget("job", "cloud", 2 * arr.nbytes)
    for i in range(6):
        base.put(f"job/x{i}", arr, tier="cloud")
    deadline = time.monotonic() + 5
    while base.namespace_tier_bytes("job", "cloud") > 2 * arr.nbytes \
            and time.monotonic() < deadline:
        time.sleep(0.01)
    assert base.namespace_tier_bytes("job", "cloud") <= 2 * arr.nbytes, \
        "background eviction never brought the namespace under budget"
    # nothing was lost: every entry still has a latest replica somewhere
    for i in range(6):
        val, ver = base.peek_latest(f"job/x{i}")
        assert ver == 1 and val is not None


def test_eviction_respects_fence_epochs_on_redrop():
    """An evicted-then-redropped namespace entry must refuse a stale
    write-back: eviction's write-back is replica movement (no version
    bump, no entry creation), and a draining step's fenced publish still
    carries the pre-drop epoch."""
    tiers = default_tiers()
    base = MDSS(tiers, cost_model=CostModel(tiers))
    view = base.namespaced("job", shared="shared")
    arr = np.ones(2048, np.float64)
    view.put("u", arr, tier="cloud")
    # an in-flight step snapshots its fence before eviction/drop
    tokens = view.fence_tokens(["u"])
    base._budgets[("job", "cloud")] = 0
    base.enforce_budget("job", "cloud")               # evict: cloud -> local
    assert not base.has_latest("job/u", "cloud")
    assert base.has_latest("job/u", "local")          # write-back landed
    assert base.version("job/u") == 1, "eviction bumped a version"
    base.drop_namespace("job")                        # run released
    # the straggler's write-back: stale epoch, must be refused
    assert view.put_many({"u": np.zeros(8)}, tier="local",
                         expect_versions=tokens) is None
    assert base.namespace_entries("job") == [], \
        "stale write-back resurrected an evicted-then-dropped namespace"
    # budgets died with the namespace
    assert base.namespace_budget("job", "cloud") is None
    # eviction on the dropped namespace is a clean no-op
    base._budgets[("job", "cloud")] = 0
    assert base.enforce_budget("job", "cloud") == (0, 0)


def test_submit_residency_budget_bounds_run_namespace():
    mgr = emerald()
    mdss = mgr.mdss
    chunk = np.ones((512, 256), np.float64)           # 1 MiB outputs
    wf = Workflow("hot")
    wf.var("x")
    for i in range(6):
        wf.step(f"w{i}", (lambda i=i: lambda x: {f"b{i}": chunk + i})(),
                inputs=("x",), outputs=(f"b{i}",), remotable=True,
                jax_step=False)
    budget = 2 * chunk.nbytes
    with EmeraldRuntime(mgr, max_workers=2) as rt:
        h = rt.submit(wf, {"x": np.float64(0.0)},
                      residency_budget={"cloud": budget})
        assert mdss.namespace_budget(h.namespace, "cloud") == budget
        out = h.result(60)
        assert len([k for k in out if k.startswith("b")]) == 6
        deadline = time.monotonic() + 5
        while mdss.namespace_tier_bytes(h.namespace, "cloud") > budget \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        assert mdss.namespace_tier_bytes(h.namespace, "cloud") <= budget
        assert mdss.evictions > 0
        # un-namespaced submissions cannot carry a budget
        with pytest.raises(ValueError, match="namespaced"):
            rt.submit(wf, {}, namespace="", residency_budget={"cloud": 1})
        # local is the write-back tier: a budget there would silently
        # never evict, so it is rejected up front
        with pytest.raises(ValueError, match="write-back"):
            rt.submit(wf, {}, residency_budget={"local": 1})


def test_admission_control_refuses_near_capacity():
    mgr = emerald()
    mgr.mdss.capacity_bytes = 1_000_000
    wf, _ = one_step_wf("adm", inputs=("x",))
    with EmeraldRuntime(mgr) as rt:
        rt.publish("blob", np.ones(150_000, np.float64))   # 1.2 MB resident
        assert mgr.mdss.over_capacity(rt.admission_headroom)
        with pytest.raises(AdmissionRefused, match="capacity"):
            rt.submit(wf, {"x": np.float64(1.0)})
        # freeing residency re-opens the front door
        mgr.mdss.drop_namespace(rt.shared_namespace)
        h = rt.submit(wf, {"x": np.float64(1.0)})
        h.result(30)


# ------------------------------------------------------- autoscaler churn
class _StubBroker:
    def __init__(self):
        self.workers = 1

    def queue_depth(self):
        return 0

    def num_workers(self, include_warm=False):
        return self.workers

    def inflight(self):
        return 0

    def avg_task_seconds(self):
        return None

    def add_worker(self):
        self.workers += 1

    def retire_worker(self):
        self.workers -= 1
        return "w"

    def reap_warm(self, ttl):
        return 0


def test_autoscaler_churn_pressure_scales_up_and_blocks_retire():
    churn = {"total": 0}
    cfg = AutoscalerConfig(min_workers=1, max_workers=4, queue_high=100.0,
                           idle_scale_down_s=0.0,
                           churn_high_bytes_per_s=1e6)
    broker = _StubBroker()
    sc = Autoscaler(broker, cfg, churn_fn=lambda: churn["total"])
    sc.tick(now=0.0)                         # first tick only marks
    act = sc.tick(now=1.0)
    assert act["added"] == 0                 # no churn, no growth
    churn["total"] = 64_000_000              # 64 MB evicted in 1 s: thrash
    act = sc.tick(now=2.0)
    assert act["added"] == 1 and broker.workers == 2, \
        "eviction churn did not grow the pool"
    # nonzero (sub-threshold) churn still blocks the idle retire path
    churn["total"] += 1000
    act = sc.tick(now=3.0)
    assert act["retired"] == 0 and broker.workers == 2
    # churn gone: idle scale-down resumes
    act = sc.tick(now=10.0)
    act = sc.tick(now=20.0)
    assert broker.workers == 1
