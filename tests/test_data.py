"""Data-pipeline tests: determinism, shapes, modality stubs."""
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeProfile, reduced
from repro.data.pipeline import (SyntheticLMData, batch_logical_axes,
                                 make_batch_specs, token_batch_shapes)


def test_deterministic_per_step():
    cfg = reduced(get_config("tinyllama-1.1b"))
    sp = ShapeProfile("t", 32, 4, "train")
    d1 = SyntheticLMData(cfg, sp, seed=3)
    d2 = SyntheticLMData(cfg, sp, seed=3)
    b1, b2 = d1.batch(17), d2.batch(17)
    for k in b1:
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))
    b3 = d1.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_tokens_in_vocab():
    cfg = reduced(get_config("tinyllama-1.1b"))
    sp = ShapeProfile("t", 64, 2, "train")
    b = SyntheticLMData(cfg, sp).batch(0)
    toks = np.asarray(b["tokens"])
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size


def test_vlm_batch_has_frontend_stub():
    cfg = reduced(get_config("internvl2-1b"))
    sp = ShapeProfile("t", 32, 2, "train")
    shapes = token_batch_shapes(cfg, sp)
    assert shapes["frontend_embeds"] == (2, cfg.frontend_tokens, cfg.d_model)
    assert shapes["tokens"] == (2, 32 - cfg.frontend_tokens)
    b = SyntheticLMData(cfg, sp).batch(0)
    assert b["frontend_embeds"].shape == shapes["frontend_embeds"]


def test_encdec_batch_has_encoder_stub():
    cfg = reduced(get_config("seamless-m4t-medium"))
    sp = ShapeProfile("t", 32, 2, "train")
    shapes = token_batch_shapes(cfg, sp)
    assert shapes["encoder_embeds"] == (2, 32, cfg.d_model)
    assert shapes["tokens"] == (2, 32)


def test_specs_match_real_batches():
    for arch in ("tinyllama-1.1b", "internvl2-1b", "seamless-m4t-medium"):
        cfg = reduced(get_config(arch))
        sp = ShapeProfile("t", 32, 2, "train")
        specs = make_batch_specs(cfg, sp)
        batch = SyntheticLMData(cfg, sp).batch(0)
        assert set(specs) == set(batch)
        for k in specs:
            assert specs[k].shape == batch[k].shape, (arch, k)
            assert specs[k].dtype == batch[k].dtype, (arch, k)
        axes = batch_logical_axes(cfg, sp)
        for k in axes:
            assert len(axes[k]) == len(specs[k].shape)
