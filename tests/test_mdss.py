"""MDSS tests: versioning, lazy sync, last-writer-wins, byte accounting —
plus hypothesis property tests against a shadow model."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CostModel, MDSS, default_tiers

TIERS = ("local", "cloud", "cloud2")


def make_mdss():
    tiers = default_tiers()
    return MDSS(tiers, cost_model=CostModel(tiers))


def test_put_get_roundtrip():
    m = make_mdss()
    m.put("a", np.arange(4), tier="local")
    assert np.array_equal(m.get("a", "local"), np.arange(4))


def test_get_syncs_from_freshest_tier():
    m = make_mdss()
    m.put("a", np.arange(4), tier="local")
    got = m.get("a", "cloud")
    assert np.array_equal(got, np.arange(4))
    assert m.has_latest("a", "cloud")
    assert m.total_bytes_moved() == np.arange(4).nbytes


def test_code_only_fast_path_no_bytes():
    m = make_mdss()
    m.put("a", np.arange(4), tier="local")
    m.ensure(["a"], "cloud")
    before = m.total_bytes_moved()
    m.ensure(["a"], "cloud")      # already latest -> nothing moves
    assert m.total_bytes_moved() == before


def test_stale_after_new_version():
    m = make_mdss()
    m.put("a", np.arange(4), tier="local")
    m.ensure(["a"], "cloud")
    m.put("a", np.arange(8), tier="local")       # new version locally
    assert not m.has_latest("a", "cloud")
    assert m.stale_bytes(["a"], "cloud") == np.arange(8).nbytes
    assert np.array_equal(m.get("a", "cloud"), np.arange(8))


def test_last_writer_wins_synchronize():
    m = make_mdss()
    m.put("a", np.zeros(2), tier="local")
    m.put("a", np.ones(2), tier="cloud")          # later write on cloud wins
    m.synchronize("a")
    assert np.array_equal(m.get("a", "local"), np.ones(2))
    assert np.array_equal(m.get("a", "cloud"), np.ones(2))


def test_version_monotonic():
    m = make_mdss()
    vs = [m.put("a", np.zeros(1), tier=t) for t in ("local", "cloud", "local")]
    assert vs == sorted(vs) and len(set(vs)) == 3


def test_pytree_values():
    m = make_mdss()
    tree = {"w": np.ones((2, 2)), "b": np.zeros(2)}
    m.put("params", tree, tier="local")
    got = m.get("params", "cloud")
    assert np.array_equal(got["w"], tree["w"])
    assert m.total_bytes_moved() == 4 * 8 + 2 * 8


def test_modeled_seconds_accumulate():
    m = make_mdss()
    m.put("a", np.zeros(1024), tier="local")
    m.get("a", "cloud")
    assert m.modeled_seconds > 0


# ---------------------------------------------------------------------------
# Property-based: arbitrary op sequences vs a shadow model.
# ---------------------------------------------------------------------------

ops = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.sampled_from(["u1", "u2"]),
                  st.sampled_from(TIERS), st.integers(0, 100)),
        st.tuples(st.just("get"), st.sampled_from(["u1", "u2"]),
                  st.sampled_from(TIERS)),
        st.tuples(st.just("sync"), st.sampled_from(["u1", "u2"])),
    ),
    min_size=1, max_size=30)


@settings(max_examples=200, deadline=None)
@given(ops)
def test_mdss_matches_shadow(op_seq):
    m = make_mdss()
    shadow = {}                      # uri -> latest payload
    seeded = set()
    for op in op_seq:
        if op[0] == "put":
            _, uri, tier, val = op
            m.put(uri, np.full(3, val), tier=tier)
            shadow[uri] = val
            seeded.add(uri)
        elif op[0] == "get":
            _, uri, tier = op
            if uri not in seeded:
                with pytest.raises(KeyError):
                    m.get(uri, tier)
            else:
                got = m.get(uri, tier)
                assert np.array_equal(got, np.full(3, shadow[uri]))
                assert m.has_latest(uri, tier)
        else:
            _, uri = op
            if uri in seeded:
                m.synchronize(uri)
    # final: synchronize converges every replica to the latest version
    m.synchronize()
    for uri in seeded:
        for t in TIERS:
            if m._entries[uri].copies.get(t) is not None:
                assert np.array_equal(m.get(uri, t), np.full(3, shadow[uri]))


@settings(max_examples=100, deadline=None)
@given(ops)
def test_mdss_bytes_never_negative_and_code_only_stable(op_seq):
    m = make_mdss()
    seeded = set()
    for op in op_seq:
        if op[0] == "put":
            _, uri, tier, val = op
            m.put(uri, np.full(3, val), tier=tier)
            seeded.add(uri)
        elif op[0] == "get" and op[1] in seeded:
            m.get(op[1], op[2])
    assert all(v >= 0 for v in m.bytes_moved.values())
    # ensure() twice in a row never moves bytes the second time
    for uri in seeded:
        m.ensure([uri], "cloud")
        before = m.total_bytes_moved()
        m.ensure([uri], "cloud")
        assert m.total_bytes_moved() == before
