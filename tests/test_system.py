"""End-to-end behaviour tests for the paper's system (Emerald).

These exercise the full pipeline the paper describes: annotated workflow ->
partitioner -> migration manager + MDSS -> distributed execution — plus the
LM substrate driven through it (train + serve).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeProfile, reduced
from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        Workflow, default_tiers, partition)
from repro.launch.serve import Request, Server
from repro.launch.train import Trainer

# compile-heavy: excluded from the smoke fast lane (-m "not slow"),
# still part of tier-1 (plain pytest runs everything)
pytestmark = pytest.mark.slow


def test_lm_training_through_emerald_learns(tmp_path):
    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    run = RunConfig(model=cfg, shape=ShapeProfile("t", 64, 4, "train"),
                    remat="none", learning_rate=3e-3)
    tr = Trainer(run)
    hist = tr.fit(40, log_every=0)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3
    rep = tr.transfer_report()
    assert rep["offloads"] == 40
    # params uploaded once; per-step traffic is just the batch
    up = rep["bytes_moved"][("local", "cloud")]
    n_params_bytes = sum(x.nbytes for x in jax.tree.leaves(
        tr.model.init_params(jax.random.PRNGKey(0))))
    batch_bytes = sum(np.asarray(v).nbytes for v in tr.data.batch(0).values())
    overhead = up - (2 * n_params_bytes + 40 * batch_bytes)
    assert overhead < n_params_bytes + 65536, "params re-uploaded every step?"


def test_train_offload_matches_local_exactly():
    """Offloaded training == local training, step for step."""
    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    run = RunConfig(model=cfg, shape=ShapeProfile("t", 32, 2, "train"),
                    remat="none")
    h_cloud = Trainer(run, policy="annotate").fit(5, log_every=0)
    h_local = Trainer(run, policy="never").fit(5, log_every=0)
    for a, b in zip(h_cloud, h_local):
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-6)


def test_serving_through_emerald():
    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    run = RunConfig(model=cfg, shape=ShapeProfile("s", 64, 4, "decode"),
                    remat="none")
    from repro.models.model_zoo import Model
    params = Model(run).init_params(jax.random.PRNGKey(0))
    srv = Server(run, params)
    rng = np.random.default_rng(0)
    for rid in range(4):
        srv.submit(Request(rid, rng.integers(0, cfg.vocab_size, 10,
                                             ).astype(np.int32), max_new=6))
    done = srv.step_batch()
    assert len(done) == 4
    assert all(len(r.tokens) == 6 for r in done)
    rep = srv.transfer_report()
    assert rep["decode_offloads"] >= 5
    # decode steps move only tokens, never params/caches
    assert rep["bytes_moved"].get(("cloud", "local"), 0) < 1e6


def test_multi_step_dag_workflow_through_emerald():
    """Diamond DAG with parallel remotable branches (paper Fig 9b)."""
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    wf = Workflow("diamond")
    wf.var("x")
    wf.step("src", lambda x: {"a": x + 1}, inputs=("x",), outputs=("a",))
    wf.step("l", lambda a: {"b": a * 2}, inputs=("a",), outputs=("b",),
            remotable=True)
    wf.step("r", lambda a: {"c": a * 3}, inputs=("a",), outputs=("c",),
            remotable=True)
    wf.step("sink", lambda b, c: {"y": b + c}, inputs=("b", "c"),
            outputs=("y",))
    ex = EmeraldExecutor(partition(wf), mgr)
    out = ex.run({"x": jnp.float32(1.0)})
    assert float(out["y"]) == 2 * 2 + 2 * 3
    # both branches offloaded; 'a' moved to the cloud exactly once
    a_moves = [e for e in mdss.sync_events if e[0] == "a" and e[2] == "cloud"]
    assert len(a_moves) == 1, "MDSS failed to share the cloud replica"
