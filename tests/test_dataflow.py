"""Event-driven dataflow runtime: completion-triggered scheduling,
transfer/compute overlap, and the concurrency-bug regression sweep.

Each regression test here pins a bug the wave-barrier executor (or its
helpers) had:

  * write-after-read edges missing from ``Workflow.dependencies()``,
  * speculation resolving to the first *finisher* instead of the first
    *successful* finisher,
  * a speculation loser's late write-back clobbering newer MDSS versions
    and polluting the runtime EMA,
  * one failed offload abandoning (and un-checkpointing) the completed
    siblings of its wave.
"""
import pickle
import threading
import time

import numpy as np
import pytest

from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        StepFailure, Workflow, WorkflowFailure,
                        critical_path_lengths, default_tiers, nbytes_of,
                        partition)


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


class Trace:
    """Thread-safe (name, phase, t) recorder shared by step fns."""

    def __init__(self):
        self._lock = threading.Lock()
        self.rows = []

    def mark(self, name, phase):
        with self._lock:
            self.rows.append((name, phase, time.perf_counter()))

    def at(self, name, phase):
        return next(t for n, p, t in self.rows if n == name and p == phase)

    def sleeper(self, name, seconds, out):
        def fn(**kw):
            self.mark(name, "start")
            time.sleep(seconds)
            self.mark(name, "end")
            return {out: np.float64(seconds)}
        return fn


# ---------------------------------------------------------------- WAR edges
def test_dependencies_include_write_after_read():
    wf = Workflow("war")
    wf.var("v")
    wf.step("w1", lambda: {"v": np.float64(1)}, outputs=("v",),
            jax_step=False)
    wf.step("r", lambda v: {"out": v}, inputs=("v",), outputs=("out",),
            jax_step=False)
    wf.step("w2", lambda: {"v": np.float64(2)}, outputs=("v",),
            jax_step=False)
    deps = wf.dependencies()
    assert "w1" in deps["r"]          # read-after-write
    assert "w1" in deps["w2"]         # write-after-write
    assert "r" in deps["w2"]          # write-after-read (the regression)
    # a step rewriting its own input must not depend on itself
    wf2 = Workflow("self")
    wf2.var("v")
    wf2.step("w", lambda: {"v": np.float64(1)}, outputs=("v",),
             jax_step=False)
    wf2.step("inc", lambda v: {"v": v + 1}, inputs=("v",), outputs=("v",),
             jax_step=False)
    assert wf2.dependencies()["inc"] == {"w"}


def test_war_edge_serialises_reader_and_rewriter():
    """A slow reader of ``v`` must finish before the next writer of ``v``
    starts, or the writer clobbers the reader's input mid-flight."""
    tr = Trace()
    wf = Workflow("war_rt")
    wf.var("x")

    def slow_read(x):
        tr.mark("r", "start")
        time.sleep(0.2)
        tr.mark("r", "end")
        return {"out": np.float64(x)}

    def rewrite(**kw):
        tr.mark("w2", "start")
        return {"x": np.float64(99.0)}

    wf.step("r", slow_read, inputs=("x",), outputs=("out",),
            remotable=True, jax_step=False)
    wf.step("w2", rewrite, outputs=("x",), remotable=True, jax_step=False)
    out = EmeraldExecutor(partition(wf), emerald()).run(
        {"x": np.float64(7.0)})
    assert float(out["out"]) == 7.0, "rewriter clobbered the reader's input"
    assert tr.at("w2", "start") >= tr.at("r", "end")


def test_successors_and_in_degrees_views():
    wf = Workflow("views")
    wf.var("x")
    wf.step("a", lambda x: {"y": x}, inputs=("x",), outputs=("y",))
    wf.step("b", lambda y: {"z": y}, inputs=("y",), outputs=("z",))
    wf.step("c", lambda y: {"w": y}, inputs=("y",), outputs=("w",))
    assert wf.successors()["a"] == {"b", "c"}
    assert wf.in_degrees() == {"a": 0, "b": 1, "c": 1}
    assert wf.in_degrees(completed={"a"}) == {"b": 0, "c": 0}


# ----------------------------------------------- completion-triggered overlap
def test_fast_branch_successor_overlaps_slow_branch():
    """Diamond: the fast source's successor must START while the slow
    source is still RUNNING — impossible under a wave barrier."""
    tr = Trace()
    wf = Workflow("diamond")
    wf.var("x")
    wf.step("fast", tr.sleeper("fast", 0.05, "y_fast"), inputs=("x",),
            outputs=("y_fast",), remotable=True, jax_step=False)
    wf.step("slow", tr.sleeper("slow", 0.45, "y_slow"), inputs=("x",),
            outputs=("y_slow",), remotable=True, jax_step=False)
    wf.step("mid", tr.sleeper("mid", 0.1, "y_mid"), inputs=("y_fast",),
            outputs=("y_mid",), remotable=True, jax_step=False)
    wf.step("join", tr.sleeper("join", 0.01, "y_join"),
            inputs=("y_mid", "y_slow"), outputs=("y_join",), remotable=True,
            jax_step=False)
    ex = EmeraldExecutor(partition(wf), emerald())
    t0 = time.perf_counter()
    ex.run({"x": np.float64(0.0)})
    dt = time.perf_counter() - t0
    assert tr.at("mid", "start") < tr.at("slow", "end"), \
        "mid waited for the slow sibling (wave barrier behaviour)"
    assert tr.at("join", "start") >= tr.at("mid", "end")
    assert dt < 0.45 + 0.1 + 0.2, f"no transfer of control overlap: {dt}"
    # Property 3 survives: strict per-step suspend -> offload -> resume
    for name in ("fast", "slow", "mid", "join"):
        kinds = [e.kind for e in ex.events
                 if e.step == name and e.kind in ("suspend", "offload",
                                                  "resume")]
        assert kinds == ["suspend", "offload", "resume"], (name, kinds)


def test_local_lane_does_not_block_offload_harvest():
    """A long LOCAL step must not stall completion-triggered dispatch of
    offloaded work (the old executor ran locals in the driver thread)."""
    tr = Trace()
    wf = Workflow("lane")
    wf.var("x")
    wf.step("llocal", tr.sleeper("llocal", 0.4, "y_l"), inputs=("x",),
            outputs=("y_l",), jax_step=False)               # local lane
    wf.step("off", tr.sleeper("off", 0.05, "y_o"), inputs=("x",),
            outputs=("y_o",), remotable=True, jax_step=False)
    wf.step("off2", tr.sleeper("off2", 0.05, "y_o2"), inputs=("y_o",),
            outputs=("y_o2",), remotable=True, jax_step=False)
    ex = EmeraldExecutor(partition(wf), emerald())
    ex.run({"x": np.float64(0.0)})
    assert tr.at("off2", "start") < tr.at("llocal", "end"), \
        "offload successor stalled behind an unrelated local step"


# ------------------------------------------------------------ dispatch order
def test_critical_path_lengths_and_priority_dispatch():
    wf = Workflow("prio")
    wf.var("x")
    # short job declared FIRST; long chain declared after
    wf.step("d", lambda x: {"yd": x}, inputs=("x",), outputs=("yd",),
            remotable=True, jax_step=False)
    wf.step("a", lambda x: {"ya": x}, inputs=("x",), outputs=("ya",),
            remotable=True, jax_step=False)
    wf.step("b", lambda ya: {"yb": ya}, inputs=("ya",), outputs=("yb",),
            remotable=True, jax_step=False)
    wf.step("c", lambda yb: {"yc": yb}, inputs=("yb",), outputs=("yc",),
            remotable=True, jax_step=False)
    cpl = critical_path_lengths(wf)
    assert cpl["a"] == 3.0 and cpl["b"] == 2.0 and cpl["c"] == 1.0
    assert cpl["d"] == 1.0
    order = []
    lock = threading.Lock()

    def tracer(name):
        orig = wf.steps[name].fn

        def fn(**kw):
            with lock:
                order.append(name)
            return orig(**kw)
        return fn

    for name in wf.steps:
        wf.steps[name].fn = tracer(name)
    # one worker => execution order == dispatch order; the chain head (long
    # pole, cpl=3) must beat the earlier-declared short job (cpl=1)
    ex = EmeraldExecutor(partition(wf), emerald(), max_workers=1)
    ex.run({"x": np.float64(0.0)})
    assert order.index("a") < order.index("d"), order


# ----------------------------------------------------------- prefetch overlap
def test_prefetch_overlaps_transfer_with_compute():
    """Dispatching a step warms its successor's already-available inputs
    on the cloud tier, so the successor's own staging is (near) code-only."""
    mgr = emerald()
    mdss = mgr.mdss
    big = np.ones((64, 1024), np.float64)          # 512 KiB constant
    wf = Workflow("pf")
    wf.var("x")
    wf.var("C")

    def src(x):
        time.sleep(0.2)                            # prefetch runs under this
        return {"y": np.float64(1.0)}

    wf.step("src", src, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False)
    wf.step("reduce", lambda y, C: {"out": np.float64(float(y) + C.sum())},
            inputs=("y", "C"), outputs=("out",), remotable=True,
            jax_step=False)
    ex = EmeraldExecutor(partition(wf), mgr)
    ex.run({"x": np.float64(0.0), "C": big})
    assert mdss.prefetch_ops >= 1
    assert mdss.prefetch_bytes >= nbytes_of(big)
    pf = [e for e in ex.events if e.kind == "prefetch"]
    assert pf and pf[0].step == "reduce" and "C" in pf[0].info["uris"]
    red = next(e for e in ex.events
               if e.kind == "offload" and e.step == "reduce")
    # C moved during src's sleep -> reduce staged only y's 8 bytes
    assert red.info["bytes_in"] < nbytes_of(big)


def test_prefetch_off_switch():
    mgr = emerald()
    wf = Workflow("pf_off")
    wf.var("x")
    wf.step("a", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
            remotable=True, jax_step=False)
    wf.step("b", lambda y: {"z": y}, inputs=("y",), outputs=("z",),
            remotable=True, jax_step=False)
    ex = EmeraldExecutor(partition(wf), mgr, prefetch=False)
    ex.run({"x": np.float64(0.0)})
    assert mgr.mdss.prefetch_ops == 0
    assert all(e.kind != "prefetch" for e in ex.events)


# ------------------------------------------------------- speculation winner
def test_speculation_backup_wins_after_primary_fails():
    """Primary fails fast AFTER the backup launches; the step must resolve
    to the backup's later success, not raise with the primary's error."""
    calls = {"n": 0}
    lock = threading.Lock()

    def fn(x):
        with lock:
            calls["n"] += 1
            n = calls["n"]
        if n == 1:                    # seed run: fast success, feeds EMA
            return {"y": np.float64(x)}
        if n == 2:                    # primary: dies after backup launch
            time.sleep(0.2)
            raise StepFailure("injected: primary node lost")
        time.sleep(0.5)               # backup: slower but SUCCEEDS
        return {"y": np.float64(x) + 1}

    wf = Workflow("specwin")
    wf.var("x")
    wf.step("s", fn, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False)
    ex = EmeraldExecutor(partition(wf), emerald(), speculate_after=2.0)
    ex.run({"x": np.float64(0.0)})               # seed the runtime EMA
    ex.events.clear()
    out = ex.run({"x": np.float64(41.0)})
    assert float(out["y"]) == 42.0, "backup's success was discarded"
    assert any(e.kind == "speculate" for e in ex.events)
    assert all(e.kind != "retry" for e in ex.events), \
        "primary's failure beat the backup's success"
    assert calls["n"] == 3


def test_speculation_raises_only_when_both_twins_fail():
    calls = {"n": 0}
    lock = threading.Lock()

    def fn(x):
        with lock:
            calls["n"] += 1
            n = calls["n"]
        if n == 1:
            return {"y": np.float64(x)}
        time.sleep(0.15)
        raise StepFailure(f"injected: twin {n} died")

    wf = Workflow("specfail")
    wf.var("x")
    wf.step("s", fn, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False, retries=1)
    ex = EmeraldExecutor(partition(wf), emerald(), speculate_after=0.1)
    ex.run({"x": np.float64(0.0)})
    ex.events.clear()
    with pytest.raises(WorkflowFailure):
        ex.run({"x": np.float64(1.0)})
    assert any(e.kind == "retry" for e in ex.events)


# --------------------------------------------------- straggler write-back
def test_loser_write_back_is_version_fenced():
    """A speculation loser finishing late must not overwrite a newer MDSS
    version nor feed its straggler wall time into the runtime EMA."""
    mgr = emerald()
    mdss = mgr.mdss
    wf = Workflow("fence")
    wf.var("x")

    def slow(x):
        time.sleep(0.3)
        return {"y": np.float64(1.0)}

    s = wf.step("s", slow, inputs=("x",), outputs=("y",), remotable=True,
                jax_step=False)
    mdss.put("x", np.float64(0.0), tier="local")
    loser = {}
    th = threading.Thread(
        target=lambda: loser.setdefault("rep", mgr.execute(s, "cloud")))
    th.start()
    time.sleep(0.05)
    # the winner (or a downstream step) publishes a newer version of y
    # while the loser is still executing
    mdss.put("y", np.float64(7.0), tier="local")
    th.join()
    assert loser["rep"].fenced is True
    assert mdss.fenced_puts == 1
    assert float(mdss.get("y", "local")) == 7.0, \
        "stale loser clobbered the newer version"
    assert "cloud" not in mgr.cost_model.stats_for("s").measured_s, \
        "fenced straggler polluted the runtime EMA"


def test_normal_write_back_unfenced():
    mgr = emerald()
    wf = Workflow("unfenced")
    wf.var("x")
    s = wf.step("s", lambda x: {"y": np.float64(2.0)}, inputs=("x",),
                outputs=("y",), remotable=True, jax_step=False)
    mgr.mdss.put("x", np.float64(0.0), tier="local")
    rep = mgr.execute(s, "cloud")
    assert rep.fenced is False
    assert "cloud" in mgr.cost_model.stats_for("s").measured_s


# ------------------------------------------------- partial-progress survival
def test_failed_sibling_keeps_survivors_in_checkpoint(tmp_path):
    """Crash one of three parallel offloads: the two survivors must land
    in ``completed`` AND in the checkpoint, and resume must re-run only
    the crashed step (the wave executor lost the whole wave)."""
    state = {"crash": True}
    ran = []
    lock = threading.Lock()

    def make(name, seconds, crash=False):
        def fn(x):
            with lock:
                ran.append(name)
            if crash and state["crash"]:
                raise StepFailure("injected: node power loss")
            time.sleep(seconds)
            return {f"y_{name}": np.float64(seconds)}
        return fn

    def build():
        wf = Workflow("partial")
        wf.var("x")
        wf.step("boom", make("boom", 0.0, crash=True), inputs=("x",),
                outputs=("y_boom",), remotable=True, jax_step=False,
                retries=0)
        wf.step("ok1", make("ok1", 0.25), inputs=("x",), outputs=("y_ok1",),
                remotable=True, jax_step=False)
        wf.step("ok2", make("ok2", 0.25), inputs=("x",), outputs=("y_ok2",),
                remotable=True, jax_step=False)
        return wf

    ex = EmeraldExecutor(partition(build()), emerald(),
                         checkpoint_dir=str(tmp_path))
    with pytest.raises(WorkflowFailure):
        ex.run({"x": np.float64(0.0)})
    with open(tmp_path / "partial.wfckpt", "rb") as f:
        ckpt = pickle.load(f)
    assert set(ckpt["completed"]) == {"ok1", "ok2"}, \
        "survivors of the failed wave were not checkpointed"
    assert {"y_ok1", "y_ok2"} <= set(ckpt["vars"])
    # resume: only the crashed step re-runs
    state["crash"] = False
    ran.clear()
    ex2 = EmeraldExecutor(partition(build()), emerald(),
                          checkpoint_dir=str(tmp_path))
    out = ex2.run({"x": np.float64(0.0)}, resume=True)
    assert ran == ["boom"], f"resume re-ran finished work: {ran}"
    assert {"y_boom", "y_ok1", "y_ok2"} <= set(out)


def test_checkpoints_are_incremental_per_completion(tmp_path):
    wf = Workflow("incr")
    wf.var("x")
    wf.step("a", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
            remotable=True, jax_step=False)
    wf.step("b", lambda y: {"z": y}, inputs=("y",), outputs=("z",),
            remotable=True, jax_step=False)
    ex = EmeraldExecutor(partition(wf), emerald(),
                         checkpoint_dir=str(tmp_path))
    ex.run({"x": np.float64(3.0)})
    ckpts = [e for e in ex.events if e.kind == "checkpoint"]
    assert [c.info["n"] for c in ckpts] == [1, 2], \
        "checkpointing is not per-completion"


def test_checkpoint_never_contains_inflight_outputs(tmp_path):
    """Invariant: a checkpoint may only hold init/resume vars and outputs
    of steps its own ``completed`` set records — never the published
    outputs of a step still in flight (resume would double-apply a
    non-idempotent step on top of its own effects)."""
    wf = Workflow("consistent")
    wf.var("x")
    wf.var("v")
    wf.step("fast", lambda x: {"y_fast": np.float64(1)}, inputs=("x",),
            outputs=("y_fast",), remotable=True, jax_step=False)

    def inc(v):
        time.sleep(0.2)                  # in flight while fast checkpoints
        return {"v": np.float64(v) + 1}

    wf.step("inc", inc, inputs=("v",), outputs=("v",), remotable=True,
            jax_step=False)
    ex = EmeraldExecutor(partition(wf), emerald(),
                         checkpoint_dir=str(tmp_path))
    seen = []
    orig = ex._save_checkpoint

    def spy(completed):
        orig(completed)
        with open(tmp_path / "consistent.wfckpt", "rb") as f:
            c = pickle.load(f)
        seen.append((set(c["completed"]), set(c["vars"]),
                     {u: float(v) for u, v in c["vars"].items()}))

    ex._save_checkpoint = spy
    ex.run({"x": np.float64(0.0), "v": np.float64(0.0)})
    assert len(seen) == 2
    for completed, uris, vals in seen:
        allowed = {"x", "v"} | {u for n in completed
                                for u in wf.steps[n].outputs}
        assert uris <= allowed, (completed, uris)
        if "inc" not in completed:
            assert vals["v"] == 0.0, "checkpoint saw in-flight inc's write"


# ----------------------------------------------------- broker harvest (fabric)
def test_broker_nonblocking_harvest():
    Fabric = pytest.importorskip("repro.cloud").Fabric
    with Fabric(workers=1) as fabric:
        fired = []
        tasks = [fabric.broker.submit(step="spin",
                                      kwargs={"seconds": 0.05})
                 for _ in range(3)]
        tasks[0].add_done_callback(lambda t: fired.append(t.task_id))
        assert not tasks[-1].done()          # nothing has had time to finish
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            finished, pending = fabric.broker.harvest(tasks)
            if not pending:
                break
            time.sleep(0.01)
        assert len(finished) == 3 and not pending
        assert fired == [tasks[0].task_id]
        for t in tasks:
            t.result(1)                      # already done: returns at once
