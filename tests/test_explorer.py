"""emcheck: deterministic schedule-space exploration.

Covers the explorer's acceptance surface:

  * the canonical 6-step diamond exhausts its interleaving space with
    zero hazards and full distinct-terminal coverage,
  * every planted bug flag is detected by its scenario model within a
    bounded schedule budget, while the clean twin model stays silent,
  * the planted PR 4 duplicate-done race is found, delta-debugged to a
    minimal decision list, serialized byte-identically, and replayed
    deterministically from the reproducer file,
  * exploration and seeded sampling are bit-for-bit deterministic,
  * the runtime's ``dispatch_hook`` seam lets an external policy drive
    real dispatch order without tripping the sanitizer,
  * the broker's dispatch loop survives a lost shutdown wakeup (the
    failsafe timed wait — the hang emcheck-driven teardowns hit).
"""
import json
import threading

import numpy as np
import pytest

from repro.analysis import explorer, sanitizer
from repro.analysis.explorer import (build_model, check_resume, explore,
                                     load_reproducer, minimize, model_diamond,
                                     replay, replay_reproducer, run_benign,
                                     sample, save_reproducer)
from repro.cloud.broker import Broker
from repro.core import (CostModel, EmeraldRuntime, MDSS, MigrationManager,
                        Workflow, default_tiers)


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    return MigrationManager(tiers, MDSS(tiers, cost_model=cm), cm)


# ------------------------------------------------------------ exhaustive
def test_diamond_exhausts_clean():
    res = explore(model_diamond())
    assert res.exhaustive
    assert res.hazard_count == 0 and res.hazards == []
    # every complete interleaving reaches a distinct recorded terminal
    assert res.schedules == len(res.coverage)
    assert res.schedules > 1000          # the space is genuinely explored
    assert res.por_pruned > 0            # POR found commuting completions
    assert res.deduped > 0               # dedup cut revisited states


def test_explore_is_deterministic():
    a = explore(model_diamond())
    b = explore(model_diamond())
    assert (a.schedules, a.decisions, a.deduped, a.por_pruned) == \
           (b.schedules, b.decisions, b.deduped, b.por_pruned)
    assert a.coverage == b.coverage


def test_sample_is_seed_deterministic():
    m = build_model("two_tenant", bugs=("unfair",))
    a = sample(m, schedules=40, seed=7)
    b = sample(m, schedules=40, seed=7)
    assert a.hazard_count == b.hazard_count
    assert a.coverage == b.coverage
    assert [s for s, _ in a.hazards] == [s for s, _ in b.hazards]


# ----------------------------------------------------- planted bug flags
# (model, bugs, expected rule, explore kwargs) — each scenario model must
# find its planted defect inside the budget and stay silent without it.
SCENARIOS = [
    ("diamond", ("duplicate_done",), "H101", {}),
    ("resubmit", ("stale_install",), "H120", {}),
    ("memo_pair", ("memo_no_guard",), "H121", {}),
    ("budget", ("no_evict",), "H123", {}),
    ("ckpt_chain", ("ckpt_lost_step",), "H124", {"resume_check": True}),
]


@pytest.mark.parametrize("name,bugs,rule,kw",
                         SCENARIOS, ids=[s[2] for s in SCENARIOS])
def test_planted_bug_detected_and_clean_twin_silent(name, bugs, rule, kw):
    buggy = explore(build_model(name, bugs=bugs), max_schedules=4000,
                    max_hazards=1, **kw)
    assert rule in buggy.hazard_rules(), \
        f"{rule} not found: {buggy.hazard_rules()}"
    clean = explore(build_model(name), max_schedules=4000, **kw)
    assert clean.hazard_count == 0, clean.hazard_rules()


def test_unfair_scheduler_starves_within_sampled_budget():
    # two_tenant is too wide to exhaust; seeded sampling must still
    # surface the starvation window.
    res = sample(build_model("two_tenant", bugs=("unfair",)),
                 schedules=120, seed=0)
    assert "H122" in res.hazard_rules()
    clean = sample(build_model("two_tenant"), schedules=120, seed=0)
    assert clean.hazard_count == 0, clean.hazard_rules()


# ------------------------------------- planted race: find/minimize/replay
def test_duplicate_done_found_minimized_and_replayable(tmp_path):
    model = model_diamond(bugs=("duplicate_done",))
    res = explore(model, max_schedules=500, max_hazards=1)
    assert res.hazard_count >= 1          # found within K=500 schedules
    schedule, findings = res.hazards[0]
    assert "H101" in {f.rule for f in findings}

    small = minimize(model, schedule)
    assert len(small) <= len(schedule)
    assert any(d.startswith("ghost:") for d in small)
    # 1-minimality: dropping any single decision loses the hazard
    for i in range(len(small)):
        probe = small[:i] + small[i + 1:]
        sim = replay(model, probe, strict=False)
        run_benign(sim)
        rules = {f.rule for f in explorer.check_trace(sim.trace())}
        assert "H101" not in rules, f"decision {small[i]} was removable"

    path = tmp_path / "repro.json"
    save_reproducer(str(path), model, small, findings)
    first = path.read_bytes()
    save_reproducer(str(path), model, small, findings)
    assert path.read_bytes() == first     # byte-identical serialization

    doc = load_reproducer(str(path))
    assert doc["emcheck_version"] == explorer.EMCHECK_VERSION
    assert doc["model"] == {"name": "diamond", "params": {},
                            "bugs": ["duplicate_done"]}
    got, ok = replay_reproducer(doc)      # model rebuilt from registry
    assert ok and "H101" in {f.rule for f in got}
    got2, ok2 = replay_reproducer(doc)
    assert ok2 and [str(f) for f in got2] == [str(f) for f in got]


def test_replay_strict_rejects_infeasible_decision():
    with pytest.raises(ValueError, match="not enabled"):
        replay(model_diamond(), ["complete:A:src"])


def test_fault_injection_stays_hazard_free():
    # crashes burn retries and may fail runs, but a correct model must
    # never turn a fault into a hazard verdict.
    m = model_diamond()
    m.max_crashes = 2
    res = sample(m, schedules=80, seed=3)
    assert res.hazard_count == 0, res.hazard_rules()


def test_resume_check_clean_on_correct_checkpointing():
    m = build_model("ckpt_chain")
    sim = explorer.Simulation(m)
    run_benign(sim)
    assert check_resume(m, sim.schedule) == []


# --------------------------------------------------- runtime dispatch seam
def test_dispatch_hook_drives_real_runtime():
    seen = []

    def hook(lane, run_ids):
        seen.append((lane, tuple(run_ids)))
        return run_ids[-1]                # force last-submitted-first

    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False,
                        dispatch_hook=hook)
    try:
        handles = []
        for i in range(3):
            wf = Workflow(f"hooked{i}")
            wf.var("x")
            wf.step("a", lambda x: {"u": x * 2}, inputs=("x",),
                    outputs=("u",), jax_step=False)
            wf.step("b", lambda u: {"out": u + 1}, inputs=("u",),
                    outputs=("out",), jax_step=False)
            handles.append(rt.submit(wf, {"x": np.float64(i)}))
        for i, h in enumerate(handles):
            assert float(h.result()["out"]) == 2.0 * i + 1.0
            assert sanitizer.check(h.events, completed_run=True) == []
        assert seen and all(lane in ("local", "offload")
                            for lane, _ in seen)
    finally:
        rt.close()


def test_dispatch_hook_none_defers_to_fair_share():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False,
                        dispatch_hook=lambda lane, run_ids: None)
    try:
        wf = Workflow("deferred")
        wf.var("x")
        wf.step("a", lambda x: {"out": x + 1}, inputs=("x",),
                outputs=("out",), jax_step=False)
        h = rt.submit(wf, {"x": np.float64(1.0)})
        assert float(h.result()["out"]) == 2.0
    finally:
        rt.close()


# ------------------------------------------------- broker shutdown wakeup
class _NullPool:
    def spawn(self):
        raise AssertionError("test broker must not spawn workers")

    def kill(self, h):
        pass

    def close(self):
        pass


def test_broker_shutdown_survives_lost_wakeup(monkeypatch):
    """With no workers the dispatch loop parks in its condition wait.
    Suppress the shutdown notification entirely: the failsafe timed
    wait must still notice ``_closed`` and let the thread exit —
    before the fix the untimed ``wait()`` wedged teardown forever."""
    monkeypatch.setattr(Broker, "_FAILSAFE_WAKEUP_S", 0.05)
    broker = Broker(_NullPool())
    try:
        assert broker._dispatcher.is_alive()
        monkeypatch.setattr(broker._cond, "notify_all", lambda: None)
        broker.shutdown()
        broker._dispatcher.join(timeout=3.0)
        assert not broker._dispatcher.is_alive()
    finally:
        monkeypatch.undo()
        broker.shutdown()
