"""Offload-fabric tests: wire format, process-separated dispatch, real
byte accounting through RPCTransport, worker-crash requeue, elastic
autoscaling with warm-pool reuse."""
import os
import socket
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cloud import (Autoscaler, AutoscalerConfig, Fabric, FabricError,
                         RemoteStepError, ShipTimeout, WorkerLostError,
                         attach)
from repro.cloud.wire import decode, encode, recv_msg, send_msg
from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        Workflow, default_tiers, partition)


# --------------------------------------------------------------- wire format
def nested_value():
    return {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "b": np.zeros(4, dtype=np.float64)},
        "meta": ("adam", 3, 0.1, None, b"blob"),
        "history": [np.int32(7), {"nested": [np.ones((2, 2, 2))]}],
        "flag": True,
        "name": "step-0",
    }


def assert_trees_equal(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            assert_trees_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b) and type(a) is type(b)
        for x, y in zip(a, b):
            assert_trees_equal(x, y)
    elif isinstance(a, np.ndarray):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)
    else:
        assert a == b


def test_wire_roundtrip_nested_pytree():
    val = nested_value()
    data = encode(val)
    assert len(data) > sum(a.nbytes for a in (val["params"]["w"],
                                              val["params"]["b"]))
    assert_trees_equal(decode(data), val)


def test_wire_roundtrip_jax_arrays_become_numpy():
    out = decode(encode({"x": jnp.arange(8.0), "s": jnp.float32(2.0)}))
    assert isinstance(out["x"], np.ndarray)
    np.testing.assert_array_equal(out["x"], np.arange(8.0))
    np.testing.assert_array_equal(out["s"], np.float32(2.0))


def test_wire_framing_over_socket():
    a, b = socket.socketpair()
    msgs = [{"op": "x", "v": np.arange(1000)}, {"op": "y"}, [1, 2, 3]]
    sent = []

    def writer():
        for m in msgs:
            sent.append(send_msg(a, m))

    t = threading.Thread(target=writer)
    t.start()
    received = [recv_msg(b) for _ in msgs]
    t.join()
    assert len(sent) == len(msgs)
    for m, n, (got, nread) in zip(msgs, sent, received):
        assert_trees_equal(got, m)
        assert nread == n
    a.close()
    b.close()


# ------------------------------------------------------------ shared fabric
@pytest.fixture(scope="module")
def fabric():
    with Fabric(workers=2) as f:
        yield f


def test_step_runs_in_separate_process(fabric):
    out = fabric.broker.submit(step="pid").result(30)
    assert int(out["pid"]) != os.getpid()
    assert int(out["pid"]) in fabric.broker.worker_pids()


def test_ship_moves_real_bytes(fabric):
    val = {"a": np.random.rand(1 << 12).astype(np.float32)}
    task = fabric.ship(val)
    np.testing.assert_array_equal(task.value["a"], val["a"])
    assert task.bytes_sent > val["a"].nbytes
    # the echo direction dedups against the request's own chunks: the
    # payload comes back as digest references, not bytes
    assert task.bytes_received < 4096
    assert task.seconds > 0


def test_ship_timeout_cancels_queued_task():
    """A ship that times out while still QUEUED is withdrawn: no worker
    ever receives it, and its future resolves (failed) instead of the
    orphaned result landing in a dead inbox."""
    with Fabric(workers=1) as fabric:
        blocker = fabric.broker.submit(step="sleep",
                                       kwargs={"seconds": 0.5})
        time.sleep(0.05)                     # the only worker is busy
        with pytest.raises(ShipTimeout) as ei:
            fabric.ship({"a": np.arange(4)}, timeout=0.05)
        t = ei.value.task
        assert fabric.broker.queue_depth() == 0, \
            "timed-out ship left an orphan in the queue"
        assert fabric.broker.tasks_cancelled == 1
        with pytest.raises(FabricError, match="cancelled"):
            t.result(1)                      # resolved, not a dead inbox
        blocker.result(30)
        assert fabric.broker.tasks_done == 1, \
            "a worker burned a slot on the cancelled ship"


def test_ship_timeout_inflight_task_stays_harvestable():
    """A ship that times out while IN FLIGHT is not lost: the exception
    carries the task and the eventual worker reply is harvestable."""
    with Fabric(workers=1) as fabric:
        val = {"a": np.random.rand(1 << 22).astype(np.float64)}   # 32 MiB
        # 5 ms: far longer than the idle dispatcher needs to pop the
        # queue, far shorter than a 32 MiB round trip
        with pytest.raises(ShipTimeout) as ei:
            fabric.ship(val, timeout=0.005)
        t = ei.value.task
        if fabric.broker.tasks_cancelled:
            pytest.skip("dispatcher lost the 5 ms race on a loaded box; "
                        "the queued branch is covered above")
        out = t.result(30)                   # the reply still arrives
        np.testing.assert_array_equal(out["a"], val["a"])
        assert fabric.broker.tasks_cancelled == 0


def test_remote_exception_keeps_worker_alive(fabric, tmp_path):
    n_before = fabric.broker.num_workers()
    t = fabric.broker.submit(step="fail_n_times", kwargs={
        "counter_file": str(tmp_path / "fails"), "n_fails": 99, "x": 0.0})
    with pytest.raises(RemoteStepError, match="injected step failure"):
        t.result(30)
    assert fabric.broker.num_workers() == n_before


def test_worker_crash_requeues_task(fabric, tmp_path):
    before = fabric.broker.tasks_requeued
    t = fabric.broker.submit(step="crash_n_times", kwargs={
        "counter_file": str(tmp_path / "crashes"), "n_crashes": 1, "x": 5.0})
    out = t.result(60)
    assert float(out["y"]) == 6.0
    assert fabric.broker.tasks_requeued == before + 1
    assert fabric.broker.workers_lost >= 1


def test_requeue_budget_exhaustion_raises(fabric, tmp_path):
    t = fabric.broker.submit(step="crash_n_times", max_attempts=1, kwargs={
        "counter_file": str(tmp_path / "always"), "n_crashes": 99, "x": 0.0})
    with pytest.raises(WorkerLostError):
        t.result(60)


# ---------------------------------------------------- MDSS / RPC transport
def test_rpc_transport_accounts_real_movement(fabric):
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    transport = attach(tiers, fabric, mdss=mdss, cost_model=cm)
    val = {"w": np.random.rand(256, 16).astype(np.float32)}
    mdss.put("params", val, tier="local")
    assert mdss.stale_bytes(["params"], "cloud") == val["w"].nbytes
    moved = mdss.ensure(["params"], "cloud")
    assert moved == val["w"].nbytes
    np.testing.assert_array_equal(mdss.get("params", "cloud")["w"], val["w"])
    # the value crossed a process boundary: wire counters and observed bw
    assert transport.total_bytes_shipped() > val["w"].nbytes
    assert cm.measured_bw[("local", "cloud")] > 0
    # second ensure is a no-op (fresh replica): nothing moves
    assert mdss.ensure(["params"], "cloud") == 0


def test_cost_model_uses_observed_bandwidth():
    tiers = default_tiers()
    cm = CostModel(tiers)
    static = cm.transfer_time(1e6, "local", "cloud")
    cm.observe_bandwidth("local", "cloud", 1e6, 0.01)   # 100 MB/s observed
    observed = cm.transfer_time(1e6, "local", "cloud")
    assert observed != static
    assert abs(observed - (tiers["local"].link_latency_s + 0.01)) < 1e-6


# --------------------------------------------------- workflow through fabric
def test_workflow_offload_executes_in_worker(fabric):
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    attach(tiers, fabric, mdss=mdss, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    wf = Workflow("fab")
    wf.var("x")
    wf.step("grow", None, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False, remote_impl="add_one")
    wf.step("sq", lambda y: {"z": y * y}, inputs=("y",), outputs=("z",))
    ex = EmeraldExecutor(partition(wf), mgr)
    out = ex.run({"x": np.float64(4.0)})
    assert float(out["z"]) == 25.0
    off = [e for e in ex.events if e.kind == "offload"][0]
    assert off.info["remote"] is True
    assert off.info["worker_pid"] not in (0, os.getpid())
    assert off.info["bytes_in"] > 0 and off.info["bytes_out"] > 0


def test_workflow_survives_worker_crash(fabric, tmp_path):
    """Acceptance: a worker dies mid-step, the broker requeues onto the
    surviving worker, and the workflow completes."""
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    attach(tiers, fabric, mdss=mdss, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    wf = Workflow("crashy")
    wf.var("x")
    wf.var("counter_file")
    wf.step("s", None, inputs=("counter_file", "x"), outputs=("y",),
            remotable=True, jax_step=False, remote_impl="crash_n_times")
    before = fabric.broker.tasks_requeued
    ex = EmeraldExecutor(partition(wf), mgr)
    out = ex.run({"x": np.float64(1.0),
                  "counter_file": str(tmp_path / "wfcrash")})
    assert float(out["y"]) == 2.0
    assert fabric.broker.tasks_requeued == before + 1
    off = [e for e in ex.events if e.kind == "offload"][0]
    assert off.info["remote"] is True and off.info["attempt"] == 0, \
        "requeue should be broker-level, invisible to the executor"


# --------------------------------------------------------------- autoscaler
def test_autoscaler_scales_up_down_and_reuses_warm_workers():
    cfg = AutoscalerConfig(min_workers=1, max_workers=3, queue_high=1.0,
                           idle_scale_down_s=0.05, warm_ttl_s=60.0)
    with Fabric(workers=1, autoscaler=cfg) as f:
        a = f.autoscaler
        assert f.broker.num_workers() == 1
        tasks = [f.broker.submit(step="sleep", kwargs={"seconds": 0.2})
                 for _ in range(6)]
        act = a.tick()
        assert act["added"] >= 1 and f.broker.num_workers() > 1
        for t in tasks:
            t.result(30)
        pids_at_peak = set(f.broker.worker_pids())
        # idle dwell -> retire down to min, one per tick
        deadline = time.monotonic() + 10
        while f.broker.num_workers() > 1 and time.monotonic() < deadline:
            time.sleep(0.06)
            a.tick()
        assert f.broker.num_workers() == 1
        assert f.broker.num_workers(include_warm=True) > 1, \
            "scale-down should park workers warm, not kill them"
        # scale-up reuses a warm process: same pid, counted as a warm hit
        hits = f.broker.warm_hits
        f.broker.add_worker()
        assert f.broker.warm_hits == hits + 1
        assert set(f.broker.worker_pids()) <= pids_at_peak
        # warm TTL reap actually kills parked workers
        assert f.broker.reap_warm(0.0) >= 0
        assert f.broker.num_workers(include_warm=True) == \
            f.broker.num_workers()


def test_autoscaler_desired_workers_uses_task_duration():
    with Fabric(workers=1) as f:
        cfg = AutoscalerConfig(min_workers=1, max_workers=8, queue_high=100.0,
                               target_drain_s=0.5)
        a = Autoscaler(f.broker, cfg)
        f.broker.submit(step="sleep", kwargs={"seconds": 0.25}).result(30)
        assert f.broker.avg_task_seconds() is not None
        for _ in range(8):
            f.broker.submit(step="sleep", kwargs={"seconds": 0.25})
        # ~8 queued * 0.25s / 0.5s target -> ~4 workers wanted (cost signal,
        # queue_high alone would never trip at 100)
        assert a.desired_workers() >= 3
