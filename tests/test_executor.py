"""Executor tests: lifecycle, parallel offload, fault tolerance, policies,
straggler speculation, workflow checkpoint/resume."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        StepFailure, Workflow, WorkflowFailure, default_tiers,
                        partition)


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def linear_wf():
    wf = Workflow("lin")
    wf.var("x")
    wf.step("a", lambda x: {"y": x + 1}, inputs=("x",), outputs=("y",))
    wf.step("b", lambda y: {"z": y * 2}, inputs=("y",), outputs=("z",),
            remotable=True)
    wf.step("c", lambda z: {"w": z - 3}, inputs=("z",), outputs=("w",))
    return wf


def test_suspend_offload_resume_alternate():
    mgr = emerald()
    ex = EmeraldExecutor(partition(linear_wf()), mgr)
    out = ex.run({"x": jnp.float32(5.0)})
    assert float(out["w"]) == (5 + 1) * 2 - 3
    kinds = [e.kind for e in ex.events if e.kind in ("suspend", "offload",
                                                     "resume")]
    assert kinds == ["suspend", "offload", "resume"]    # P3: alternation


def test_policy_never_keeps_everything_local():
    mgr = emerald()
    ex = EmeraldExecutor(partition(linear_wf()), mgr, policy="never")
    out = ex.run({"x": jnp.float32(1.0)})
    assert float(out["w"]) == 1.0
    assert all(e.kind != "offload" for e in ex.events)


def test_parallel_steps_offload_concurrently():
    wf = Workflow("par")
    wf.var("x")
    order = []

    def slow(tag):
        def fn(x):
            order.append((tag, "start"))
            time.sleep(0.15)
            order.append((tag, "end"))
            return {f"y{tag}": np.asarray(float(x) + 1)}
        return fn

    wf.step("p1", slow(1), inputs=("x",), outputs=("y1",), remotable=True,
            jax_step=False)
    wf.step("p2", slow(2), inputs=("x",), outputs=("y2",), remotable=True,
            jax_step=False)
    mgr = emerald()
    ex = EmeraldExecutor(partition(wf), mgr)
    t0 = time.perf_counter()
    ex.run({"x": np.float64(0.0)})
    dt = time.perf_counter() - t0
    starts = [i for i, (t, k) in enumerate(order) if k == "start"]
    assert starts[:2] == [0, 1], f"steps did not overlap: {order}"
    assert dt < 0.29, "parallel steps ran sequentially"


def test_retry_then_success():
    fails = {"n": 2}

    def flaky(x):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise StepFailure("injected node failure")
        return {"y": x + 1}

    wf = Workflow("flaky")
    wf.var("x")
    wf.step("s", flaky, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False, retries=3)
    mgr = emerald()
    ex = EmeraldExecutor(partition(wf), mgr)
    out = ex.run({"x": 1.0})
    assert out["y"] == 2.0
    assert sum(1 for e in ex.events if e.kind == "retry") == 2


def test_fallback_to_local_after_cloud_dead():
    calls = []

    def cloud_dead(x):
        # the migration manager reports the tier via thread context; infer
        # from call count: first attempts are cloud (retries), last is local
        calls.append(1)
        if len(calls) <= 2:
            raise StepFailure("cloud node lost")
        return {"y": x * 10}

    wf = Workflow("dead")
    wf.var("x")
    wf.step("s", cloud_dead, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False, retries=2)
    mgr = emerald()
    ex = EmeraldExecutor(partition(wf), mgr)
    out = ex.run({"x": 3.0})
    assert out["y"] == 30.0
    offl = [e for e in ex.events if e.kind == "offload"]
    assert offl and offl[-1].tier == "local"     # final success was local


def test_total_failure_raises():
    def always(x):
        raise StepFailure("dead")

    wf = Workflow("dead2")
    wf.var("x")
    wf.step("s", always, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False, retries=1)
    ex = EmeraldExecutor(partition(wf), emerald())
    with pytest.raises(WorkflowFailure):
        ex.run({"x": 1.0})


def test_straggler_speculation():
    state = {"calls": 0}

    def sometimes_slow(x):
        state["calls"] += 1
        if state["calls"] == 2:          # second call (the straggler) hangs
            time.sleep(1.0)
        return {"y": np.asarray(float(x) + 1)}

    wf = Workflow("strag")
    wf.var("x")
    wf.step("s", sometimes_slow, inputs=("x",), outputs=("y",),
            remotable=True, jax_step=False)
    mgr = emerald()
    ex = EmeraldExecutor(partition(wf), mgr, speculate_after=2.0)
    ex.run({"x": 0.0})                   # seeds the runtime EMA
    t0 = time.perf_counter()
    out = ex.run({"x": 5.0})             # straggles -> speculative duplicate
    dt = time.perf_counter() - t0
    assert out["y"] == 6.0
    assert any(e.kind == "speculate" for e in ex.events)
    assert dt < 0.9, "speculation did not cut straggler latency"


def test_workflow_checkpoint_resume(tmp_path):
    state = {"crash": True}

    def mid(y):
        if state["crash"]:
            raise StepFailure("power loss")
        return {"z": y * 2}

    wf = Workflow("ck")
    wf.var("x")
    wf.step("a", lambda x: {"y": x + 1}, inputs=("x",), outputs=("y",),
            remotable=True)
    wf.step("b", mid, inputs=("y",), outputs=("z",), remotable=True,
            jax_step=False, retries=0)
    wf.step("c", lambda z: {"w": z + 0.5}, inputs=("z",), outputs=("w",))
    mgr = emerald()
    ex = EmeraldExecutor(partition(wf), mgr, checkpoint_dir=str(tmp_path))
    with pytest.raises(WorkflowFailure):
        ex.run({"x": jnp.float32(1.0)})
    # restart: step a's result restored from checkpoint, b now succeeds
    state["crash"] = False
    mgr2 = emerald()
    ex2 = EmeraldExecutor(partition(wf), mgr2, checkpoint_dir=str(tmp_path))
    out = ex2.run({"x": jnp.float32(1.0)}, resume=True)
    assert float(out["w"]) == (1 + 1) * 2 + 0.5
    ran = {e.step for e in ex2.events if e.kind in ("offload", "local")}
    assert "a" not in ran, "completed step re-ran after resume"


def test_cost_model_policy_prefers_local_for_tiny_steps():
    wf = Workflow("tiny")
    wf.var("x")
    wf.step("s", lambda x: {"y": x + 1}, inputs=("x",), outputs=("y",),
            remotable=True, flops_hint=10.0, bytes_hint=8.0)
    ex = EmeraldExecutor(partition(wf), emerald(), policy="cost_model")
    ex.run({"x": jnp.float32(1.0)})
    assert all(e.kind != "offload" for e in ex.events)


def test_cost_model_policy_offloads_heavy_steps():
    wf = Workflow("heavy")
    wf.var("x")
    wf.step("s", lambda x: {"y": x + 1}, inputs=("x",), outputs=("y",),
            remotable=True, flops_hint=1e15, bytes_hint=8.0)
    ex = EmeraldExecutor(partition(wf), emerald(), policy="cost_model")
    ex.run({"x": jnp.float32(1.0)})
    assert any(e.kind == "offload" for e in ex.events)
