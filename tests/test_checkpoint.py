"""Checkpointer tests: roundtrip, async, crash-atomicity, elastic re-shard
(subprocess with 8 fake devices), trainer resume equality."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer

# compile-heavy: excluded from the smoke fast lane (-m "not slow"),
# still part of tier-1 (plain pytest runs everything)
pytestmark = pytest.mark.slow

# The explicit-mesh API (jax.sharding.AxisType / jax.set_mesh) is newer
# than this container's jax; the subprocess scripts below require it.
import jax as _jax
needs_axis_type = pytest.mark.skipif(
    not hasattr(_jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType (explicit-mesh API)")


def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save("m", 10, t, topology={"mesh": [1]})
    restored, meta = ck.restore("m", jax.eval_shape(lambda: t))
    assert meta["step"] == 10 and meta["topology"] == {"mesh": [1]}
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_tracking(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save("m", 5, tree(), topology={})
    ck.save("m", 9, tree(), topology={})
    assert ck.latest_step("m") == 9


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save("m", 1, tree(), topology={})
    ck.wait()
    restored, _ = ck.restore("m", jax.eval_shape(lambda: tree()))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(12.0).reshape(3, 4))


def test_partial_file_never_visible(tmp_path):
    """Atomic rename: no *.npz file exists until fully written."""
    ck = Checkpointer(str(tmp_path))
    ck.save("m", 1, tree(), topology={})
    files = os.listdir(tmp_path)
    assert not any(f.endswith(".tmp.npz") for f in files)


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save("m", 1, tree(), topology={})
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.zeros((4,))},
           "step": jnp.int32(0)}
    with pytest.raises(ValueError):
        ck.restore("m", jax.eval_shape(lambda: bad))


ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.checkpoint import Checkpointer

    phase = sys.argv[1]
    ckdir = sys.argv[2]
    tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
    ck = Checkpointer(ckdir)
    if phase == "save":
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sh = NamedSharding(mesh, P("data", "model"))
        t = {{"w": jax.device_put(tree["w"], sh)}}
        ck.save("elastic", 1, t, topology={{"mesh": [4, 2]}})
    else:
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sh = {{"w": NamedSharding(mesh, P("model", "data"))}}
        restored, meta = ck.restore("elastic", jax.eval_shape(lambda: tree),
                                    shardings=sh)
        assert meta["topology"] == {{"mesh": [4, 2]}}
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64.0).reshape(8, 8))
        assert restored["w"].sharding.spec == P("model", "data")
        print("ELASTIC_OK")
""")


@needs_axis_type
def test_elastic_reshard_across_meshes(tmp_path):
    """Save sharded on a (4,2) mesh, restore onto a (2,4) mesh."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = ELASTIC_SCRIPT.format(src=os.path.abspath(src))
    env = dict(os.environ)
    for phase in ("save", "restore"):
        r = subprocess.run([sys.executable, "-c", script, phase,
                            str(tmp_path)], capture_output=True, text=True,
                           env=env, timeout=240)
        assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout


def test_trainer_resume_bit_identical(tmp_path):
    """Train 6 steps; vs train 3, checkpoint, restart, 3 more."""
    from repro.configs import get_config
    from repro.configs.base import RunConfig, ShapeProfile, reduced
    from repro.launch.train import Trainer

    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    shape = ShapeProfile("t", 32, 2, "train")
    run = RunConfig(model=cfg, shape=shape, remat="none")

    t1 = Trainer(run, ckpt_dir=str(tmp_path / "a"), ckpt_every=3,
                 async_ckpt=False)
    h1 = t1.fit(6, log_every=0)

    t2 = Trainer(run, ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                 async_ckpt=False)
    t2.fit(3, log_every=0)
    t3 = Trainer(run, ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                 async_ckpt=False)
    h3 = t3.fit(3, resume=True, log_every=0)

    np.testing.assert_allclose(h1[-1]["loss"], h3[-1]["loss"], rtol=1e-5)
    assert h3[-1]["step"] == h1[-1]["step"]
