"""Observability layer: tracing, metrics registry, introspection, events.

Acceptance surface of the telemetry PR:

  * span context survives a REAL fabric round-trip — the worker
    subprocess's recv/exec/send phases come back as spans whose ancestry
    reaches the driver-side dispatch span,
  * ``introspect()`` is serially consistent under concurrent tenants —
    a step is never simultaneously in-flight and completed, and
    completion is absorbing across repeated snapshots,
  * the Chrome trace-event export is structurally valid (X events with
    microsecond ts/dur, M metadata naming every track, explicit
    parent_id linkage in args),
  * previously-orphaned counters (broker.tasks_cancelled, warm/idle
    worker counts, MDSS eviction bytes) surface in the metrics snapshot,
  * every ``emit(`` call site in src/ uses a kind registered in
    EVENT_SCHEMA (lint), and events carry a cross-process-comparable
    wall timestamp next to the monotonic one,
  * ``telemetry=False`` turns the whole layer into no-ops.
"""
import json
import os
import re
import threading
import time

import numpy as np
import pytest

from repro.core import (CostModel, EmeraldRuntime, MDSS, MigrationManager,
                        Workflow, default_tiers)
from repro.obs.events import EVENT_SCHEMA, validate_event
from repro.obs.introspect import render
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import Tracer, chrome_trace, wall_now

SRC_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def sleeper(out, seconds=0.0):
    def fn(**kw):
        (val,) = kw.values()
        if seconds:
            time.sleep(seconds)
        return {out: np.float64(float(val) + 1.0)}
    return fn


def chain_wf(name, depth, step_s=0.0):
    wf = Workflow(name)
    wf.var("x")
    src = "x"
    for i in range(depth):
        out = f"y{i + 1}"
        wf.step(f"s{i + 1}", sleeper(out, step_s), inputs=(src,),
                outputs=(out,), remotable=True, jax_step=False)
        src = out
    return wf


# ------------------------------------------------------------- tracer unit
def test_tracer_tls_parenting_and_ctx():
    tr = Tracer()
    with tr.span("outer", track="t") as outer:
        assert tr.current_ctx() == outer.ctx
        with tr.span("inner", track="t") as inner:
            assert inner.span.parent_id == outer.span.span_id
        # explicit parent overrides TLS
        with tr.span("routed", parent=("tid", 99)) as routed:
            assert routed.span.parent_id == 99
            assert routed.span.trace_id == "tid"
    assert tr.current_ctx() is None
    names = {s.name for s in tr.spans()}
    assert names == {"outer", "inner", "routed"}


def test_tracer_attach_propagates_to_helper_thread():
    tr = Tracer()
    got = {}
    with tr.span("dispatch") as d:
        ctx = d.ctx

        def helper():
            with tr.attach(ctx):
                with tr.span("child") as c:
                    got["parent"] = c.span.parent_id
        t = threading.Thread(target=helper)
        t.start()
        t.join()
    assert got["parent"] == ctx[1]


def test_tracer_ring_is_bounded_and_counts_drops():
    tr = Tracer(cap=4)
    for i in range(10):
        tr.add_span("t", f"s{i}", wall_now(), 0.0)
    assert len(tr.spans()) == 4
    assert tr.dropped == 6
    assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    with tr.span("x") as sp:
        assert sp.ctx is None
    assert tr.add_span("t", "x", 0.0, 0.0) is None
    assert tr.spans() == [] and tr.current_ctx() is None


# ------------------------------------------------------------ metrics unit
def test_metrics_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.inc("a.count")
    reg.inc("a.count", 4)
    reg.gauge("a.gauge", lambda: 7)
    reg.gauge("a.bad", lambda: 1 / 0)          # sampling never throws
    reg.observe("a.hist", 0.003)
    reg.observe("a.hist", 99.0)
    snap = reg.snapshot()
    assert snap["a.count"] == 5
    assert snap["a.gauge"] == 7
    assert snap["a.bad"] is None
    h = snap["a.hist"]
    assert h["count"] == 2 and h["min"] == 0.003 and h["max"] == 99.0
    assert h["buckets"]["+inf"] == 1
    # last-wins gauge re-registration (idempotent attach_fabric wiring)
    reg.gauge("a.gauge", lambda: 8)
    assert reg.snapshot()["a.gauge"] == 8


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    reg.inc("x")
    reg.observe("y", 1.0)
    assert reg.snapshot() == {}


# ------------------------------------------------- fabric span round-trip
def test_worker_spans_parent_under_driver_dispatch():
    """Acceptance: a registry step through a real worker subprocess comes
    back with recv/exec/send child spans whose ancestry chain reaches the
    driver-side dispatch span of the same trace."""
    Fabric = pytest.importorskip("repro.cloud").Fabric
    wf = Workflow("traced")
    wf.var("x")
    wf.step("grow", None, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False, remote_impl="add_one")
    with Fabric(workers=1) as fabric:
        with EmeraldRuntime(emerald(), max_workers=2) as rt:
            rt.attach_fabric(fabric)
            h = rt.submit(wf, {"x": np.float64(4.0)})
            assert float(h.result(60)["y"]) == 5.0
            spans = rt.tracer.spans(h.trace_id)
            by_id = {s.span_id: s for s in spans}
            worker = [s for s in spans if s.track.startswith("worker:")]
            assert {s.name for s in worker} >= {"recv", "exec", "send"}
            wpid = worker[0].pid
            assert wpid not in (0, os.getpid()), \
                "worker spans must carry the worker subprocess pid"
            for ws in worker:
                chain = []
                cur = by_id.get(ws.parent_id)
                while cur is not None:
                    chain.append(cur.name)
                    cur = by_id.get(cur.parent_id)
                assert "dispatch" in chain, (ws.name, chain)
                assert chain[-1] == "run", (ws.name, chain)
            # satellite (b): the orphaned fabric counters are in the
            # unified registry snapshot
            snap = rt.metrics.snapshot()
            for key in ("broker.tasks_cancelled", "broker.idle_workers",
                        "broker.num_workers_with_warm",
                        "broker.queue_depth", "pool.spawned_total",
                        "mdss.eviction_bytes", "wire.bytes_sent"):
                assert key in snap, key
            assert snap["broker.tasks_cancelled"] == \
                fabric.broker.tasks_cancelled
            assert snap["pool.spawned_total"] >= 1
            assert snap["wire.bytes_sent"] > 0

            # the exported Chrome trace carries the worker-side spans with
            # their explicit parent linkage
            doc = rt.tracer.export(h.trace_id)
            xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
            wx = [e for e in xs if e["pid"] == wpid]
            assert wx, "no worker-process events in the export"
            ids = {e["args"]["span_id"] for e in xs}
            for e in wx:
                assert e["args"]["parent_id"] in ids


# ------------------------------------------------------ introspect / emtop
def test_introspect_consistent_under_concurrent_tenants():
    """Hammer introspect() from two reader threads while two tenants
    execute: per-step states are single-valued (never both in-flight and
    completed), counts add up, and completion is absorbing."""
    with EmeraldRuntime(emerald(), max_workers=2, local_workers=2) as rt:
        h1 = rt.submit(chain_wf("alpha", 6, 0.02), {"x": np.float64(0.0)})
        h2 = rt.submit(chain_wf("beta", 6, 0.02), {"x": np.float64(10.0)})
        per_thread = [[], []]
        errs = []

        def reader(out):
            try:
                while not (h1.done() and h2.done()):
                    out.append(rt.introspect(timeout=10))
            except Exception as e:   # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=reader, args=(out,))
                   for out in per_thread]
        for t in threads:
            t.start()
        h1.result(60)
        h2.result(60)
        for t in threads:
            t.join(30)
        assert not errs
        assert any(per_thread), "no snapshots taken while runs were live"
        # snapshot order is only meaningful per reader thread (each call
        # blocks until the driver answers, so a thread's sequence is the
        # driver's order; across threads the appends interleave)
        for snaps in per_thread:
            completed_seen = {}              # (run_id, step) -> True
            for snap in snaps:
                for run in snap["runs"]:
                    states = run["steps"]
                    counts = {"pending": 0, "ready": 0, "inflight": 0,
                              "completed": 0}
                    for nm, st in states.items():
                        counts[st] += 1
                        if completed_seen.get((run["run_id"], nm)):
                            assert st == "completed", \
                                f"{nm} regressed from completed to {st}"
                        if st == "completed":
                            completed_seen[(run["run_id"], nm)] = True
                    assert sum(counts.values()) == len(states)
                    assert counts["completed"] == run["completed"]
        # post-run: the final snapshot renders (emtop's code path) and
        # survives a JSON round-trip (emtop's file input path)
        final = rt.introspect()
        text = render(json.loads(json.dumps(final)))
        assert "LANES" in text and "METRICS" in text


def test_introspect_after_close_and_disabled_telemetry():
    rt = EmeraldRuntime(emerald(), max_workers=2, telemetry=False)
    try:
        h = rt.submit(chain_wf("quiet", 3), {"x": np.float64(1.0)})
        assert float(h.result(30)["y3"]) == 4.0
        assert rt.tracer.spans() == [], "telemetry=False must trace nothing"
        assert rt.metrics.snapshot() == {}
        snap = rt.introspect()
        assert snap["runtime"]["telemetry"] is False
    finally:
        rt.close()
    # driver gone: introspect falls back to the direct read
    snap = rt.introspect(timeout=0.5)
    assert snap["runtime"]["closed"] is True


# ------------------------------------------------------------ trace export
def test_chrome_trace_export_validates(tmp_path):
    with EmeraldRuntime(emerald(), max_workers=2) as rt:
        h = rt.submit(chain_wf("exported", 3), {"x": np.float64(0.0)})
        h.result(30)
        path = rt.export_trace(str(tmp_path / "trace.json"),
                               run_id=h.trace_id)
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    ms = [e for e in events if e["ph"] == "M"]
    assert xs and ms
    names = {e["name"] for e in xs}
    # "place" appears only under a locality policy; this run exercises
    # the default should_offload path
    assert {"run", "dispatch", "exec", "install", "complete"} <= names
    span_ids = set()
    for e in xs:
        assert isinstance(e["ts"], (int, float))
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        a = e["args"]
        assert a["trace_id"] == h.trace_id
        assert a["span_id"] not in span_ids, "span ids must be unique"
        span_ids.add(a["span_id"])
    for e in xs:
        assert e["args"]["parent_id"] == 0 \
            or e["args"]["parent_id"] in span_ids
    # every (pid, tid) row is named by an M thread_name record
    named = {(e["pid"], e["tid"]) for e in ms if e["name"] == "thread_name"}
    assert {(e["pid"], e["tid"]) for e in xs} <= named
    # one track per lane and one per run on separate tids
    tracks = {e["args"]["name"] for e in ms if e["name"] == "thread_name"}
    assert "driver" in tracks and f"run:{h.trace_id}" in tracks


def test_chrome_trace_sanitises_non_json_attrs():
    tr = Tracer()
    tr.add_span("t", "x", wall_now(), 0.01, obj=object(), ok=1)
    doc = chrome_trace(tr.spans())
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"][0]
    json.dumps(doc)                         # must be serialisable
    assert isinstance(x["args"]["obj"], str) and x["args"]["ok"] == 1


# ----------------------------------------------------------- event schema
def test_every_emit_call_site_is_registered(tmp_path):
    """Wrapper over the promoted self-lint rules (repro.analysis.selfcheck,
    also reachable as ``emlint --self``): every emit( kind and dotted
    metric name in src/ must be registered in its schema/catalogue."""
    from repro.analysis import selfcheck
    findings = selfcheck.check_source(SRC_DIR)
    assert not findings, "\n".join(str(f) for f in findings)
    # canary: the lint actually detects drift (else a regex rot would
    # make the assertion above pass vacuously)
    bad = tmp_path / "drift.py"
    bad.write_text('run.emit("bogus_kind", s)\n'
                   'metrics.inc("bogus.metric")\n')
    rules = {f.rule for f in selfcheck.check_source(str(tmp_path))}
    assert rules == {"L001", "L002"}


def test_dynamic_metric_names_are_linted(tmp_path):
    """Drift canary for the dynamic-name extension: dotted metric / event
    names built with f-strings or ``+`` concatenation are checked against
    the registries as prefix patterns, not skipped."""
    from repro.analysis.selfcheck import check_snippet
    # a dynamic pattern whose prefix matches no catalogued metric drifts
    bad = ('def f(metrics, run, k):\n'
           '    metrics.inc(f"nosuch.{k}_total")\n'
           '    run.emit(f"bogus_{k}", object())\n')
    rules = {f.rule for f in check_snippet(bad)}
    assert rules == {"L001", "L002"}
    # patterns under a registered family are accepted, either spelling
    ok = ('def f(metrics, kind):\n'
          '    metrics.inc(f"emcheck.{kind}")\n'
          '    metrics.inc("fanout." + kind)\n')
    assert check_snippet(ok) == []
    # and the same contract holds through the file-tree entry point
    drift = tmp_path / "dyn.py"
    drift.write_text('def f(metrics, k):\n'
                     '    metrics.observe(f"nosuch.{k}.seconds", 1.0)\n')
    from repro.analysis import selfcheck
    assert {f.rule for f in selfcheck.check_source(str(tmp_path))} == {"L002"}


def test_validate_event():
    validate_event("offload", {"seconds": 0.1, "bytes_in": 3})
    with pytest.raises(ValueError, match="unregistered"):
        validate_event("nonsense", {})
    with pytest.raises(ValueError, match="missing required"):
        validate_event("offload", {})
    with pytest.raises(ValueError, match="undeclared"):
        validate_event("offload", {"seconds": 0.1, "surprise": 1})


def test_runtime_events_conform_to_schema_and_carry_wall_clock():
    """Satellite (a): every Event now records a wall-clock timestamp
    (cross-process comparable) next to the monotonic one, and live event
    payloads validate against the registered schema."""
    t_before = time.time()
    with EmeraldRuntime(emerald(), max_workers=2) as rt:
        h = rt.submit(chain_wf("walled", 3, 0.01), {"x": np.float64(0.0)})
        h.result(30)
        events = list(h.events)
    t_after = time.time()
    assert events
    for e in events:
        validate_event(e.kind, e.info)
        assert t_before - 1.0 <= e.t_wall <= t_after + 1.0, \
            (e.kind, e.t_wall)
    # wall ordering must agree with monotonic ordering within the run
    ts = [(e.t, e.t_wall) for e in events]
    for (t0, w0), (t1, w1) in zip(ts, ts[1:]):
        if t1 > t0:
            assert w1 >= w0 - 1e-3
