"""Property-based tests for sharding resolution invariants."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import PRESETS, resolve
from tests.test_sharding import FakeMesh

MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16}),
          FakeMesh({"data": 4, "model": 2})]

LOGICAL = [None, "embed", "ff", "vocab", "heads", "kv_heads", "experts",
           "act_batch", "act_ff", "act_kv_seq", "ssm_inner", "moe_ff"]

dims = st.lists(
    st.tuples(st.sampled_from(LOGICAL), st.integers(1, 8192)),
    min_size=1, max_size=5)


@settings(max_examples=300, deadline=None)
@given(dims, st.sampled_from(list(PRESETS)), st.integers(0, 2))
def test_resolve_invariants(dims_, preset, mesh_i):
    mesh = MESHES[mesh_i]
    axes = tuple(d[0] for d in dims_)
    shape = tuple(d[1] for d in dims_)
    spec = resolve(PRESETS[preset], axes, shape, mesh)
    # 1. spec rank never exceeds tensor rank
    assert len(spec) <= len(shape)
    used = []
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for n in names:
            assert n in mesh.shape          # 2. only real mesh axes
            used.append(n)
            prod *= mesh.shape[n]
        # 3. divisibility always holds
        assert shape[i] % prod == 0, (axes, shape, spec)
    # 4. each mesh axis used at most once
    assert len(used) == len(set(used))
