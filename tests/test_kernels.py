"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret mode — this container is CPU-only; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention_kernel_call
from repro.kernels.mamba_scan import ref as ms_ref
from repro.kernels.mamba_scan.kernel import selective_scan_fwd

RNG = np.random.default_rng(42)


def _mk(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 2, 2, 128, 128),      # MHA
    (2, 4, 2, 256, 128),      # GQA 2:1
    (1, 8, 2, 128, 128),      # GQA 4:1
    (1, 2, 1, 384, 128),      # non-pow2 block count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, KV, S, D, dtype, causal):
    q = _mk((B, S, H, D), dtype)
    k = _mk((B, S, KV, D), dtype)
    v = _mk((B, S, KV, D), dtype)
    scale = D ** -0.5
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    out = flash_attention_fwd(qt, kt, vt, scale=scale, causal=causal,
                              interpret=True)
    ref = fa_ref.attention_ref(q, k, v, scale=scale, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.transpose(0, 2, 1, 3), np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_padding_wrapper():
    """Seq not a multiple of the block, head dim not lane-aligned."""
    B, S, H, KV, D = 1, 200, 2, 1, 96
    q, k, v = _mk((B, S, H, D), jnp.float32), _mk((B, S, KV, D), jnp.float32), \
        _mk((B, S, KV, D), jnp.float32)
    out = flash_attention_kernel_call(q, k, v, scale=D ** -0.5, causal=True,
                                      interpret=True)
    ref = fa_ref.attention_ref(q, k, v, scale=D ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_kv_len_mask():
    B, S, H, D = 1, 128, 2, 128
    q, k, v = (_mk((B, S, H, D), jnp.float32) for _ in range(3))
    out = flash_attention_fwd(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), scale=0.1, causal=False,
                              kv_len=70, interpret=True)
    ref = fa_ref.attention_ref(q, k, v, scale=0.1, causal=False, kv_len=70)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.transpose(0, 2, 1, 3)), atol=2e-5)


def test_chunked_ref_matches_direct():
    B, S, H, KV, D = 2, 320, 4, 2, 64
    q, k, v = _mk((B, S, H, D), jnp.float32), _mk((B, S, KV, D), jnp.float32), \
        _mk((B, S, KV, D), jnp.float32)
    for causal in (True, False):
        a = fa_ref.attention_ref(q, k, v, scale=0.3, causal=causal)
        b = fa_ref.attention_ref_chunked(q, k, v, scale=0.3, causal=causal,
                                         q_chunk=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# Mamba selective scan
# ---------------------------------------------------------------------------

def _scan_args(Bt, L, di, N, dtype):
    x = _mk((Bt, L, di), dtype)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (Bt, L, di)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, N)), jnp.float32)
    B = _mk((Bt, L, N), dtype)
    C = _mk((Bt, L, N), dtype)
    D = jnp.asarray(RNG.normal(size=(di,)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(Bt, di, N)), jnp.float32)
    return x, dt, A, B, C, D, h0


@pytest.mark.parametrize("Bt,L,di,N,chunk,block_d", [
    (1, 64, 32, 8, 16, 32),
    (2, 128, 64, 16, 32, 32),
    (2, 96, 48, 16, 32, 16),      # L not a power of two
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_sweep(Bt, L, di, N, chunk, block_d, dtype):
    args = _scan_args(Bt, L, di, N, dtype)
    y, h = selective_scan_fwd(*args, chunk=chunk, block_d=block_d,
                              interpret=True)
    y_ref, h_ref = ms_ref.selective_scan_ref(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4)


def test_mamba_step_matches_scan():
    """Decode single-step recurrence == scan applied one token at a time."""
    Bt, L, di, N = 2, 8, 16, 4
    x, dt, A, B, C, D, h0 = _scan_args(Bt, L, di, N, jnp.float32)
    y_ref, h_ref = ms_ref.selective_scan_ref(x, dt, A, B, C, D, h0, chunk=8)
    h = h0
    ys = []
    for t in range(L):
        y_t, h = ms_ref.selective_step_ref(x[:, t], dt[:, t], A, B[:, t],
                                           C[:, t], D, h)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_mamba_chunk_invariance():
    """Chunk size must not change results (cross-chunk carry correctness)."""
    args = _scan_args(1, 64, 16, 8, jnp.float32)
    y1, h1 = ms_ref.selective_scan_ref(*args, chunk=8)
    y2, h2 = ms_ref.selective_scan_ref(*args, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
