"""Cross-pod compressed gradient sync: correctness + wire-format proof.

Runs in a subprocess with 8 fake devices (mesh 2x2x2) — tests in the main
process must keep the default single device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# The explicit-mesh API (jax.sharding.AxisType / jax.set_mesh) is newer
# than this container's jax; the subprocess scripts below require it.
import jax as _jax
needs_axis_type = pytest.mark.skipif(
    not hasattr(_jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType (explicit-mesh API)")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding, AxisType

    from repro.configs import get_config
    from repro.configs.base import RunConfig, ShapeProfile, reduced
    from repro.data.pipeline import SyntheticLMData
    from repro.models.model_zoo import Model
    from repro.optim.grad_compress import multipod_train_step, sync_grads

    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    run = RunConfig(model=cfg, shape=ShapeProfile("t", 16, 8, "train"),
                    remat="none")
    model = Model(run)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = model.opt_init(params)
    batch = SyntheticLMData(cfg, run.shape).batch(0)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
    results = {{}}
    hlos = {{}}
    with jax.set_mesh(mesh):
        for method in ("none", "bf16", "int8"):
            step = jax.jit(multipod_train_step(model, mesh, method))
            p2, o2, m = step(params, opt, batch)
            results[method] = float(m["loss"])
            hlos[method] = step.lower(params, opt, batch).compile().as_text()

    # baseline: plain single-jit train step on the same global batch
    ref_p, ref_o, ref_m = jax.jit(model.train_step)(params, opt, batch)
    ref = float(ref_m["loss"])
    for method, loss in results.items():
        assert abs(loss - ref) < 1e-3, (method, loss, ref)
    assert "all-gather" in hlos["int8"]
    assert any(("s8[" in l and "all-gather" in l)
               for l in hlos["int8"].splitlines()), "no int8 wire traffic"

    from repro.launch.hlo_analysis import collective_bytes
    b_none = collective_bytes(hlos["none"])["total"]
    b_int8 = collective_bytes(hlos["int8"])["total"]
    print("WIRE none=%d int8=%d" % (b_none, b_int8))
    print("GRAD_COMPRESS_OK")
""")


@needs_axis_type
@pytest.mark.slow
def test_multipod_compressed_sync_subprocess():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT.format(src=src)],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "GRAD_COMPRESS_OK" in r.stdout


def test_sync_grads_math_single_axis():
    """int8 quantize/dequant roundtrip error is bounded by scale/2."""
    import jax.numpy as jnp
    import numpy as np
    from repro.optim.grad_compress import quantize_int8
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 0.01,
                    jnp.float32)
    q, scale = quantize_int8(g)
    deq = q.astype(jnp.float32) * scale
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.51
    assert q.dtype == jnp.int8
