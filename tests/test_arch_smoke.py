"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates a REDUCED same-family config and runs one train step
+ prefill + one decode step on CPU, asserting output shapes and no NaNs.
Full configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config

# compile-heavy: excluded from the smoke fast lane (-m "not slow"),
# still part of tier-1 (plain pytest runs everything)
pytestmark = pytest.mark.slow
from repro.configs.base import RunConfig, ShapeProfile, reduced
from repro.data.pipeline import SyntheticLMData
from repro.models.model_zoo import Model

S, B = 32, 2


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = reduced(get_config(arch))
    shape = ShapeProfile("smoke", S, B, "train")
    run = RunConfig(model=cfg, shape=shape, remat="none")
    model = Model(run)
    params = model.init_params(jax.random.PRNGKey(0))
    data = SyntheticLMData(cfg, shape)
    return arch, cfg, run, model, params, data


def test_train_step(arch_setup):
    arch, cfg, run, model, params, data = arch_setup
    opt = model.opt_init(params)
    p, o, metrics = jax.jit(model.train_step)(params, opt, data.batch(0))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: NaN loss"
    assert loss > 0
    # params actually changed
    diff = sum(float(jnp.sum(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)))
    assert diff > 0, f"{arch}: optimizer made no update"
    # shapes preserved through the update
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_prefill_and_decode(arch_setup):
    arch, cfg, run, model, params, data = arch_setup
    drun = RunConfig(model=cfg, shape=ShapeProfile("d", S, B, "decode"),
                     remat="none")
    dmodel = Model(drun)
    cache = dmodel.init_cache()
    batch = data.batch(0)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    if "tokens" in pb:
        pb["tokens"] = pb["tokens"][:, :S // 2]
    logits, cache = jax.jit(dmodel.prefill)(params, pb, cache)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN prefill"
    tok = jnp.argmax(logits, -1)
    logits2, cache = jax.jit(dmodel.decode_step)(params, tok, cache)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: NaN decode"


def test_full_config_constructs_abstractly():
    """Full-size templates build + count params without allocation."""
    import math
    expected_scale = {
        "falcon-mamba-7b": 7e9, "llama3.2-3b": 3e9, "tinyllama-1.1b": 1.1e9,
        "qwen1.5-32b": 32e9, "minicpm3-4b": 4e9, "internvl2-1b": 0.6e9,
        "deepseek-v3-671b": 671e9, "qwen2-moe-a2.7b": 14e9,
        "jamba-v0.1-52b": 52e9, "seamless-m4t-medium": 1.2e9,
    }
    from repro.models.params import count_params
    from repro.models.transformer import model_template
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = count_params(model_template(cfg))
        lo, hi = expected_scale[arch] * 0.5, expected_scale[arch] * 2.2
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params out of band"


def test_decode_prefill_consistency_dense():
    """Greedy decode continuation matches a fresh prefill over the longer
    sequence (exact cache correctness) for a dense arch."""
    cfg = reduced(get_config("tinyllama-1.1b"))
    run = RunConfig(model=cfg, shape=ShapeProfile("d", S, B, "decode"),
                    remat="none")
    model = Model(run)
    params = model.init_params(jax.random.PRNGKey(1))
    data = SyntheticLMData(cfg, ShapeProfile("t", S, B, "train"))
    toks = data.batch(0)["tokens"][:, :12]
    logits, cache = jax.jit(model.prefill)(params, {"tokens": toks},
                                           model.init_cache())
    tok = jnp.argmax(logits, -1)
    seq = [tok]
    dstep = jax.jit(model.decode_step)
    for _ in range(3):
        logits, cache = dstep(params, tok, cache)
        tok = jnp.argmax(logits, -1)
        seq.append(tok)
    full = jnp.concatenate([toks, jnp.stack(seq[:-1], 1)], 1)
    logits_ref, _ = jax.jit(model.prefill)(params, {"tokens": full},
                                           model.init_cache())
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               atol=2e-4)


def test_decode_prefill_consistency_ssm():
    """Same consistency check through the Mamba state/conv caches."""
    cfg = reduced(get_config("falcon-mamba-7b"))
    run = RunConfig(model=cfg, shape=ShapeProfile("d", S, B, "decode"),
                    remat="none", ssm_chunk=8)
    model = Model(run)
    params = model.init_params(jax.random.PRNGKey(1))
    data = SyntheticLMData(cfg, ShapeProfile("t", S, B, "train"))
    toks = data.batch(0)["tokens"][:, :12]
    logits, cache = jax.jit(model.prefill)(params, {"tokens": toks},
                                           model.init_cache())
    tok = jnp.argmax(logits, -1)
    logits2, cache = jax.jit(model.decode_step)(params, tok, cache)
    full = jnp.concatenate([toks, tok[:, None]], 1)
    logits_ref, _ = jax.jit(model.prefill)(params, {"tokens": full},
                                           model.init_cache())
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(logits_ref),
                               atol=2e-3, rtol=2e-3)
