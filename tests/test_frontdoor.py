"""Serving front door: admission parking, continuous batching, and
SLO preemption.

Covers the acceptance surface of the front-door refactor:

  * park-instead-of-refuse — over-capacity submissions return a
    ``parked`` handle immediately and drain oldest-deadline-first as
    run slots free; ``queue_full`` is the only hard refusal,
  * symmetric release — refused/parked/finalized paths all leave the
    reservation table and the run-slot count at zero (the regression
    the refuse path used to leak), hammered concurrently,
  * cancel-while-parked and close-with-parked semantics,
  * :class:`BatchCoalescer` — window / full / deadline flush reasons,
    per-key isolation, 1/k fair-share charging, error fan-out,
  * broker checkpoint-abort — ``preempt_longest`` requeues the victim
    attempt-free and the task still completes,
  * the driver's SLO guard fires exactly once per threatened run,
  * explorer ``frontdoor`` model: clean is exhaustively hazard-free,
    planted bugs surface H125/H126.
"""
import threading
import time

import numpy as np
import pytest

from repro.analysis import explorer
from repro.core import (AdmissionRefused, CostModel, EmeraldRuntime, MDSS,
                        MigrationManager, RunCancelled, RuntimeClosed,
                        Workflow, default_tiers)
from repro.core.batching import BatchCoalescer, CoalesceError


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def sleeper_wf(name, seconds=0.0):
    def fn(x):
        if seconds:
            time.sleep(seconds)
        return {"y": np.float64(float(x) + 1.0)}
    wf = Workflow(name)
    wf.var("x")
    wf.step("s", fn, inputs=("x",), outputs=("y",), remotable=False,
            jax_step=False)
    return wf


# ------------------------------------------------------------- admission
def test_park_drains_oldest_deadline_first():
    with EmeraldRuntime(emerald(), max_workers=2, max_active_runs=1,
                        park_limit=4, telemetry=False) as rt:
        head = rt.submit(sleeper_wf("head", 0.25), {"x": 0.0})
        # loose deadline parked first, tight deadline second: admission
        # must reorder them (oldest deadline first), not FIFO
        loose = rt.submit(sleeper_wf("loose"), {"x": 10.0}, park=True,
                          deadline_s=60.0)
        tight = rt.submit(sleeper_wf("tight"), {"x": 20.0}, park=True,
                          deadline_s=1.0)
        assert loose.state == "parked" and tight.state == "parked"
        snap = rt.introspect()["frontdoor"]
        assert snap["depth"] == 2 and snap["queue_limit"] == 4
        assert [p["run_id"] for p in snap["parked"]] == \
            [tight.run_id, loose.run_id]           # deadline order

        assert head.result(10)["y"] == 1.0
        assert tight.result(10)["y"] == 21.0
        assert loose.result(10)["y"] == 11.0
        assert tight.state == "done" and loose.state == "done"
        admit_t = {}
        for h in (tight, loose):
            (ev,) = [e for e in h.events if e.kind == "admit"]
            admit_t[h.run_id] = ev.t
            assert any(e.kind == "park" for e in h.events)
        assert admit_t[tight.run_id] <= admit_t[loose.run_id]
        assert rt.admitted_total == 2 and rt.parked_total == 2


def test_queue_full_is_the_only_refusal_and_release_is_symmetric():
    with EmeraldRuntime(emerald(), max_workers=2, max_active_runs=1,
                        park_limit=2, telemetry=False) as rt:
        head = rt.submit(sleeper_wf("head", 0.4), {"x": 0.0})
        parked = [rt.submit(sleeper_wf(f"p{i}"), {"x": float(i)}, park=True)
                  for i in range(2)]
        # the head run is still sleeping, so the queue is full now
        with pytest.raises(AdmissionRefused, match="queue_full"):
            rt.submit(sleeper_wf("overflow"), {"x": 9.0}, park=True)
        # non-parking submission over the run-slot cap refuses outright
        with pytest.raises(AdmissionRefused, match="run slots"):
            rt.submit(sleeper_wf("refused"), {"x": 9.0})
        head.result(10)
        for i, h in enumerate(parked):
            assert h.result(10)["y"] == i + 1.0
        # every path released its state: nothing reserved, nothing live
        with rt._runs_lock:
            assert not rt._reserved and rt._live == 0 and not rt._parked


def test_park_validation_runs_before_queueing():
    from repro.analysis import WorkflowRejected
    with EmeraldRuntime(emerald(), max_workers=2, max_active_runs=1,
                        telemetry=False) as rt:
        head = rt.submit(sleeper_wf("head", 0.2), {"x": 0.0})
        bad = Workflow("bad")
        bad.var("missing")          # declared but never provided: W002
        bad.step("s", lambda missing: {}, inputs=("missing",),
                 outputs=("y",), jax_step=False)
        with pytest.raises(WorkflowRejected):
            rt.submit(bad, {}, park=True)
        # the rejected submission never landed in the queue
        assert rt.introspect()["frontdoor"]["depth"] == 0
        head.result(10)
        with rt._runs_lock:
            assert not rt._reserved and rt._live == 0


def test_cancel_while_parked():
    with EmeraldRuntime(emerald(), max_workers=2, max_active_runs=1,
                        telemetry=False) as rt:
        head = rt.submit(sleeper_wf("head", 0.3), {"x": 0.0})
        h = rt.submit(sleeper_wf("victim"), {"x": 1.0}, park=True)
        assert h.state == "parked"
        h.cancel()
        with pytest.raises(RunCancelled):
            h.result(10)
        assert h.state == "cancelled"
        head.result(10)
        assert rt.admitted_total == 0


def test_close_fails_parked_with_runtime_closed():
    rt = EmeraldRuntime(emerald(), max_workers=2, max_active_runs=1,
                        telemetry=False)
    head = rt.submit(sleeper_wf("head", 0.2), {"x": 0.0})
    h = rt.submit(sleeper_wf("stuck"), {"x": 1.0}, park=True)
    head.result(10)
    rt.close()
    if h.state == "done":         # admitted before close won the race
        assert h.result(0)["y"] == 2.0
    else:
        with pytest.raises(RuntimeClosed):
            h.result(10)


def test_concurrent_park_refuse_finalize_hammer():
    """Park, refuse, and finalize racing from many threads must never
    leak a reservation or a run slot (the symmetric-release bugfix)."""
    with EmeraldRuntime(emerald(), max_workers=4, max_active_runs=2,
                        park_limit=3, telemetry=False) as rt:
        handles, refused = [], []
        lock = threading.Lock()

        def tenant(i):
            for j in range(4):
                try:
                    h = rt.submit(sleeper_wf(f"t{i}.{j}", 0.01),
                                  {"x": float(i)}, park=(j % 2 == 0),
                                  deadline_s=5.0)
                    with lock:
                        handles.append(h)
                    if j % 2:
                        h.result(30)
                except AdmissionRefused:
                    with lock:
                        refused.append((i, j))

        threads = [threading.Thread(target=tenant, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for h in handles:
            assert "y" in h.result(30)
        deadline = time.time() + 10.0
        while time.time() < deadline:
            with rt._runs_lock:
                if not rt._reserved and rt._live == 0 and not rt._parked:
                    break
            time.sleep(0.01)
        with rt._runs_lock:
            assert not rt._reserved and rt._live == 0 and not rt._parked


# -------------------------------------------------------------- coalescer
def test_coalescer_window_flush_and_rows():
    got = []

    def fuse(key, stacked, k):
        got.append((key, stacked.shape, k))
        return stacked * 2

    c = BatchCoalescer(fuse, window_s=0.03, max_batch=8)
    try:
        tickets = [c.submit("k", np.full((2,), i)) for i in range(3)]
        rows = [t.result(5.0) for t in tickets]
        assert len(got) == 1 and got[0] == ("k", (3, 2), 3)
        for i, row in enumerate(rows):
            np.testing.assert_array_equal(row, np.full((2,), i * 2))
        (flush,) = [e for e in c.events if e.kind == "flush"]
        assert flush.info["reason"] == "window" and flush.info["batch"] == 3
    finally:
        c.close()


def test_coalescer_full_flush_is_immediate():
    c = BatchCoalescer(lambda key, stacked, k: stacked, window_s=10.0,
                       max_batch=4)
    try:
        t0 = time.perf_counter()
        tickets = [c.submit("k", np.float64(i)) for i in range(4)]
        for t in tickets:
            t.result(5.0)
        assert time.perf_counter() - t0 < 5.0      # did not wait the window
        (flush,) = [e for e in c.events if e.kind == "flush"]
        assert flush.info["reason"] == "full"
    finally:
        c.close()


def test_coalescer_deadline_forces_early_flush():
    c = BatchCoalescer(lambda key, stacked, k: stacked, window_s=30.0,
                       max_batch=8)
    try:
        t = c.submit("k", np.float64(1.0), deadline_s=0.05)
        t.result(5.0)
        (flush,) = [e for e in c.events if e.kind == "flush"]
        assert flush.info["reason"] == "deadline"
        assert flush.info["waited_s"] < 5.0
    finally:
        c.close()


def test_coalescer_keys_never_fuse_and_charges_are_fair():
    shares = []
    c = BatchCoalescer(lambda key, stacked, k: stacked, window_s=0.02,
                       max_batch=8)
    try:
        a = [c.submit("ka", np.float64(i), charge=shares.append)
             for i in range(3)]
        b = c.submit("kb", np.float64(9.0))
        for t in a:
            t.result(5.0)
        b.result(5.0)
        assert c.flushes == 2                       # one per key
        # the three ka participants each paid the same 1/3 share
        assert len(shares) == 3 and len({round(s, 12) for s in shares}) == 1
    finally:
        c.close()


def test_coalescer_error_fans_out_to_every_ticket():
    def boom(key, stacked, k):
        raise ValueError("fused failure")

    c = BatchCoalescer(boom, window_s=0.02, max_batch=8)
    try:
        tickets = [c.submit("k", np.float64(i)) for i in range(2)]
        for t in tickets:
            with pytest.raises(CoalesceError, match="fused failure"):
                t.result(5.0)
    finally:
        c.close()


# ------------------------------------------------------------- preemption
def test_broker_preempt_longest_is_attempt_free():
    from repro.cloud import Fabric
    with Fabric(workers=1) as fabric:
        t = fabric.broker.submit(step="sleep", kwargs={"seconds": 1.0},
                                 preemptible=True)
        deadline = time.time() + 10.0
        while time.time() < deadline and not fabric.broker._inflight:
            time.sleep(0.01)
        victim = fabric.broker.preempt_longest()
        assert victim is t
        assert t.preempted == 1
        assert fabric.broker.tasks_preempted == 1
        # the requeued task completes on the replacement worker, and the
        # preempted placement was refunded: exactly one charged attempt
        t.result(60)
        assert t.attempts == 1


def test_broker_preempt_longest_skips_non_preemptible():
    from repro.cloud import Fabric
    with Fabric(workers=1) as fabric:
        fabric.broker.submit(step="sleep", kwargs={"seconds": 0.3})
        time.sleep(0.05)
        assert fabric.broker.preempt_longest() is None


def test_slo_guard_fires_once_per_threatened_run():
    class FakeTask:
        task_id = 7
        step = "bat"

    class FakeBroker:
        def __init__(self):
            self.calls = 0

        def preempt_longest(self):
            self.calls += 1
            return FakeTask()

    class FakeFabric:
        def __init__(self):
            self.broker = FakeBroker()

    with EmeraldRuntime(emerald(), max_workers=2, max_active_runs=1,
                        telemetry=False) as rt:
        rt._fabric = FakeFabric()
        head = rt.submit(sleeper_wf("head", 0.3), {"x": 0.0})
        h = rt.submit(sleeper_wf("urgent"), {"x": 1.0}, park=True,
                      deadline_s=0.05, slo_ms=10_000.0)
        assert h.result(10)["y"] == 2.0
        head.result(10)
        assert rt._fabric.broker.calls == 1      # once, despite many ticks
        assert any(e.kind == "preempt" for e in h.events)


# ---------------------------------------------------------------- emcheck
def test_frontdoor_model_clean_is_exhaustively_hazard_free():
    res = explorer.explore(explorer.build_model("frontdoor"))
    assert res.exhaustive and res.hazard_count == 0


def test_frontdoor_model_finds_parked_starvation():
    res = explorer.explore(
        explorer.build_model("frontdoor", bugs=["parked_starved"]),
        max_hazards=1)
    assert "H125" in res.hazard_rules()


def test_frontdoor_model_finds_preemption_burning_progress():
    res = explorer.explore(
        explorer.build_model("frontdoor", bugs=["preempt_lost_step"]),
        max_hazards=1)
    assert "H126" in res.hazard_rules()


def test_frontdoor_reproducer_roundtrip(tmp_path):
    model = explorer.build_model("frontdoor", bugs=["parked_starved"])
    res = explorer.explore(model, max_hazards=1)
    sched, findings = res.hazards[0]
    small = explorer.minimize(model, sched)
    path = str(tmp_path / "repro.json")
    explorer.save_reproducer(path, model, small, findings)
    doc = explorer.load_reproducer(path)
    replayed, retriggered = explorer.replay_reproducer(doc)
    assert retriggered and "H125" in {f.rule for f in replayed}
