"""Model-layer unit tests: MoE dispatch equivalence, vocab/head padding,
MLA absorbed decode, attention oracles, stage compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import (ATTN_DENSE, ATTN_MOE, MAMBA_DENSE, MAMBA_MOE,
                                MAMBA_ONLY, ModelConfig)
from repro.models import attention as A
from repro.models import moe as M
from repro.models.layers import xent_loss
from repro.models.params import init_params
from repro.parallel.sharding import get_rules
from tests.conftest import tiny_dense

RULES = get_rules("fsdp")


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
                n_kv_heads=0, d_ff=0, vocab_size=16, n_experts=8,
                experts_per_token=2, moe_d_ff=8, dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("E,K,shared", [(8, 2, 0), (8, 2, 1), (4, 1, 2),
                                        (6, 3, 0)])
def test_moe_sort_matches_gshard(E, K, shared):
    cfg = moe_cfg(n_experts=E, experts_per_token=K, n_shared_experts=shared)
    p = init_params(M.moe_template(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, a1 = M.moe(cfg, p, x, RULES)
    y2, a2 = M.moe_gshard(cfg, p, x, RULES)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert float(abs(a1 - a2)) < 1e-7


def test_moe_capacity_drops_tokens_consistently():
    """With capacity binding, both impls drop the same assignments."""
    cfg = moe_cfg(n_experts=2, experts_per_token=2)   # forces congestion
    p = init_params(M.moe_template(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model))
    y1, _ = M.moe(cfg, p, x, RULES)
    y2, _ = M.moe_gshard(cfg, p, x, RULES)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_moe_grad_finite():
    cfg = moe_cfg(n_shared_experts=1)
    p = init_params(M.moe_template(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = M.moe(cfg, p, x, RULES)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_moe_aux_loss_uniform_router_is_one():
    """With perfectly uniform routing, Switch aux = weight * 1.0."""
    cfg = moe_cfg(router_aux_weight=1.0)
    p = init_params(M.moe_template(cfg), jax.random.PRNGKey(0), "float32")
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, aux = M.moe(cfg, p, x, RULES)
    # f_e sums to K (each token routed K times): aux = E * sum(f_e*p_e)
    assert abs(float(aux) - cfg.experts_per_token) < 0.3


# ---------------------------------------------------------------------------
# Padding
# ---------------------------------------------------------------------------

def test_vocab_padding_loss_matches_unpadded():
    cfg_pad = tiny_dense(vocab_size=100, pad_multiple=8)   # -> 104
    assert cfg_pad.vocab_padded == 104
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 104))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 100)
    loss_pad = xent_loss(cfg_pad, logits, labels)
    cfg_nopad = tiny_dense(vocab_size=100, pad_multiple=1)
    loss_ref = xent_loss(cfg_nopad, logits[..., :100], labels)
    np.testing.assert_allclose(float(loss_pad), float(loss_ref), rtol=1e-6)


def test_head_padding_counts():
    cfg = tiny_dense(n_heads=6, n_kv_heads=2, pad_multiple=4)
    assert cfg.heads_padded == 8
    assert cfg.kv_heads_padded == 2        # 2 divides 8
    assert cfg.q_group == 4
    cfg2 = tiny_dense(n_heads=40, n_kv_heads=40, pad_multiple=16)
    assert cfg2.heads_padded == 48 and cfg2.kv_heads_padded == 48


def test_padded_heads_with_zero_wo_contribute_nothing():
    cfg = tiny_dense(n_heads=6, n_kv_heads=2, pad_multiple=4)
    p = init_params(A.gqa_template(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    # zero the padded heads' output rows; then their wq values are irrelevant
    wo = p["wo"].at[6:].set(0.0)
    p1 = dict(p, wo=wo)
    y1, _ = A.gqa_full(cfg, p1, x, RULES)
    p2 = dict(p1, wq=p1["wq"].at[:, 6:, :].set(123.0))
    y2, _ = A.gqa_full(cfg, p2, x, RULES)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------

def mla_cfg():
    return tiny_dense(attn_type="mla", q_lora_rank=16, kv_lora_rank=8,
                      qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8)


def test_mla_absorbed_decode_matches_full():
    """Absorbed-latent decode == expanded full attention at the last pos."""
    cfg = mla_cfg()
    p = init_params(A.mla_template(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model)) * 0.3
    full_out, _ = A.mla_full(cfg, p, x, RULES)
    # decode path: prefill first 8 tokens, then decode token 9
    cache = {
        "ckv": jnp.zeros((2, 16, cfg.kv_lora_rank)),
        "krope": jnp.zeros((2, 16, cfg.qk_rope_head_dim)),
        "pos": jnp.int32(0),
    }
    _, cache = A.mla_full(cfg, p, x[:, :8], RULES, cache=cache)
    dec_out, _ = A.mla_decode(cfg, p, x[:, 8:9], cache, RULES)
    np.testing.assert_allclose(np.asarray(dec_out[:, 0]),
                               np.asarray(full_out[:, 8]), atol=2e-4)


# ---------------------------------------------------------------------------
# Stage compression (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 64), st.integers(0, 3))
def test_stage_compression_reconstructs_block_types(n_layers, kind):
    if kind == 0:
        cfg = tiny_dense(n_layers=n_layers)
    elif kind == 1:
        cfg = tiny_dense(n_layers=n_layers, family="moe", n_experts=4,
                         experts_per_token=2, moe_d_ff=8,
                         moe_layer_period=2, moe_layer_offset=1)
    elif kind == 2:
        cfg = tiny_dense(n_layers=n_layers, family="hybrid", ssm_state=4,
                         dt_rank=4, attn_layer_period=8, attn_layer_offset=4,
                         n_experts=4, experts_per_token=2, moe_d_ff=8,
                         moe_layer_period=2, moe_layer_offset=1)
    else:
        cfg = tiny_dense(n_layers=n_layers, family="moe", n_experts=4,
                         experts_per_token=2, moe_d_ff=8,
                         first_dense_layers=min(3, n_layers))
    rebuilt = []
    for pattern, reps in cfg.stages():
        rebuilt.extend(list(pattern) * reps)
    assert rebuilt == [cfg.block_type(i) for i in range(n_layers)]


def test_jamba_pattern():
    cfg = tiny_dense(family="hybrid", n_layers=32, ssm_state=4, dt_rank=4,
                     attn_layer_period=8, attn_layer_offset=4,
                     n_experts=4, experts_per_token=2, moe_d_ff=8,
                     moe_layer_period=2, moe_layer_offset=1)
    types = [cfg.block_type(i) for i in range(32)]
    assert types[4] == ATTN_DENSE and types[12] == ATTN_DENSE
    assert sum(1 for t in types if t in (ATTN_DENSE, ATTN_MOE)) == 4
    assert sum(1 for t in types if t in (MAMBA_MOE, ATTN_MOE)) == 16
