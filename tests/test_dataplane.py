"""Content-addressed data plane: chunked wire format edge cases, digest
dedup at the socket / fabric / MDSS layers, per-direction bandwidth in
placement, cross-run step memoization, budget-aware admission."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.cloud import Fabric
from repro.cloud.wire import (CHUNK_BYTES, ChannelStore, WireError,
                              content_digest, decode, encode, manifest_of,
                              recv_msg, send_msg)
from repro.core import (AdmissionRefused, CostModel, EmeraldRuntime, MDSS,
                        MigrationManager, Workflow, default_tiers)
from repro.core.scheduler import LocalityPolicy


# ----------------------------------------------------- wire format edges
@pytest.mark.parametrize("value", [{}, [], (), None, {"a": {}, "b": []}])
def test_wire_empty_pytrees(value):
    got = decode(encode(value))
    assert got == value and type(got) is type(value)


def test_wire_zero_length_buffers():
    val = {"z": np.empty((0, 3), np.float32), "w": np.zeros(0),
           "ok": np.arange(2)}
    got = decode(encode(val))
    assert got["z"].shape == (0, 3) and got["z"].dtype == np.float32
    assert got["w"].shape == (0,)
    np.testing.assert_array_equal(got["ok"], np.arange(2))


def test_wire_multi_chunk_frame():
    big = {"x": np.random.rand((3 * CHUNK_BYTES) // 8 + 17)}
    _, chunks = manifest_of(big["x"])
    assert len(chunks) == 4
    got = decode(encode(big))
    np.testing.assert_array_equal(got["x"], big["x"])
    got["x"][0] = -1.0                       # decoded arrays are writable


def test_wire_corrupted_digest_raises_not_hangs():
    data = bytearray(encode({"x": np.random.rand(4096)}, ChannelStore()))
    data[-3] ^= 0xFF                         # flip a payload byte
    with pytest.raises(WireError, match="digest mismatch"):
        decode(bytes(data), ChannelStore())


def test_wire_unknown_reference_raises():
    tx = ChannelStore()
    encode({"x": np.ones(4096)}, tx)         # primes the sender mirror
    ref_frame = encode({"x": np.ones(4096)}, tx)   # all references
    with pytest.raises(WireError, match="unknown chunk digest"):
        decode(ref_frame, ChannelStore())    # receiver never saw them


def test_wire_bad_magic_raises():
    with pytest.raises(WireError, match="magic"):
        decode(b"NOPE" + b"\x00" * 32)


def test_socket_dedup_second_send_is_metadata_only():
    a, b = socket.socketpair()
    sa, sb = ChannelStore(), ChannelStore()
    big = {"x": np.random.rand(1 << 18)}     # 2 MiB
    sizes = []

    def writer():
        sizes.append(send_msg(a, big, sa))
        sizes.append(send_msg(a, big, sa))

    t = threading.Thread(target=writer)
    t.start()
    v1, n1 = recv_msg(b, sb)
    v2, n2 = recv_msg(b, sb)
    t.join()
    a.close(), b.close()
    assert sizes == [n1, n2]
    np.testing.assert_array_equal(v2["x"], big["x"])
    assert n1 > big["x"].nbytes and n2 < 4096
    assert sa.saved_bytes >= big["x"].nbytes


# --------------------------------------------------------- fabric dedup
def test_fabric_warm_reship_and_task_kwargs_dedup():
    val = {"w": np.random.rand(1 << 18)}     # 2 MiB
    with Fabric(workers=1) as f:
        t1 = f.ship(val)
        t2 = f.ship(val)
        np.testing.assert_array_equal(t2.value["w"], val["w"])
        assert t1.bytes_sent > val["w"].nbytes
        assert t2.bytes_sent < 4096          # warm re-ship: metadata only
        # repeated task kwargs dedup the same way
        k1 = f.broker.submit(step="echo", kwargs={"p": val["w"]})
        k1.result(30)
        assert k1.bytes_sent < 4096          # chunks crossed in the ships


def test_fabric_dedup_off_ships_everything():
    val = {"w": np.random.rand(1 << 16)}     # 512 KiB
    with Fabric(workers=1, dedup=False) as f:
        f.ship(val)
        t2 = f.ship(val)
        assert t2.bytes_sent > val["w"].nbytes
        assert t2.bytes_received > val["w"].nbytes


# ----------------------------------------------------------- MDSS dedup
def make_mgr():
    tiers = default_tiers()
    cm = CostModel(tiers)
    return MigrationManager(tiers, MDSS(tiers, cost_model=cm), cm)


def test_mdss_cross_namespace_content_dedup():
    mgr = make_mgr()
    mdss = mgr.mdss
    big = np.random.rand(1 << 17)            # 1 MiB
    mdss.put("a/params", big, tier="local")
    moved = mdss.ensure(["a/params"], "cloud")
    assert moved == big.nbytes               # cold: full freight
    # same content under another namespace: the cloud tier already holds
    # every chunk, so the transfer obligation is zero
    mdss.put("b/params", big.copy(), tier="local")
    assert mdss.stale_bytes(["b/params"], "cloud") == 0
    assert mdss.ensure(["b/params"], "cloud") == 0
    assert mdss.has_latest("b/params", "cloud")
    # and dropping ONE namespace keeps the other's chunks resident
    mdss.drop_namespace("a")
    assert mdss.tier_chunk_stats("cloud")[0] > 0
    mdss.drop_namespace("b")
    assert mdss.tier_chunk_stats("cloud") == (0, 0)


def test_mdss_distinct_content_still_charged():
    mgr = make_mgr()
    mdss = mgr.mdss
    mdss.put("a/x", np.zeros(1 << 14), tier="local")
    mdss.ensure(["a/x"], "cloud")
    mdss.put("b/x", np.ones(1 << 14), tier="local")
    assert mdss.stale_bytes(["b/x"], "cloud") == (1 << 14) * 8


def test_placement_cost_charges_only_nonduplicate_bytes():
    mgr = make_mgr()
    cm, mdss = mgr.cost_model, mgr.mdss
    pol = LocalityPolicy(cm, mdss, "cloud")
    wf = Workflow("dp")
    wf.var("a")
    s = wf.step("s", lambda **kw: {"y": np.float64(0)}, inputs=("a",),
                outputs=("y",), remotable=True, jax_step=False)
    big = np.random.rand(1 << 17)
    mdss.put("other/warm", big, tier="cloud")    # same content, other URI
    mdss.put("a", big.copy(), tier="local")
    cm.stats_for("s").measured_s.update(local=0.001, cloud=0.001)
    d = pol.place(s)
    # the cloud tier holds a's content (under another entry): no staging
    # charge, so equal exec estimates make cloud win on the tie-break
    assert d.stale_bytes["cloud"] == 0 and d.offload


def test_namespace_reuse_does_not_resurrect_stale_digests():
    """drop_namespace resets versions to 1 on reuse: the manifest cache
    must not hand the OLD content's digest to the new data (a stale hit
    would collide memo keys across unrelated submissions)."""
    mgr = make_mgr()
    mdss = mgr.mdss
    mdss.put("exp/P", np.zeros(256), tier="local")
    d1 = mdss.content_digest("exp/P")
    mdss.drop_namespace("exp")
    mdss.put("exp/P", np.ones(256), tier="local")    # version 1 again
    assert mdss.content_digest("exp/P") != d1


def test_content_digest_tracks_value_not_uri():
    mgr = make_mgr()
    mdss = mgr.mdss
    v = np.random.rand(256)
    mdss.put("p/x", v, tier="local")
    mdss.put("q/y", v.copy(), tier="cloud")
    assert mdss.content_digest("p/x") == mdss.content_digest("q/y")
    mdss.put("p/x", v + 1, tier="local")
    assert mdss.content_digest("p/x") != mdss.content_digest("q/y")
    assert content_digest({"a": v}) != content_digest({"b": v})


# ------------------------------------------------- asymmetric placement
def test_placement_tracks_asymmetric_link():
    """Force an asymmetric link: a fast up (local->cloud), slow down
    (cloud->local). The locality scorer must charge each direction at
    its own observed bandwidth — staging TO cloud is cheap, staging the
    same bytes home is not."""
    mgr = make_mgr()
    cm, mdss = mgr.cost_model, mgr.mdss
    cm.observe_bandwidth("local", "cloud", 1e9, 1.0)    # 1 GB/s up
    cm.observe_bandwidth("cloud", "local", 1e9, 100.0)  # 10 MB/s down
    wf = Workflow("asym")
    wf.var("a")
    s = wf.step("s", lambda **kw: {"y": np.float64(0)}, inputs=("a",),
                outputs=("y",), remotable=True, jax_step=False)
    mdss.put("a", np.random.rand(1 << 20), tier="local")   # 8 MiB, local
    cm.stats_for("s").measured_s.update(local=0.01, cloud=0.01)
    pol = LocalityPolicy(cm, mdss, "cloud")
    d = pol.place(s)
    # staging UP rides the fast leg: the cloud score carries only ~8 ms
    # of transfer on top of equal exec
    assert d.stale_bytes["cloud"] == 8 << 20
    assert d.scores["cloud"] < 0.05
    # new content on cloud: bringing it home pays the slow DOWN leg —
    # two orders of magnitude worse for the same bytes
    mdss.put("a", np.random.rand(1 << 20), tier="cloud")
    d2 = pol.place(s)
    assert d2.offload and d2.scores["local"] > 0.5
    # the directional estimates really differ
    assert cm.transfer_time(8 << 20, "cloud", "local") > \
        10 * cm.transfer_time(8 << 20, "local", "cloud")


@pytest.mark.slow
def test_fabric_feeds_per_direction_bandwidth():
    from repro.cloud import attach
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    with Fabric(workers=1, dedup=False) as fabric:
        attach(tiers, fabric, mdss=mdss, cost_model=cm)
        mdss.put("big", np.random.rand(1 << 20), tier="local")   # 8 MiB
        mdss.ensure(["big"], "cloud")
    assert cm.measured_bw.get(("local", "cloud"), 0) > 0
    assert cm.measured_bw.get(("cloud", "local"), 0) > 0


# -------------------------------------------------- cross-run memoization
HEAVY_CALLS = []
_heavy_lock = threading.Lock()


def heavy_step(P):
    with _heavy_lock:
        HEAVY_CALLS.append(threading.get_ident())
    time.sleep(0.15)
    return {"out": np.asarray(P).sum() * np.ones(16)}


def make_tenant(name):
    wf = Workflow(name)
    wf.var("P")
    wf.step("heavy", heavy_step, inputs=("P",), outputs=("out",),
            remotable=True, jax_step=False)
    return wf


def test_memoized_duplicate_submission_executes_once():
    HEAVY_CALLS.clear()
    P = np.random.rand(1 << 14)
    with EmeraldRuntime(memoize=True) as rt:
        h1 = rt.submit(make_tenant("t1"), {"P": P}, fetch=["out"])
        h2 = rt.submit(make_tenant("t2"), {"P": P}, fetch=["out"])
        r1, r2 = h1.result(60), h2.result(60)
    np.testing.assert_array_equal(r1["out"], r2["out"])
    assert len(HEAVY_CALLS) == 1
    execs = [e for h in (h1, h2) for e in h.events
             if e.kind in ("local", "offload") and e.step == "heavy"]
    assert sorted(e.info["memo_hit"] for e in execs) == [False, True]
    assert rt.manager.memo_hits == 1


def test_memoization_respects_input_content():
    HEAVY_CALLS.clear()
    with EmeraldRuntime(memoize=True) as rt:
        h1 = rt.submit(make_tenant("t1"), {"P": np.zeros(64)})
        h2 = rt.submit(make_tenant("t2"), {"P": np.ones(64)})
        h1.result(60), h2.result(60)
    assert len(HEAVY_CALLS) == 2             # different inputs: no sharing


def test_memoization_default_off_and_per_step_override():
    HEAVY_CALLS.clear()
    P = np.random.rand(64)
    with EmeraldRuntime() as rt:             # memoize unset: off
        rt.submit(make_tenant("t1"), {"P": P}).result(60)
        rt.submit(make_tenant("t2"), {"P": P}).result(60)
    assert len(HEAVY_CALLS) == 2
    HEAVY_CALLS.clear()
    with EmeraldRuntime(memoize=True) as rt:
        wf1, wf2 = make_tenant("t1"), make_tenant("t2")
        wf2.steps["heavy"].memoizable = False    # step-level veto
        rt.submit(wf1, {"P": P}).result(60)
        rt.submit(wf2, {"P": P}).result(60)
    assert len(HEAVY_CALLS) == 2


def test_memoized_results_are_not_aliased_between_tenants():
    P = np.random.rand(64)
    with EmeraldRuntime(memoize=True) as rt:
        h1 = rt.submit(make_tenant("t1"), {"P": P}, fetch=["out"])
        h2 = rt.submit(make_tenant("t2"), {"P": P}, fetch=["out"])
        r1, r2 = h1.result(60), h2.result(60)
        r1["out"][0] = -999.0                # tenant 1 scribbles on its copy
        r2["out"][1] = -888.0
        h3 = rt.submit(make_tenant("t3"), {"P": P}, fetch=["out"])
        r3 = h3.result(60)                   # memo hit off the cached entry
    assert r2["out"][0] != -999.0
    assert r3["out"][0] != -999.0 and r3["out"][1] != -888.0


def test_memoized_failure_does_not_poison_the_key():
    from repro.core import StepFailure
    calls = []

    def flaky(P):
        calls.append(1)
        if len(calls) == 1:
            raise StepFailure("first attempt dies")   # retryable failure
        return {"out": np.float64(1.0)}

    wf = Workflow("flaky")
    wf.var("P")
    # retries=0: one cloud attempt then the local fallback lane
    wf.step("heavy", flaky, inputs=("P",), outputs=("out",),
            remotable=True, jax_step=False, retries=0)
    with EmeraldRuntime(memoize=True) as rt:
        out = rt.submit(wf, {"P": np.zeros(4)}, fetch=["out"]).result(60)
    assert float(out["out"]) == 1.0 and len(calls) == 2


# ---------------------------------------------- budget-aware admission
def tiny_wf(name="t"):
    wf = Workflow(name)
    wf.var("x")
    wf.step("s", lambda x: {"y": np.float64(float(x) + 1)}, inputs=("x",),
            outputs=("y",), remotable=False, jax_step=False)
    return wf


def test_admission_refuses_budget_over_remaining_capacity():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm, capacity_bytes=100 << 20)
    mgr = MigrationManager(tiers, mdss, cm)
    with EmeraldRuntime(mgr, admission_headroom=1.0) as rt:
        gate = threading.Event()
        wf = Workflow("hold")
        wf.var("x")
        wf.step("s", lambda x: (gate.wait(30), {"y": np.float64(0)})[1],
                inputs=("x",), outputs=("y",), remotable=False,
                jax_step=False)
        h1 = rt.submit(wf, {"x": np.float64(0)},
                       residency_budget={"cloud": 60 << 20})
        # occupancy is ~zero, but 60 MiB is already spoken for: a second
        # 60 MiB declaration exceeds REMAINING capacity and is refused
        with pytest.raises(AdmissionRefused, match="remaining capacity"):
            rt.submit(tiny_wf(), {"x": np.float64(0)},
                      residency_budget={"cloud": 60 << 20})
        # an undeclared (occupancy-only) submission still admits
        h3 = rt.submit(tiny_wf("free"), {"x": np.float64(0)})
        gate.set()
        h1.result(60), h3.result(60)
        # h1 finished: its reservation is released, the budget now fits
        h4 = rt.submit(tiny_wf("later"), {"x": np.float64(0)},
                       residency_budget={"cloud": 60 << 20})
        h4.result(60)


def test_failed_submit_releases_its_reservation():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm, capacity_bytes=100 << 20)
    mgr = MigrationManager(tiers, mdss, cm)
    with EmeraldRuntime(mgr, admission_headroom=1.0) as rt:
        # a submission that reserves its budget but fails before the
        # driver takes ownership must not leak the reservation
        with pytest.raises(ValueError):
            rt.submit(tiny_wf(), {"x": np.float64(0)}, policy="no-such",
                      residency_budget={"cloud": 60 << 20})
        h = rt.submit(tiny_wf("ok"), {"x": np.float64(0)},
                      residency_budget={"cloud": 60 << 20})
        h.result(60)
