"""Deterministic fault injection through the executor's recovery paths.

Covers the exact Event streams (not just counts) for: a step fn that
fails N times then succeeds, retry-with-tier-fallback, a fabric worker
hard-killed mid-task with in-process local fallback, and straggler
speculation."""
import time

import numpy as np
import pytest

from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        StepFailure, Workflow, default_tiers, partition)


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def event_kinds(ex, step):
    return [(e.kind, e.tier) for e in ex.events
            if e.step == step and e.kind in ("suspend", "retry", "offload",
                                             "speculate", "resume")]


def test_fails_n_times_then_succeeds_event_stream():
    state = {"fails": 2}

    def flaky(x):
        if state["fails"] > 0:
            state["fails"] -= 1
            raise StepFailure("injected: transient node fault")
        return {"y": np.float64(x) + 1}

    wf = Workflow("flaky")
    wf.var("x")
    wf.step("s", flaky, inputs=("x",), outputs=("y",), remotable=True,
            jax_step=False, retries=3)
    ex = EmeraldExecutor(partition(wf), emerald())
    out = ex.run({"x": 41.0})
    assert float(out["y"]) == 42.0
    # exactly: suspend, two failed cloud placements, success still on cloud
    assert event_kinds(ex, "s") == [
        ("suspend", ""), ("retry", "cloud"), ("retry", "cloud"),
        ("offload", "cloud"), ("resume", "")]


def test_retry_with_tier_fallback_event_stream():
    calls = {"n": 0}

    def cloud_only_fails(x):
        calls["n"] += 1
        if calls["n"] == 1:                 # the single cloud attempt
            raise StepFailure("injected: cloud node lost")
        return {"y": np.float64(x) * 10}

    wf = Workflow("fallback")
    wf.var("x")
    wf.step("s", cloud_only_fails, inputs=("x",), outputs=("y",),
            remotable=True, jax_step=False, retries=1)
    ex = EmeraldExecutor(partition(wf), emerald())
    out = ex.run({"x": 3.0})
    assert float(out["y"]) == 30.0
    assert event_kinds(ex, "s") == [
        ("suspend", ""), ("retry", "cloud"), ("offload", "local"),
        ("resume", "")]
    offload = [e for e in ex.events if e.kind == "offload"][0]
    assert offload.info["remote"] is False   # fallback ran in-process


def test_worker_killed_mid_task_falls_back_to_local():
    """A fabric worker is hard-killed (os._exit) while running the step;
    with no requeue budget the executor's tier fallback must finish the
    workflow in-process."""
    Fabric = pytest.importorskip("repro.cloud").Fabric
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    with Fabric(workers=1, max_attempts=1, replace_dead=False) as fabric:
        tiers["cloud"].worker_pool = fabric
        mgr = MigrationManager(tiers, mdss, cm)
        wf = Workflow("killed")
        wf.var("x")
        # crash_in_worker dies inside a worker, succeeds in-process
        wf.step("s", None, inputs=("x",), outputs=("y",), remotable=True,
                jax_step=False, retries=1, remote_impl="crash_in_worker")
        ex = EmeraldExecutor(partition(wf), mgr)
        out = ex.run({"x": np.float64(7.0)})
        assert float(out["y"]) == 70.0
        assert fabric.broker.workers_lost >= 1
    assert event_kinds(ex, "s") == [
        ("suspend", ""), ("retry", "cloud"), ("offload", "local"),
        ("resume", "")]


def test_straggler_speculation_event_stream():
    state = {"calls": 0}

    def sometimes_slow(x):
        state["calls"] += 1
        if state["calls"] == 2:
            time.sleep(1.0)
        return {"y": np.float64(x) + 1}

    wf = Workflow("strag")
    wf.var("x")
    wf.step("s", sometimes_slow, inputs=("x",), outputs=("y",),
            remotable=True, jax_step=False)
    ex = EmeraldExecutor(partition(wf), emerald(), speculate_after=2.0)
    ex.run({"x": 0.0})                       # seeds the runtime EMA
    ex.events.clear()
    out = ex.run({"x": 5.0})
    assert float(out["y"]) == 6.0
    kinds = event_kinds(ex, "s")
    assert kinds[0] == ("suspend", "")
    assert ("speculate", "cloud2") in kinds
    assert kinds[-1] == ("resume", "")
