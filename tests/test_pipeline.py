"""Pipeline parallelism over the pod axis: loss equivalence vs plain step.

Subprocess with 8 fake devices (mesh 2x2x2: 2 pipeline stages).
"""
import os
import subprocess
import sys
import textwrap

import pytest

# The explicit-mesh API (jax.sharding.AxisType / jax.set_mesh) is newer
# than this container's jax; the subprocess scripts below require it.
import jax as _jax
needs_axis_type = pytest.mark.skipif(
    not hasattr(_jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType (explicit-mesh API)")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType

    from repro.configs import get_config
    from repro.configs.base import RunConfig, ShapeProfile, reduced
    from repro.data.pipeline import SyntheticLMData
    from repro.models.model_zoo import Model
    from repro.parallel.pipeline import pipeline_train_step

    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=4)
    run = RunConfig(model=cfg, shape=ShapeProfile("t", 16, 8, "train"),
                    remat="none")
    model = Model(run)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = model.opt_init(params)
    batch = SyntheticLMData(cfg, run.shape).batch(0)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
    with jax.set_mesh(mesh):
        step = jax.jit(pipeline_train_step(model, mesh, n_micro=4))
        p2, o2, m = step(params, opt, batch)
        hlo = step.lower(params, opt, batch).compile().as_text()
    ref_p, ref_o, ref_m = jax.jit(model.train_step)(params, opt, batch)
    print("pp xent", float(m["xent"]), "ref", float(ref_m["xent"]))
    assert abs(float(m["xent"]) - float(ref_m["xent"])) < 2e-3
    # params updated equivalently (same grads modulo accumulation order)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(ref_p)):
        pass
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(ref_p)))
    print("max param delta vs ref step:", err)
    assert err < 5e-2
    assert "collective-permute" in hlo, "pipeline rotation missing from HLO"
    print("PIPELINE_OK")
""")


@needs_axis_type
@pytest.mark.slow
def test_pipeline_matches_plain_step():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT.format(src=src)],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "PIPELINE_OK" in r.stdout
