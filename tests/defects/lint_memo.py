"""Memoization-safety and residency-budget defects."""
from repro.core.tiers import default_tiers
from repro.core.workflow import Workflow


def pure(x):
    return {"y": x}


# W030: memoizable=True but the fn closes over mutable state the memo
# key (code fingerprint + input digests + outputs) cannot see.
def w030_defective():
    state = {"calls": 0}

    def fn(x):
        state["calls"] += 1
        return {"y": (x, state["calls"])}
    wf = Workflow("memodirty")
    wf.var("x")
    wf.step("s", fn, inputs=("x",), outputs=("y",), memoizable=True)
    return {"wf": wf, "provided": {"x"}}


def w030_clean():
    wf = Workflow("memodirty-clean")
    wf.var("x")
    wf.step("s", pure, inputs=("x",), outputs=("y",), memoizable=True)
    return {"wf": wf, "provided": {"x"}}


# W031: memoizable=True with no outputs — no execution is ever keyed.
def w031_defective():
    wf = Workflow("memovoid")
    wf.var("x")
    wf.step("s", lambda x: {}, inputs=("x",), outputs=(),
            memoizable=True)
    return {"wf": wf, "provided": {"x"}}


def w031_clean():
    wf = Workflow("memovoid-clean")
    wf.var("x")
    wf.step("s", pure, inputs=("x",), outputs=("y",), memoizable=True)
    return {"wf": wf, "provided": {"x"}}


# W040: a residency budget smaller than the bytes the workflow declares
# it will materialise.
def _budget_wf():
    wf = Workflow("budget")
    wf.var("x")
    wf.step("s", pure, inputs=("x",), outputs=("y",),
            bytes_hint=64 * 1024 * 1024)
    return wf


def w040_defective():
    return {"wf": _budget_wf(), "provided": {"x"},
            "residency_budget": {"cloud": 1024},
            "tiers": default_tiers()}


def w040_clean():
    return {"wf": _budget_wf(), "provided": {"x"},
            "residency_budget": {"cloud": 256 * 1024 * 1024},
            "tiers": default_tiers()}


# W041: a budget on a tier the runtime does not have.
def w041_defective():
    return {"wf": _budget_wf(), "provided": {"x"},
            "residency_budget": {"nebula": 256 * 1024 * 1024},
            "tiers": default_tiers()}


def w041_clean():
    return w040_clean()


CASES = {
    "W030": ("verify", w030_defective, w030_clean),
    "W031": ("verify", w031_defective, w031_clean),
    "W040": ("verify", w040_defective, w040_clean),
    "W041": ("verify", w041_defective, w041_clean),
}
