"""Fan-out legality defects: broken specs, unshippable partition fns,
gathers that drop shards, sibling shards racing on one URI.

W060/W061 fire on the user-declared (unexpanded) step — a spec the
partitioner refuses to expand survives to admission where the verifier
names the defect. W062/W063 fire on the expanded scatter/shard/gather
form (hand-built here, as a mutated or hand-rolled expansion would be).
"""
from repro.core.partitioner import split_rows
from repro.core.workflow import Fanout, Workflow


def _fn(**kw):
    return {}


def _wf(name):
    return Workflow(name)


# W060: a fan-out spec expansion cannot honour (zero shards).
def w060_defective():
    wf = _wf("fanout-spec")
    wf.var("P")
    wf.step("big", _fn, inputs=("P",), outputs=("out",),
            fanout=Fanout(shards=0))
    return {"wf": wf, "provided": {"P"}}


def w060_clean():
    wf = _wf("fanout-spec-clean")
    wf.var("P")
    wf.step("big", _fn, inputs=("P",), outputs=("out",),
            fanout=Fanout(shards=2))
    return {"wf": wf, "provided": {"P"}}


# W061: partition_fn is a lambda — fabric workers and checkpoints cannot
# pickle it.
def w061_defective():
    wf = _wf("fanout-pickle")
    wf.var("P")
    wf.step("big", _fn, inputs=("P",), outputs=("out",),
            fanout=Fanout(shards=2, partition_fn=lambda v, n: [v] * n))
    return {"wf": wf, "provided": {"P"}}


def w061_clean():
    wf = _wf("fanout-pickle-clean")
    wf.var("P")
    wf.step("big", _fn, inputs=("P",), outputs=("out",),
            fanout=Fanout(shards=2, partition_fn=split_rows))
    return {"wf": wf, "provided": {"P"}}


# W062: a gather that never reads one sibling's output — that shard's
# result silently vanishes from the combined value.
def _shards(wf, outs=("out#0", "out#1")):
    for k, o in enumerate(outs):
        wf.step(f"big#{k}", _fn, inputs=("P",), outputs=(o,),
                fanout_role="shard", fanout_parent="big",
                shard_index=k, fanout_shards=2)


def w062_defective():
    wf = _wf("gather-miss")
    wf.var("P")
    _shards(wf)
    wf.step("big.gather", _fn, inputs=("out#0",), outputs=("out",),
            fanout_role="gather", fanout_parent="big", fanout_shards=2)
    return {"wf": wf, "provided": {"P"}}


def w062_clean():
    wf = _wf("gather-miss-clean")
    wf.var("P")
    _shards(wf)
    wf.step("big.gather", _fn, inputs=("out#0", "out#1"), outputs=("out",),
            fanout_role="gather", fanout_parent="big", fanout_shards=2)
    return {"wf": wf, "provided": {"P"}}


# W063: two sibling shards of one fan-out write the same shard URI —
# the surviving version depends on completion order.
def w063_defective():
    wf = _wf("sibling-ww")
    wf.var("P")
    _shards(wf, outs=("out#0", "out#0"))
    wf.step("read", _fn, inputs=("out#0",), outputs=("r",))
    return {"wf": wf, "provided": {"P"}}


def w063_clean():
    wf = _wf("sibling-ww-clean")
    wf.var("P")
    _shards(wf)
    wf.step("read", _fn, inputs=("out#0", "out#1"), outputs=("r",))
    return {"wf": wf, "provided": {"P"}}


CASES = {
    "W060": ("verify", w060_defective, w060_clean),
    "W061": ("verify", w061_defective, w061_clean),
    "W062": ("verify", w062_defective, w062_clean),
    "W063": ("verify", w063_defective, w063_clean),
}
