"""Seeded defect corpus for the analysis subsystem.

One minimal *defective* workflow per verifier rule and one replayed
event/replica log per sanitizer hazard class, each paired with a *clean
twin* differing only in the defect. ``tests/test_analysis.py``
parametrizes over :data:`CASES`, asserting the exact rule id fires on
the defective artifact and stays silent on the twin — the
failing-before test each rule was built against.

A case is ``(rule_id, make_defective, make_clean)`` where the factories
return a kwargs dict for :func:`repro.analysis.verify` (lint cases:
``{"wf": Workflow, ...extra verify kwargs}``), for the sanitizer
(hazard cases: ``{"events": [...]}`` / ``{"installs": [...],
"evictions": [...]}``), for the explorer's trace checker
(cross-schedule hazards: the :func:`explorer.check_trace` dict shape),
or for the source lint (lock-discipline cases: ``{"text": snippet}``).
"""
from . import (hazards, hazards_explore, lint_fanout, lint_frontdoor,
               lint_graph, lint_locks, lint_memo, lint_offload)

#: rule id -> (kind, make_defective, make_clean); kind in
#: {"verify", "events", "store", "trace", "source"}.
CASES = {}
CASES.update(lint_graph.CASES)
CASES.update(lint_offload.CASES)
CASES.update(lint_memo.CASES)
CASES.update(lint_fanout.CASES)
CASES.update(lint_frontdoor.CASES)
CASES.update(lint_locks.CASES)
CASES.update(hazards.CASES)
CASES.update(hazards_explore.CASES)
