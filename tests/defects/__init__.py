"""Seeded defect corpus for the analysis subsystem.

One minimal *defective* workflow per verifier rule and one replayed
event/replica log per sanitizer hazard class, each paired with a *clean
twin* differing only in the defect. ``tests/test_analysis.py``
parametrizes over :data:`CASES`, asserting the exact rule id fires on
the defective artifact and stays silent on the twin — the
failing-before test each rule was built against.

A case is ``(rule_id, make_defective, make_clean)`` where the factories
return either a kwargs dict for :func:`repro.analysis.verify` (lint
cases: ``{"wf": Workflow, ...extra verify kwargs}``) or a kwargs dict
for the sanitizer (hazard cases: ``{"events": [...]}`` /
``{"installs": [...], "evictions": [...]}``).
"""
from . import hazards, lint_fanout, lint_graph, lint_memo, lint_offload

#: rule id -> (kind, make_defective, make_clean); kind in
#: {"verify", "events", "store"}.
CASES = {}
CASES.update(lint_graph.CASES)
CASES.update(lint_offload.CASES)
CASES.update(lint_memo.CASES)
CASES.update(lint_fanout.CASES)
CASES.update(hazards.CASES)
