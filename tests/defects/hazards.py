"""Sanitizer hazard corpus: minimal replayed event / replica logs, one
per hazard class, with clean twins that respect happens-before."""
from repro.core.runtime import Event


def _ev(kind, step, t, **info):
    return Event(kind, step, "", t, info, t)


def _dispatch(step, t, lane="offload"):
    return _ev("dispatch", step, t, lane=lane)


def _done(step, t):
    return _ev("step_done", step, t, offloaded=True)


# H101: one dispatch, two completions (a replayed done got through).
def h101_defective():
    return {"events": [_dispatch("s", 1.0), _done("s", 2.0),
                       _done("s", 3.0)]}


def h101_clean():
    return {"events": [_dispatch("s", 1.0), _done("s", 2.0)]}


# H102: a completion for a step never granted a lane slot.
def h102_defective():
    return {"events": [_dispatch("a", 1.0), _done("a", 2.0),
                       _done("ghost", 3.0)]}


def h102_clean():
    return {"events": [_dispatch("a", 1.0), _done("a", 2.0),
                       _dispatch("ghost", 2.5), _done("ghost", 3.0)]}


# H103: a dispatched step never completes in a run that reported done.
def h103_defective():
    return {"events": [_dispatch("a", 1.0), _done("a", 2.0),
                       _dispatch("lost", 2.5)]}


def h103_clean():
    # the same truncated log is legitimate for a cancelled/failed run
    d = h103_defective()
    d["completed_run"] = False
    return d


# H110: a tier's replica version regresses within one namespace epoch
# (install rows: (uri, tier, version, epoch, t)).
def h110_defective():
    return {"installs": [("ns/u", "cloud", 1, 0, 1.0),
                         ("ns/u", "cloud", 3, 0, 2.0),
                         ("ns/u", "cloud", 2, 0, 3.0)],
            "evictions": []}


def h110_clean():
    # same shape, but the "regression" is a new namespace epoch (the
    # namespace was dropped and reused) plus a same-version re-install
    return {"installs": [("ns/u", "cloud", 1, 0, 1.0),
                         ("ns/u", "cloud", 3, 0, 2.0),
                         ("ns/u", "cloud", 3, 0, 2.5),
                         ("ns/u", "cloud", 2, 1, 3.0)],
            "evictions": []}


# H111: eviction of a replica version never installed on that tier
# (eviction rows: (uri, tier, bytes, version, epoch, t)).
def h111_defective():
    return {"installs": [("ns/u", "cloud", 1, 0, 1.0)],
            "evictions": [("ns/u", "cloud", 512, 2, 0, 2.0)]}


def h111_clean():
    return {"installs": [("ns/u", "cloud", 1, 0, 1.0),
                         ("ns/u", "cloud", 2, 0, 1.5)],
            "evictions": [("ns/u", "cloud", 512, 2, 0, 2.0)]}


CASES = {
    "H101": ("events", h101_defective, h101_clean),
    "H102": ("events", h102_defective, h102_clean),
    "H103": ("events", h103_defective, h103_clean),
    "H110": ("store", h110_defective, h110_clean),
    "H111": ("store", h111_defective, h111_clean),
}
