"""Graph-shape defects: cycles, unbound reads, dataflow races, dead code.

Each factory returns verify() kwargs. Step fns use **kw so only the
graph shape differs between a defective workflow and its clean twin.
"""
from repro.core.workflow import Workflow


def _fn(**kw):
    return {}


def _wf(name):
    return Workflow(name)


# W001: a reads b's output, b reads a's output; nothing provided, so the
# declaration order "resolved" the forward read into a cycle.
def w001_defective():
    wf = _wf("cycle")
    wf.step("a", _fn, inputs=("vb",), outputs=("va",))
    wf.step("b", _fn, inputs=("va",), outputs=("vb",))
    return {"wf": wf, "provided": set()}


def w001_clean():
    wf = _wf("cycle-clean")
    wf.step("a", _fn, inputs=("vb",), outputs=("va",))
    wf.step("b", _fn, inputs=("va",), outputs=("vb",))
    return {"wf": wf, "provided": {"vb"}}   # feedback loop seeded at submit


# W002: a step reads a declared variable nothing binds.
def w002_defective():
    wf = _wf("unbound")
    wf.var("obs")
    wf.step("fit", _fn, inputs=("obs",), outputs=("chi",))
    return {"wf": wf, "provided": set()}


def w002_clean():
    d = w002_defective()
    d["provided"] = {"obs"}
    return d


# W010: two blind writers of one URI with no dataflow path between them.
def w010_defective():
    wf = _wf("ww")
    wf.var("x")
    wf.step("w1", _fn, inputs=("x",), outputs=("r",))
    wf.step("w2", _fn, inputs=("x",), outputs=("r",))
    return {"wf": wf, "provided": {"x"}}


def w010_clean():
    wf = _wf("ww-clean")
    wf.var("x")
    wf.step("w1", _fn, inputs=("x",), outputs=("r",))
    wf.step("w2", _fn, inputs=("x", "r"), outputs=("r",))   # accumulates
    return {"wf": wf, "provided": {"x"}}


# W011: a reader whose input is blindly overwritten by a step ordered
# after it only by the scheduler's anti-dependency fence.
def w011_defective():
    wf = _wf("rw")
    wf.var("x")
    wf.step("produce", _fn, inputs=("x",), outputs=("v",))
    wf.step("consume", _fn, inputs=("v",), outputs=("out",))
    wf.step("refresh", _fn, inputs=("x",), outputs=("v",))
    return {"wf": wf, "provided": {"x"}}


def w011_clean():
    wf = _wf("rw-clean")
    wf.var("x")
    wf.step("produce", _fn, inputs=("x",), outputs=("v",))
    wf.step("consume", _fn, inputs=("v",), outputs=("out",))
    wf.step("refresh", _fn, inputs=("out",), outputs=("v2",))
    return {"wf": wf, "provided": {"x"}}


# W012: a version overwritten before anything reads it.
def w012_defective():
    wf = _wf("deadwrite")
    wf.var("x")
    wf.step("w1", _fn, inputs=("x",), outputs=("v",))
    wf.step("w2", _fn, inputs=("x",), outputs=("v",))
    wf.step("read", _fn, inputs=("v",), outputs=("out",))
    return {"wf": wf, "provided": {"x"}}


def w012_clean():
    wf = _wf("deadwrite-clean")
    wf.var("x")
    wf.step("w1", _fn, inputs=("x",), outputs=("v",))
    wf.step("read1", _fn, inputs=("v",), outputs=("o1",))
    wf.step("w2", _fn, inputs=("x", "v"), outputs=("v",))
    wf.step("read2", _fn, inputs=("v",), outputs=("o2",))
    return {"wf": wf, "provided": {"x"}}


# W050: a step none of whose outputs reach a final version or a reader.
def w050_defective():
    wf = _wf("deadstep")
    wf.var("x")
    wf.step("dead", _fn, inputs=("x",), outputs=("v",))
    wf.step("alive", _fn, inputs=("x",), outputs=("v",))
    wf.step("read", _fn, inputs=("v",), outputs=("out",))
    return {"wf": wf, "provided": {"x"}}


def w050_clean():
    wf = _wf("deadstep-clean")
    wf.var("x")
    wf.step("a", _fn, inputs=("x",), outputs=("v",))
    wf.step("read", _fn, inputs=("v",), outputs=("out",))
    return {"wf": wf, "provided": {"x"}}


CASES = {
    "W001": ("verify", w001_defective, w001_clean),
    "W002": ("verify", w002_defective, w002_clean),
    "W010": ("verify", w010_defective, w010_clean),
    "W011": ("verify", w011_defective, w011_clean),
    "W012": ("verify", w012_defective, w012_clean),
    "W050": ("verify", w050_defective, w050_clean),
}
