"""Serving front-door defects: SLOs on steps the batch coalescer
cannot fuse, and preemptible fan-out shards with no gather barrier.

W070 fires on the user-declared step (an ``slo_ms`` hint the front
door can never honour); W071 on the expanded shard/gather form
(hand-built here, as a mutated or hand-rolled expansion would be).
"""
from repro.core.workflow import Workflow


def _fn(**kw):
    return {}


# W070: slo_ms on a step the coalescer cannot batch — not remotable
# (never dispatches through the front door's fused path) here.
def w070_defective():
    wf = Workflow("slo-local")
    wf.var("tok")
    wf.step("decode", _fn, inputs=("tok",), outputs=("logits",),
            remotable=False, slo_ms=5.0)
    return {"wf": wf, "provided": {"tok"}}


def w070_clean():
    wf = Workflow("slo-local-clean")
    wf.var("tok")
    wf.step("decode", _fn, inputs=("tok",), outputs=("logits",),
            remotable=True, slo_ms=5.0)
    return {"wf": wf, "provided": {"tok"}}


# W071: a preemptible shard whose fan-out has no gather step — a
# preempted-and-requeued shard re-publishes its shard URI with no
# barrier fencing downstream readers.
def _shards(wf, preemptible):
    for k in range(2):
        wf.step(f"big#{k}", _fn, inputs=("P",), outputs=(f"out#{k}",),
                fanout_role="shard", fanout_parent="big",
                shard_index=k, fanout_shards=2, preemptible=preemptible)


def w071_defective():
    wf = Workflow("preempt-no-gather")
    wf.var("P")
    _shards(wf, preemptible=True)
    wf.step("read", _fn, inputs=("out#0", "out#1"), outputs=("r",))
    return {"wf": wf, "provided": {"P"}}


def w071_clean():
    wf = Workflow("preempt-gather-clean")
    wf.var("P")
    _shards(wf, preemptible=True)
    wf.step("big.gather", _fn, inputs=("out#0", "out#1"),
            outputs=("out",), fanout_role="gather", fanout_parent="big",
            fanout_shards=2)
    return {"wf": wf, "provided": {"P"}}


CASES = {
    "W070": ("verify", w070_defective, w070_clean),
    "W071": ("verify", w071_defective, w071_clean),
}
