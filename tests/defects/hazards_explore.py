"""Explorer cross-schedule hazard corpus: one minimal trace artifact
per H12x invariant, with clean twins. The artifacts are the dict shape
:func:`repro.analysis.explorer.check_trace` accepts — each carries only
the trace section its rule replays, exactly what a serialized explored
schedule would produce."""


# H120: an install lands carrying a namespace epoch older than one
# already observed — a pre-drop transfer writing into the reused
# namespace (install rows: (uri, tier, version, epoch, t)).
def h120_defective():
    return {"installs": [("ns/u", "local", 1, 0, 1.0),
                         ("ns/v", "local", 1, 1, 2.0),   # epoch 1 live
                         ("ns/u", "local", 1, 0, 3.0)],  # stale epoch 0
            "evictions": []}


def h120_clean():
    # the stale write-back was fenced: only live-epoch installs land
    return {"installs": [("ns/u", "local", 1, 0, 1.0),
                         ("ns/v", "local", 1, 1, 2.0),
                         ("ns/u", "local", 1, 1, 3.0)],
            "evictions": []}


# H121: one memo key executed twice — the second tenant should have
# joined the in-flight entry as a waiter (rows: (key, run, step, t)).
def h121_defective():
    return {"executions": [("k1", "A", "s", 1.0),
                           ("k1", "B", "s", 2.0)]}


def h121_clean():
    # B's distinct inputs key differently; same key never re-executes
    return {"executions": [("k1", "A", "s", 1.0),
                           ("k2", "B", "s", 2.0)]}


# H122: a run holding the smallest virtual time with ready steps is
# passed over for a full starvation window of dispatch rounds
# (rows: (chosen_run, owed_runs)).
def h122_defective():
    return {"dispatch_rounds": [("A", ("B",)), ("A", ("B",)),
                                ("A", ("B",)), ("A", ("B",))],
            "starvation_window": 4}


def h122_clean():
    # the scheduler serves the owed run before the window closes
    return {"dispatch_rounds": [("A", ("B",)), ("A", ("B",)),
                                ("A", ("B",)), ("B", ("B",))],
            "starvation_window": 4}


# H123: resident bytes exceed the configured per-(namespace, tier)
# budget after a decision (rows: (t, ns, tier, bytes)).
def h123_defective():
    return {"budgets": {"A:cloud": 2},
            "residency": [(1.0, "A", "cloud", 1),
                          (2.0, "A", "cloud", 3)]}


def h123_clean():
    # eviction ran on the crossing install: residency never overshoots
    return {"budgets": {"A:cloud": 2},
            "residency": [(1.0, "A", "cloud", 1),
                          (2.0, "A", "cloud", 2)]}


# H124: resuming from a checkpointed prefix converges to different
# final content digests than the uninterrupted run.
def h124_defective():
    return {"base_digests": {"A": {"x": "d1", "y": "d2"}},
            "resumed": [{"prefix": 3,
                         "digests": {"A": {"x": "d1", "y": "DIVERGED"}}}]}


def h124_clean():
    return {"base_digests": {"A": {"x": "d1", "y": "d2"}},
            "resumed": [{"prefix": 3,
                         "digests": {"A": {"x": "d1", "y": "d2"}}}]}


# H125: a parked run stayed admissible (free slot, head of the
# deadline order) for a full admission window of drain rounds without
# being admitted (rows: (admitted_runs, eligible_runs)).
def h125_defective():
    return {"admission_rounds": [((), ("B",)), ((), ("B",)),
                                 ((), ("B",)), ((), ("B",))],
            "admission_window": 4}


def h125_clean():
    # the drain loop admits the owed run before the window closes
    return {"admission_rounds": [((), ("B",)), ((), ("B",)),
                                 ((), ("B",)), (("B",), ("B",))],
            "admission_window": 4}


# H126: a preempted batch step burned retry budget or lost a
# checkpointed completion (rows: (run, step, d_attempts, ckpt_before,
# ckpt_after)).
def h126_defective():
    return {"preempt_log": [("C", "bat1", 1, 2, 1)]}


def h126_clean():
    # attempt-free requeue, checkpoint intact: only in-flight work lost
    return {"preempt_log": [("C", "bat1", 0, 2, 2)]}


CASES = {
    "H120": ("trace", h120_defective, h120_clean),
    "H121": ("trace", h121_defective, h121_clean),
    "H122": ("trace", h122_defective, h122_clean),
    "H123": ("trace", h123_defective, h123_clean),
    "H124": ("trace", h124_defective, h124_clean),
    "H125": ("trace", h125_defective, h125_clean),
    "H126": ("trace", h126_defective, h126_clean),
}
