"""Offloadability defects: missing impls, signature drift, unshippable
closures, captured device arrays."""
import jax.numpy as jnp

from repro.core.workflow import Workflow


def _fn(**kw):
    return {}


# module-level and thus picklable — the shippable twin of a nested fn
def shippable(x):
    return {"y": x}


# W003: neither fn nor remote_impl.
def w003_defective():
    wf = Workflow("noimpl")
    wf.var("x")
    wf.step("ghost", None, inputs=("x",), outputs=("y",))
    return {"wf": wf, "provided": {"x"}}


def w003_clean():
    wf = Workflow("noimpl-clean")
    wf.var("x")
    wf.step("ghost", None, inputs=("x",), outputs=("y",),
            remote_impl="registered_step")
    return {"wf": wf, "provided": {"x"},
            "registry": {"registered_step": object()}}


# W004: remote_impl not in the fabric step registry.
def w004_defective():
    wf = Workflow("unknownimpl")
    wf.var("x")
    wf.step("s", None, inputs=("x",), outputs=("y",),
            remote_impl="nope_not_registered", remotable=True)
    return {"wf": wf, "provided": {"x"}, "registry": {}}


def w004_clean():
    d = w004_defective()
    d["registry"] = {"nope_not_registered": object()}
    return d


# W005: declared inputs the fn cannot accept / params it cannot bind.
def w005_defective():
    wf = Workflow("sig")
    wf.var("a")
    wf.step("s", lambda a, b: {"y": a}, inputs=("a",), outputs=("y",))
    return {"wf": wf, "provided": {"a"}}


def w005_clean():
    wf = Workflow("sig-clean")
    wf.var("a").var("b")
    wf.step("s", lambda a, b: {"y": a}, inputs=("a", "b"), outputs=("y",))
    return {"wf": wf, "provided": {"a", "b"}}


# W020: a remotable non-jax step whose fn cannot pickle (nested closure).
def w020_defective():
    def nested(x):
        return {"y": x}
    wf = Workflow("unship")
    wf.var("x")
    wf.step("s", nested, inputs=("x",), outputs=("y",),
            remotable=True, jax_step=False)
    return {"wf": wf, "provided": {"x"}}


def w020_clean():
    wf = Workflow("unship-clean")
    wf.var("x")
    wf.step("s", shippable, inputs=("x",), outputs=("y",),
            remotable=True, jax_step=False)
    return {"wf": wf, "provided": {"x"}}


# W021: a remotable step closing over a device array.
def w021_defective():
    scale = jnp.ones((4,))

    def fn(x):
        return {"y": x * scale}
    wf = Workflow("devcap")
    wf.var("x")
    wf.step("s", fn, inputs=("x",), outputs=("y",), remotable=True)
    return {"wf": wf, "provided": {"x"}}


def w021_clean():
    scale = 2.0

    def fn(x):
        return {"y": x * scale}
    wf = Workflow("devcap-clean")
    wf.var("x")
    wf.step("s", fn, inputs=("x",), outputs=("y",), remotable=True)
    return {"wf": wf, "provided": {"x"}}


CASES = {
    "W003": ("verify", w003_defective, w003_clean),
    "W004": ("verify", w004_defective, w004_clean),
    "W005": ("verify", w005_defective, w005_clean),
    "W020": ("verify", w020_defective, w020_clean),
    "W021": ("verify", w021_defective, w021_clean),
}
