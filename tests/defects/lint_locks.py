"""Lock-discipline lint corpus: one minimal defective source snippet
per L01x rule, with clean twins. The snippets are linted by
:func:`repro.analysis.selfcheck.check_snippet` via the ``"source"``
corpus kind."""

import textwrap


def _src(text):
    return {"text": textwrap.dedent(text)}


# L010: two paths acquire the same pair of locks in opposite orders.
def l010_defective():
    return _src("""
        class S:
            def a(self):
                with self._mu_lock:
                    with self._io_lock:
                        self.flush()

            def b(self):
                with self._io_lock:
                    with self._mu_lock:
                        self.flush()
        """)


def l010_clean():
    # both paths honour the canonical mu -> io order
    return _src("""
        class S:
            def a(self):
                with self._mu_lock:
                    with self._io_lock:
                        self.flush()

            def b(self):
                with self._mu_lock:
                    with self._io_lock:
                        self.flush()
        """)


# L011: a blocking call runs inside the critical section.
def l011_defective():
    return _src("""
        import time

        class S:
            def poke(self):
                with self._state_lock:
                    time.sleep(0.5)
                    return self.state
        """)


def l011_clean():
    # the slow work happens outside the lock
    return _src("""
        import time

        class S:
            def poke(self):
                time.sleep(0.5)
                with self._state_lock:
                    return self.state
        """)


# L012: a condition wait guarded by `if` instead of a predicate loop.
def l012_defective():
    return _src("""
        class S:
            def take(self):
                with self._cond:
                    if not self.items:
                        self._cond.wait()
                    return self.items.pop()
        """)


def l012_clean():
    return _src("""
        class S:
            def take(self):
                with self._cond:
                    while not self.items:
                        self._cond.wait()
                    return self.items.pop()
        """)


CASES = {
    "L010": ("source", l010_defective, l010_clean),
    "L011": ("source", l011_defective, l011_clean),
    "L012": ("source", l012_defective, l012_clean),
}
