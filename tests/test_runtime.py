"""Multi-tenant EmeraldRuntime: concurrent submissions over one scheduler.

Covers the acceptance surface of the multi-tenant refactor:

  * N concurrent heterogeneous workflows over one runtime, with per-run
    MDSS namespace isolation (same variable names, no cross-run
    corruption) and namespace teardown,
  * cross-run fair share — a small interactive run finishes while a wide
    batch run is still executing (no starvation), and aggregate
    throughput of concurrent submissions beats back-to-back serial runs,
  * warm resubmission — the second submission of an identical workflow is
    code-only (shared-namespace data already cloud-resident) and hits the
    shared compile cache,
  * run handles: non-blocking submit, cancel, release,
  * satellites: deterministic speculation backup tier, bounded
    in-flight-transfer waits surfacing as MDSSTransferError/StepFailure,
    CostModelPolicy.explain reporting, put_many fencing on absent
    entries, broker priority classes, autoscaler aggregate backlog.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (CostModel, CostModelPolicy, EmeraldExecutor,
                        EmeraldRuntime, FairShare, MDSS, MDSSTransferError,
                        MigrationManager, RunCancelled, StepFailure, Workflow,
                        default_tiers, nbytes_of, partition)
from repro.core.tiers import Tier


def emerald():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    return MigrationManager(tiers, mdss, cm)


def sleeper(name, seconds, out, factor=2.0):
    def fn(**kw):
        (val,) = kw.values()
        time.sleep(seconds)
        return {out: np.float64(float(val) * factor)}
    return fn


def chain_wf(name, depth, step_s, factor=2.0, prefix=""):
    """x -> y1 -> ... -> y_depth, each step multiplying by ``factor``.
    ``prefix`` namespaces the URIs manually — needed only for executors
    sharing one base store un-namespaced (the compat mode)."""
    wf = Workflow(name)
    wf.var(prefix + "x")
    src = prefix + "x"
    for i in range(depth):
        out = f"{prefix}y{i + 1}"
        wf.step(f"s{i + 1}", sleeper(f"{name}.s{i}", step_s, out, factor),
                inputs=(src,), outputs=(out,), remotable=True, jax_step=False)
        src = out
    return wf


def wide_wf(name, width, step_s):
    wf = Workflow(name)
    wf.var("x")
    for i in range(width):
        wf.step(f"w{i}", sleeper(f"{name}.w{i}", step_s, f"y{i}"),
                inputs=("x",), outputs=(f"y{i}",), remotable=True,
                jax_step=False)
    return wf


# ------------------------------------------------------------ concurrency
def test_three_concurrent_runs_namespace_isolation():
    """3 heterogeneous workflows using the SAME variable names execute
    concurrently over one runtime; every run sees only its own data."""
    with EmeraldRuntime(emerald(), max_workers=6) as rt:
        # heterogeneous: different depths and factors, identical URIs
        handles = []
        for depth, factor, x in ((2, 2.0, 1.0), (3, 3.0, 2.0), (4, 5.0, 3.0)):
            wf = chain_wf("tenant", depth, 0.03, factor)
            handles.append((rt.submit(wf, {"x": np.float64(x)}),
                            x * factor ** depth, depth))
        for h, expect, depth in handles:
            out = h.result(30)
            assert float(out[f"y{depth}"]) == expect
        # isolation is structural: each run's URIs live under its own
        # namespace in the shared store
        namespaces = {h.namespace for h, _, _ in handles}
        assert len(namespaces) == 3
        base = rt.mdss
        for h, _, depth in handles:
            entries = base.namespace_entries(h.namespace)
            assert f"{h.namespace}/y{depth}" in entries
        # teardown: release drops exactly that run's data
        h0 = handles[0][0]
        dropped, freed = h0.release()
        assert dropped >= 3 and freed > 0          # x + y1 + y2 replicas
        assert base.namespace_entries(h0.namespace) == []
        assert base.namespace_entries(handles[1][0].namespace)  # untouched


def test_fair_share_small_run_not_starved_by_wide_run():
    """A 4-step interactive chain submitted after a 16-step wide batch
    run must finish while the wide run is still executing — under FIFO it
    would queue behind the whole backlog."""
    with EmeraldRuntime(emerald(), max_workers=2, local_workers=2) as rt:
        hw = rt.submit(wide_wf("batch", 16, 0.05), {"x": np.float64(1.0)})
        hs = rt.submit(chain_wf("inter", 4, 0.005), {"x": np.float64(1.0)})
        out = hs.result(30)
        assert float(out["y4"]) == 16.0
        assert not hw.done(), \
            "wide batch run finished first: small run was starved"
        hw.result(60)


def test_fair_share_weight_buys_share():
    fs = FairShare()
    fs.add("a", weight=1.0)
    fs.add("b", weight=3.0)
    grants = {"a": 0, "b": 0}
    for _ in range(40):
        rid = fs.pick(["a", "b"])
        grants[rid] += 1
        fs.charge(rid, 1.0)
    assert grants["b"] == 30 and grants["a"] == 10
    # a latecomer starts at the current minimum share, not at zero
    fs.add("c", weight=1.0)
    assert fs.share_of("c") == fs.share_of("a")
    fs.remove("b")
    assert fs.pick(["b"]) == "b"        # unknown ids still resolvable


def test_concurrent_throughput_beats_serial():
    """3 chain workflows (poor intra-run parallelism) through one runtime:
    concurrent submission must beat back-to-back runs, because idle lanes
    of one run absorb ready work from another."""
    mk = lambda i: chain_wf(f"tp{i}", 4, 0.05)
    # serial: one run at a time over the same shared runtime
    with EmeraldRuntime(emerald(), max_workers=8) as rt:
        t0 = time.perf_counter()
        for i in range(3):
            rt.submit(mk(i), {"x": np.float64(1.0)}).result(60)
        serial = time.perf_counter() - t0
    with EmeraldRuntime(emerald(), max_workers=8) as rt:
        t0 = time.perf_counter()
        hs = [rt.submit(mk(i), {"x": np.float64(1.0)}) for i in range(3)]
        for h in hs:
            h.result(60)
        concurrent = time.perf_counter() - t0
    assert serial / concurrent > 1.5, \
        f"no inter-workflow parallelism: serial {serial:.3f}s vs " \
        f"concurrent {concurrent:.3f}s"


def test_cancel_stops_pending_steps():
    ran = []
    lock = threading.Lock()

    def step(i):
        def fn(x):
            with lock:
                ran.append(i)
            time.sleep(0.05)
            return {f"y{i}": np.float64(i)}
        return fn

    wf = Workflow("cancelme")
    wf.var("x")
    for i in range(12):
        wf.step(f"s{i}", step(i), inputs=("x",), outputs=(f"y{i}",),
                remotable=True, jax_step=False)
    with EmeraldRuntime(emerald(), max_workers=2) as rt:
        h = rt.submit(wf, {"x": np.float64(0.0)})
        time.sleep(0.08)              # let a couple of steps start
        h.cancel()
        with pytest.raises(RunCancelled):
            h.result(30)
        assert h.state == "cancelled"
    assert len(ran) < 12, "cancel did not stop pending dispatch"


def test_executors_share_one_runtime():
    """Two classic executors over one shared runtime (the serve.py shape):
    both workflows run, events stay per-executor, nothing is torn down
    between runs. Compat executors address the base store un-namespaced
    (shared URIs are a *feature* there — serve's decode reads the cache
    prefill wrote), so co-tenant fronts use distinct URI names."""
    mgr = emerald()
    with EmeraldRuntime(mgr, max_workers=4) as rt:
        wf1 = chain_wf("front1", 2, 0.01, prefix="a_")
        wf2 = chain_wf("front2", 3, 0.01, factor=3.0, prefix="b_")
        ex1 = EmeraldExecutor(partition(wf1), mgr, runtime=rt)
        ex2 = EmeraldExecutor(partition(wf2), mgr, runtime=rt)
        h1 = ex1.submit({"a_x": np.float64(1.0)})
        h2 = ex2.submit({"b_x": np.float64(1.0)})
        assert float(h1.result(30)["a_y2"]) == 4.0
        assert float(h2.result(30)["b_y3"]) == 27.0
        assert {e.step for e in ex1.events if e.kind == "offload"} \
            == {"s1", "s2"}
        assert {e.step for e in ex2.events if e.kind == "offload"} \
            == {"s1", "s2", "s3"}
        # second run on the same executor still works (runtime persists)
        assert float(ex1.run({"a_x": np.float64(2.0)})["a_y2"]) == 8.0


# ------------------------------------------------------- warm resubmission
def test_second_submission_is_code_only_and_warm():
    mgr = emerald()
    mdss = mgr.mdss
    big = np.ones((64, 1024), np.float64)          # 512 KiB shared constant

    def build():
        wf = Workflow("warmjob")
        wf.var("params")
        wf.step("use", lambda params: {"out": np.float64(params.sum())},
                inputs=("params",), outputs=("out",), remotable=True,
                jax_step=False)
        return wf

    with EmeraldRuntime(mgr) as rt:
        rt.publish("params", big)
        out1 = rt.submit(build(), {}).result(30)
        shared_moved = mdss.namespace_bytes(rt.shared_namespace)
        assert shared_moved >= nbytes_of(big)      # first run staged params
        hits_before = mgr.compile_cache_hits
        h2 = rt.submit(build(), {})
        out2 = h2.result(30)
        assert float(out1["out"]) == float(out2["out"])
        # code-only: the shared data was already cloud-resident...
        off = [e for e in h2.events if e.kind == "offload"]
        assert off and off[0].info["code_only"] is True
        assert mdss.namespace_bytes(rt.shared_namespace) == shared_moved
        # ...and pre-compiled + pre-measured from the first submission
        assert mgr.compile_cache_hits > hits_before
        assert "cloud" in mgr.cost_model.stats_for("use").measured_s


def test_runtime_checkpoint_resume_in_namespace(tmp_path):
    state = {"crash": True}

    def mid(y1):
        if state["crash"]:
            raise StepFailure("injected: power loss")
        return {"z": np.float64(y1) * 10}

    def build():
        wf = Workflow("ckns")
        wf.var("x")
        wf.step("a", lambda x: {"y1": np.float64(x) + 1}, inputs=("x",),
                outputs=("y1",), remotable=True, jax_step=False)
        wf.step("b", mid, inputs=("y1",), outputs=("z",), remotable=True,
                jax_step=False, retries=0)
        return wf

    with EmeraldRuntime(emerald(), checkpoint_dir=str(tmp_path)) as rt:
        h = rt.submit(build(), {"x": np.float64(1.0)}, namespace="job")
        with pytest.raises(Exception):
            h.result(30)
        state["crash"] = False
        h2 = rt.submit(build(), {"x": np.float64(1.0)}, namespace="job",
                       resume=True)
        out = h2.result(30)
        assert float(out["z"]) == 20.0
        ran = {e.step for e in h2.events if e.kind == "offload"}
        assert "a" not in ran, "resume re-ran completed step"


def test_compile_cache_never_shared_across_default_arg_variants():
    """Two tenants building steps via the ``def fn(x, k=k)`` default-arg
    idiom share one code object but different bound state; the compile
    cache must not hand tenant B tenant A's executable."""
    def build(k):
        def fn(x, k=k):
            return {"y": np.float64(float(x) * k)}
        wf = Workflow(f"defaults{k}")
        wf.var("x")
        wf.step("mul", fn, inputs=("x",), outputs=("y",), remotable=True,
                jax_step=False)
        return wf

    with EmeraldRuntime(emerald()) as rt:
        h2 = rt.submit(build(2), {"x": np.float64(10.0)})
        h3 = rt.submit(build(3), {"x": np.float64(10.0)})
        assert float(h2.result(30)["y"]) == 20.0
        assert float(h3.result(30)["y"]) == 30.0, \
            "tenant ran another tenant's cached executable"


def test_compile_cache_distinguishes_exec_compiled_bodies():
    """Exec-compiled step fns share '<string>:1' location metadata; the
    cache key must compare code by value AND globals identity, while
    identical code rebuilt in the same environment still hits."""
    from repro.core.migration import step_code_key

    def make(src, env):
        exec(src, env)
        wf = Workflow("execwf")
        wf.var("x")
        return wf.step("f", env["f"], inputs=("x",), outputs=("y",),
                       remotable=True, jax_step=False)

    shared_env = {}
    a = make("def f(x):\n    return {'y': x + 1}\n", shared_env)
    b = make("def f(x):\n    return {'y': x * 2}\n", {})
    a2 = make("def f(x):\n    return {'y': x + 1}\n", shared_env)
    assert step_code_key(a) != step_code_key(b), \
        "different exec'd bodies collided in the compile cache"
    assert step_code_key(a) == step_code_key(a2), \
        "identical code rebuilt in the same environment missed the cache"
    # equal code under DIFFERENT globals can read different module state
    # (e.g. `x * SCALE`) — must be a safe miss, never a shared hit
    ga = make("def f(x):\n    return {'y': x * SCALE}\n", {"SCALE": 2})
    gb = make("def f(x):\n    return {'y': x * SCALE}\n", {"SCALE": 3})
    assert ga.fn.__code__ == gb.fn.__code__       # the trap being tested
    assert step_code_key(ga) != step_code_key(gb), \
        "identical code under different globals shared a cache entry"


def test_close_drains_in_flight_but_does_not_run_the_rest():
    """close() mid-run lets in-flight steps finish but must NOT keep
    unlocking successors; the pending run fails with RuntimeClosed."""
    from repro.core import RuntimeClosed
    rt = EmeraldRuntime(emerald(), max_workers=2)
    h = rt.submit(chain_wf("longchain", 8, 0.15), {"x": np.float64(1.0)})
    time.sleep(0.2)                    # a step or two in flight
    t0 = time.perf_counter()
    rt.close()
    assert time.perf_counter() - t0 < 2.0, \
        "close() ran the whole chain instead of draining"
    with pytest.raises(RuntimeClosed):
        h.result(5)


def test_submit_after_close_never_hangs():
    from repro.core import RuntimeClosed
    rt = EmeraldRuntime(emerald())
    rt.close()
    with pytest.raises(RuntimeClosed):
        rt.submit(chain_wf("late", 1, 0.01), {"x": np.float64(1.0)})


def test_owned_runtime_reaped_without_result_call():
    """A submit() whose caller cancels and never calls result() must not
    leak the executor's private runtime (driver thread + pools)."""
    mgr = emerald()
    ex = EmeraldExecutor(partition(chain_wf("reapme", 3, 0.05)), mgr)
    h = ex.submit({"x": np.float64(1.0)})
    h.cancel()
    assert h.wait(10)

    def driver_alive():
        return any(t.name == "emerald-reapme-driver"
                   for t in threading.enumerate())

    deadline = time.monotonic() + 5
    while time.monotonic() < deadline and driver_alive():
        time.sleep(0.02)
    assert not driver_alive(), \
        "private runtime leaked after cancel without result()"


def test_overlapping_checkpointed_executor_submits_refused(tmp_path):
    mgr = emerald()
    wf = chain_wf("ckol", 2, 0.2)
    with EmeraldRuntime(mgr) as rt:
        ex = EmeraldExecutor(partition(wf), mgr, runtime=rt,
                             checkpoint_dir=str(tmp_path))
        h = ex.submit({"x": np.float64(1.0)})
        with pytest.raises(RuntimeError, match="overlapping"):
            ex.submit({"x": np.float64(2.0)})
        assert float(h.result(30)["y2"]) == 4.0
        # sequential reuse stays fine
        assert float(ex.run({"x": np.float64(2.0)})["y2"]) == 8.0


def test_checkpoint_write_failure_fails_run_not_runtime(tmp_path):
    """An unwritable checkpoint fails THAT run (durability contract) but
    the driver survives and keeps serving other tenants."""
    from repro.core.runtime import RunCheckpointer

    class BadCkpt(RunCheckpointer):
        def _save_checkpoint(self, completed):
            raise OSError("injected: disk full")

    with EmeraldRuntime(emerald()) as rt:
        wf = chain_wf("ckfail", 2, 0.01)
        ck = BadCkpt(rt.mdss.namespaced("z", shared=rt.shared_namespace),
                     wf, str(tmp_path))
        h = rt.submit(wf, {"x": np.float64(1.0)}, namespace="z",
                      checkpointer=ck)
        with pytest.raises(OSError):
            h.result(30)
        # the runtime is still alive for other tenants
        h2 = rt.submit(chain_wf("fine", 2, 0.01), {"x": np.float64(1.0)})
        assert float(h2.result(30)["y2"]) == 4.0


def test_resume_does_not_privatize_shared_data(tmp_path):
    """Checkpoints must not capture variables resolving to the shared
    namespace: resume would write a private (stale, re-staged) copy of
    data meant to be stored once and read live."""
    mgr = emerald()
    big = np.ones((32, 1024), np.float64)
    state = {"crash": True}

    def build():
        wf = Workflow("sharedck")
        wf.var("C")

        def use(C):
            if state["crash"]:
                raise StepFailure("injected")
            return {"out": np.float64(C.sum())}

        wf.step("use", use, inputs=("C",), outputs=("out",), remotable=True,
                jax_step=False, retries=0)
        return wf

    with EmeraldRuntime(mgr, checkpoint_dir=str(tmp_path)) as rt:
        rt.publish("C", big)
        h = rt.submit(build(), {}, namespace="job")
        with pytest.raises(Exception):
            h.result(30)
        state["crash"] = False
        h2 = rt.submit(build(), {}, namespace="job", resume=True)
        assert float(h2.result(30)["out"]) == big.sum()
        # the run's namespace holds its OWN output, never a private copy
        # of the shared constant
        entries = mgr.mdss.namespace_entries("job")
        assert "job/out" in entries and "job/C" not in entries


# ------------------------------------------------------------- satellites
def test_alternate_tier_picks_lowest_estimated_exec_time():
    tiers = {
        "local": Tier("local", chips=1, peak_flops_per_chip=1e12,
                      hbm_bw_per_chip=1e11),
        "cloud": Tier("cloud", chips=4, peak_flops_per_chip=1e12,
                      hbm_bw_per_chip=1e11),
        "cloudA": Tier("cloudA", chips=2, peak_flops_per_chip=1e12,
                       hbm_bw_per_chip=1e11),
        "cloudB": Tier("cloudB", chips=8, peak_flops_per_chip=1e12,
                       hbm_bw_per_chip=1e11),
    }
    cm = CostModel(tiers)
    mgr = MigrationManager(tiers, MDSS(tiers, cost_model=cm), cm)
    wf = Workflow("alt")
    wf.var("x")
    s = wf.step("s", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
                remotable=True, jax_step=False)
    with EmeraldRuntime(mgr) as rt:
        # no estimates: deterministic declaration order (cloudA first)
        assert rt._alternate_tier(s, "cloud") == "cloudA"
        # measured estimates flip the choice to the fastest backup —
        # dict order would have kept cloudA
        cm.stats_for("s").observe("cloud", 0.3)
        cm.stats_for("s").observe("cloudA", 0.5)
        cm.stats_for("s").observe("cloudB", 0.1)
        assert rt._alternate_tier(s, "cloud") == "cloudB"
        # the straggling tier itself and local are never candidates
        assert rt._alternate_tier(s, "cloudB") == "cloud"
        assert rt._alternate_tier(s, "local") in ("cloud", "cloudA",
                                                  "cloudB")


def test_ensure_bounded_wait_raises_transfer_error():
    tiers = default_tiers()
    m = MDSS(tiers, cost_model=CostModel(tiers))
    m.put("a", np.arange(8), tier="local")
    m.transfer_wait_s = 0.01
    m.max_transfer_waits = 3
    # a peer "transfer" that never completes
    m._inflight[("a", "cloud")] = threading.Event()
    t0 = time.perf_counter()
    with pytest.raises(MDSSTransferError):
        m.ensure(["a"], "cloud")
    assert time.perf_counter() - t0 < 5.0, "retried far past the bound"


def test_stuck_transfer_maps_to_step_failure_and_fallback():
    """A wedged in-flight transfer surfaces as StepFailure at staging, so
    the executor's retry/fallback path finishes the step locally."""
    mgr = emerald()
    mdss = mgr.mdss
    mdss.transfer_wait_s = 0.01
    mdss.max_transfer_waits = 2
    wf = Workflow("stuck")
    wf.var("x")
    wf.step("s", lambda x: {"y": np.float64(x) + 1}, inputs=("x",),
            outputs=("y",), remotable=True, jax_step=False, retries=1)
    ex = EmeraldExecutor(partition(wf), mgr)
    mdss.put("x", np.float64(1.0), tier="local")
    mdss._inflight[("x", "cloud")] = threading.Event()   # never completes
    out = ex.run({"x": np.float64(1.0)})
    assert float(out["y"]) == 2.0
    kinds = [(e.kind, e.tier) for e in ex.events
             if e.step == "s" and e.kind in ("retry", "offload")]
    assert ("retry", "cloud") in kinds
    assert ("offload", "local") in kinds


def test_missing_entry_staging_maps_to_step_failure():
    """A URI vanished from the store (namespace dropped mid-run) must
    surface as StepFailure — owned by retry/fallback — not a raw
    KeyError that bypasses the recovery path."""
    mgr = emerald()
    wf = Workflow("gone")
    wf.var("x")
    s = wf.step("s", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
                remotable=True, jax_step=False)
    with pytest.raises(StepFailure, match="staging inputs"):
        mgr._stage_inputs(s, "cloud", ["x"], mgr.mdss)   # never written


def test_cost_model_policy_explain_reports_bandwidth_source():
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    pol = CostModelPolicy(cm, mdss, "cloud")
    wf = Workflow("explain")
    wf.var("x")
    s = wf.step("s", lambda x: {"y": x}, inputs=("x",), outputs=("y",),
                remotable=True, flops_hint=1e15, bytes_hint=8.0)
    big = np.ones(4096, np.float64)
    mdss.put("x", big, tier="local")
    d = pol.explain(s)
    assert d["bw_source"] == "static" and d["bw_bytes_per_s"] is None
    assert d["stale_in_bytes"] == big.nbytes
    assert d["benefit_s"] > 0.0 and pol.should_offload(s)
    # an observed wire sample flips the reported source and feeds the rate
    cm.observe_bandwidth("local", "cloud", nbytes=1e6, seconds=0.001)
    d2 = pol.explain(s)
    assert d2["bw_source"] == "observed"
    assert d2["bw_bytes_per_s"] == pytest.approx(1e9)
    # once staged, the stale footprint the decision charges drops to zero
    mdss.ensure(["x"], "cloud")
    assert pol.explain(s)["stale_in_bytes"] == 0


def test_put_many_fences_absent_entry_with_nonzero_expectation():
    tiers = default_tiers()
    m = MDSS(tiers, cost_model=CostModel(tiers))
    # absent entry + nonzero expectation: stale expectation, must fence
    assert m.put_many({"ghost": np.zeros(2)}, tier="local",
                      expect_versions={"ghost": 3}) is None
    assert m.fenced_puts == 1
    assert m.version("ghost") == 0, "fenced batch mutated the store"
    # absent entry + zero expectation: a legitimate first write
    got = m.put_many({"ghost": np.zeros(2)}, tier="local",
                     expect_versions={"ghost": 0})
    assert got == {"ghost": 1}
    # all-or-nothing: one stale member fences the whole batch
    assert m.put_many({"ghost": np.ones(2), "other": np.ones(2)},
                      tier="local",
                      expect_versions={"ghost": 0, "other": 0}) is None
    assert m.version("ghost") == 1 and m.version("other") == 0


def test_namespaced_fence_tokens_block_cross_boundary_collision():
    """shared/u at v1 and a later private run/u at v1 must not satisfy
    the same fence: a speculation loser snapshotting against the shared
    entry cannot republish over the winner's private copy."""
    tiers = default_tiers()
    base = MDSS(tiers, cost_model=CostModel(tiers))
    base.put("shared/u", np.float64(0.0), tier="local")     # shared v1
    view = base.namespaced("run1", shared="shared")
    tokens = view.fence_tokens(["u"])
    assert tokens["u"] == ("shared/u", 1, 0)
    # the winner publishes: resolution still shared/u v1 -> fence passes
    assert view.put_many({"u": np.float64(1.0)}, tier="local",
                         expect_versions=tokens) is not None
    assert base.version("run1/u") == 1
    # the loser re-fences with the SAME stale tokens: the resolution has
    # moved to the private copy (also v1) — bare numbers would pass here
    assert view.put_many({"u": np.float64(2.0)}, tier="local",
                         expect_versions=tokens) is None
    assert float(view.get("u", "local")) == 1.0, "loser clobbered winner"
    # int compat path still works for in-run WAW fencing
    assert view.put_many({"u": np.float64(3.0)}, tier="local",
                         expect_versions={"u": 1}) is not None


def test_fenced_write_back_cannot_resurrect_dropped_namespace():
    """A draining step's publish after drop_namespace must be refused
    (epoch fence), while a NEW submission reusing the namespace name
    snapshots the new epoch and writes normally."""
    tiers = default_tiers()
    base = MDSS(tiers, cost_model=CostModel(tiers))
    view = base.namespaced("job", shared="shared")
    # an in-flight step snapshots tokens for its never-written output
    tokens = view.fence_tokens(["out"])
    assert tokens["out"] == ("job/out", 0, 0)
    base.drop_namespace("job")                 # release() while draining
    assert view.put_many({"out": np.ones(1024)}, tier="local",
                         expect_versions=tokens) is None
    assert base.namespace_entries("job") == [], \
        "write-back resurrected the dropped namespace"
    # deliberate reuse of the name: fresh tokens carry the new epoch
    fresh = view.fence_tokens(["out"])
    assert fresh["out"] == ("job/out", 0, 1)
    assert view.put_many({"out": np.zeros(2)}, tier="local",
                         expect_versions=fresh) is not None


def test_broker_priority_classes():
    Fabric = pytest.importorskip("repro.cloud").Fabric
    order = []
    with Fabric(workers=1) as fabric:
        blocker = fabric.broker.submit(step="spin",
                                       kwargs={"seconds": 0.3})
        time.sleep(0.05)           # ensure the worker is busy on blocker
        low = fabric.broker.submit(step="spin", kwargs={"seconds": 0.01})
        high = fabric.broker.submit(step="spin", kwargs={"seconds": 0.01},
                                    priority=1)
        low.add_done_callback(lambda t: order.append("low"))
        high.add_done_callback(lambda t: order.append("high"))
        blocker.result(30)
        low.result(30)
        high.result(30)
    assert order == ["high", "low"], \
        "interactive-class task did not overtake the queued batch task"


def test_duplicate_done_does_not_double_decrement_indegrees():
    """Regression: a duplicate "done" harvest (a speculation loser
    surfacing after the winner, or a replayed message) must be ignored —
    before the `_outstanding` guard it double-decremented successor
    in-degrees, dispatching a join step while its slow input was still
    in flight, and corrupted the lane-slot accounting."""
    wf = Workflow("dupdone")
    wf.var("x")
    wf.step("a", sleeper("a", 0.01, "ya"), inputs=("x",), outputs=("ya",),
            remotable=True, jax_step=False)
    wf.step("y", sleeper("y", 0.4, "yy"), inputs=("x",), outputs=("yy",),
            remotable=True, jax_step=False)
    wf.step("z", lambda ya, yy: {"z": np.float64(float(ya) + float(yy))},
            inputs=("ya", "yy"), outputs=("z",), remotable=True,
            jax_step=False)
    rt = EmeraldRuntime(emerald(), max_workers=2)
    try:
        h = rt.submit(wf, {"x": np.float64(1.0)})
        deadline = time.monotonic() + 10
        while not any(e.kind == "step_done" and e.step == "a"
                      for e in list(h.events)):
            assert time.monotonic() < deadline, "step a never completed"
            time.sleep(0.005)
        # replay a's completion while y is still in flight
        rt._inbox.put(("done", h.run_id, "a", None, True))
        out = h.result(30)
        assert float(out["z"]) == 4.0, "join step read a hole"
        dones = [e for e in h.events
                 if e.kind == "step_done" and e.step == "a"]
        assert len(dones) == 1, "duplicate step_done emitted"
    finally:
        rt.close()
    assert rt._busy == {True: 0, False: 0}, \
        "duplicate done corrupted lane-slot accounting"


def test_checkpoint_writes_off_driver_with_completion_fence(tmp_path):
    """Checkpoint pickles run on the dedicated writer lane, the driver
    keeps serving other tenants while a write blocks, and a run's handle
    only resolves after its final checkpoint is durable."""
    from repro.core.runtime import RunCheckpointer

    gate = threading.Event()

    class BlockingCkpt(RunCheckpointer):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.threads = []
            self.writes = []

        def _save_checkpoint(self, completed):
            self.threads.append(threading.current_thread().name)
            assert gate.wait(10), "test gate never opened"
            super()._save_checkpoint(completed)
            self.writes.append(set(completed))

    mgr = emerald()
    with EmeraldRuntime(mgr, max_workers=4) as rt:
        wfa = chain_wf("cka", 3, 0.02)
        ck = BlockingCkpt(
            rt.mdss.namespaced("nsa", shared=rt.shared_namespace), wfa,
            str(tmp_path), ckpt_name="nsa.cka")
        h = rt.submit(wfa, {"x": np.float64(1.0)}, namespace="nsa",
                      checkpointer=ck)
        # while A's first write is parked on the gate, another tenant's
        # whole run completes: the driver loop is not serialized by the
        # pickle (it used to be)
        hb = rt.submit(chain_wf("ckb", 3, 0.01), {"x": np.float64(1.0)})
        assert float(hb.result(10)["y3"]) == 8.0
        # let every step of A finish while the first write stays gated,
        # so the dirt provably coalesces into ONE follow-up write
        deadline = time.monotonic() + 10
        while sum(1 for e in list(h.events) if e.kind == "step_done") < 3:
            assert time.monotonic() < deadline, "run A never finished"
            time.sleep(0.005)
        assert not h.done(), "run resolved before its checkpoint landed"
        gate.set()
        assert float(h.result(10)["y3"]) == 8.0
        # completion fence: the last write that hit disk covers the whole
        # run, and it happened on the checkpoint lane, not the driver
        assert ck.writes and ck.writes[-1] == {"s1", "s2", "s3"}
        assert all("ckpt" in t for t in ck.threads), ck.threads
        # coalescing: completions that landed while the writer was
        # blocked merged into one write instead of queueing three
        assert len(ck.writes) < 3
        import pickle as _pickle
        with open(tmp_path / "nsa.cka.wfckpt", "rb") as f:
            state = _pickle.load(f)
        assert set(state["completed"]) == {"s1", "s2", "s3"}


def test_flush_orphaned_inbox_resolves_raced_submit():
    """A submit that raced close() (entry check passed, driver already
    exited) must resolve with RuntimeClosed instead of hanging — the
    dead-driver inbox flush owns it."""
    from types import SimpleNamespace
    from repro.core import RuntimeClosed
    from repro.core.runtime import RunHandle

    rt = EmeraldRuntime(emerald())
    rt.close()
    assert not rt._driver.is_alive()
    handle = RunHandle("raced#1", "", rt, [])
    rt._inbox.put(("submit", SimpleNamespace(handle=handle)))
    rt._flush_orphaned_inbox()
    assert handle.done() and handle.state == "failed"
    with pytest.raises(RuntimeClosed):
        handle.result(1)


def test_autoscaler_sees_runtime_backlog():
    from repro.cloud.autoscaler import Autoscaler, AutoscalerConfig

    class StubBroker:
        def queue_depth(self):
            return 0

        def num_workers(self, include_warm=False):
            return 1

        def avg_task_seconds(self):
            return None

    cfg = AutoscalerConfig(min_workers=1, max_workers=4, queue_high=2.0)
    sc = Autoscaler(StubBroker(), cfg)
    assert sc.desired_workers() == 1          # no pressure anywhere
    sc.backlog_fn = lambda: 10                # cross-run ready offloads
    assert sc.desired_workers() == 4          # aggregate pressure scales up


def test_runtime_offload_backlog_counts_ready_steps():
    with EmeraldRuntime(emerald(), max_workers=2) as rt:
        assert rt.offload_backlog() == 0
        h = rt.submit(wide_wf("backlog", 8, 0.05), {"x": np.float64(0.0)})
        deadline = time.monotonic() + 5
        seen = 0
        while time.monotonic() < deadline:
            now = rt.offload_backlog()
            # capped at lane width: the broker can't be fed more than that
            assert now <= rt.max_workers
            seen = max(seen, now)
            if seen >= 2:
                break
            time.sleep(0.005)
        assert seen >= 2, "ready-but-unlaned steps not visible as backlog"
        h.result(30)
        assert rt.offload_backlog() == 0
