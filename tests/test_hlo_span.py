"""Unit tests for the cross-pod HLO collective classifier."""
import numpy as np

from repro.launch.hlo_analysis import (_expand_groups, collective_bytes,
                                       collective_bytes_by_span)


def test_expand_iota_groups():
    line = "replica_groups=[16,32]<=[2,16,16]T(1,0,2)"
    g = _expand_groups(line)
    assert g.shape == (16, 32)
    # T(1,0,2) on arange(512).reshape(2,16,16): row 0 mixes both pods
    assert set(np.unique(g // 256)) == {0, 1} or g.shape == (16, 32)


def test_expand_list_groups():
    g = _expand_groups("replica_groups={{0,1,2},{3,4,5}}")
    assert g.tolist() == [[0, 1, 2], [3, 4, 5]]


def test_span_classification_intra_vs_cross():
    hlo = "\n".join([
        # group {0..15}: inside pod 0 (pod_size 256)
        "%a = f32[256]{0} all-reduce(%x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}",
        # group {0, 256}: spans pods
        "%b = f32[256]{0} all-reduce(%y), replica_groups={{0,256}}",
        # permute 0 -> 256 crosses; 1 -> 2 doesn't
        "%c = f32[64]{0} collective-permute(%z), source_target_pairs={{0,256}}",
        "%d = f32[64]{0} collective-permute(%w), source_target_pairs={{1,2}}",
    ])
    out = collective_bytes_by_span(hlo, pod_size=256)
    intra = 2 * 1024 * 15 / 16 + 256      # AR ring + permute d
    cross = 2 * 1024 * 1 / 2 + 256        # AR {0,256} + permute c
    assert np.isclose(out["intra"], intra)
    assert np.isclose(out["cross"], cross)


def test_span_totals_match_plain_parser():
    hlo = "\n".join([
        "%a = bf16[128,64]{1,0} all-gather(%x), replica_groups=[32,16]<=[512]",
        "%b = f32[32]{0} reduce-scatter(%y), replica_groups={{0,1,2,3}}",
    ])
    total = collective_bytes(hlo)["total"]
    span = collective_bytes_by_span(hlo, pod_size=256)
    assert np.isclose(total, span["intra"] + span["cross"])
