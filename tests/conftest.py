import os
import sys

# Tests must see the default (single) CPU device — only the dry-run sets
# xla_force_host_platform_device_count (see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.configs.base import ModelConfig, RunConfig, ShapeProfile


def tiny_dense(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
                dtype="float32", param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def dense_cfg():
    return tiny_dense()


@pytest.fixture
def train_shape():
    return ShapeProfile("t", 16, 2, "train")


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def make_emerald(policy="annotate", **kw):
    from repro.core import (CostModel, EmeraldExecutor, MDSS,
                            MigrationManager, default_tiers)
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    return tiers, cm, mdss, mgr


# --------------------------------------------------------------------------
# opt-in happens-before hazard sanitizer (repro.analysis.sanitizer):
# --sanitize / EMERALD_SANITIZE=1 replays every runtime submission's event
# log and every store's replica log at test teardown, turning the whole
# suite into a race detector. Zero hazards is the pass criterion.
# --------------------------------------------------------------------------
def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="run the happens-before hazard sanitizer over every "
             "EmeraldRuntime submission (also: EMERALD_SANITIZE=1)")


@pytest.fixture(autouse=True)
def hazard_sanitizer(request, monkeypatch):
    if not (request.config.getoption("--sanitize")
            or os.environ.get("EMERALD_SANITIZE")):
        yield
        return
    from repro.analysis import sanitizer
    from repro.core.runtime import EmeraldRuntime

    records = []          # (runtime, handle) per submission in this test
    orig = EmeraldRuntime.submit

    def spying_submit(self, workflow, *a, **kw):
        h = orig(self, workflow, *a, **kw)
        records.append((self, h))
        return h

    monkeypatch.setattr(EmeraldRuntime, "submit", spying_submit)
    yield
    findings = []
    stores = {}
    for rt, h in records:
        # replay every settled run: duplicate dones (H101) and orphan
        # completions (H102) are hazards on failed/cancelled runs too —
        # only the full dispatch/done pairing (H103 lost-completion) is
        # reserved for runs that finished cleanly. Still-running
        # handles (a test that abandoned its submission) are skipped:
        # their event streams are legitimately mid-flight.
        state = getattr(h, "state", "")
        if state not in ("done", "failed", "cancelled"):
            continue
        findings += sanitizer.check(h.events,
                                    completed_run=(state == "done"))
        stores[id(rt.mdss)] = rt.mdss
    for mdss in stores.values():
        findings += sanitizer.check_store(mdss)
    if findings:
        pytest.fail("hazard sanitizer: "
                    + "; ".join(str(f) for f in findings), pytrace=False)
