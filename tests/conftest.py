import os
import sys

# Tests must see the default (single) CPU device — only the dry-run sets
# xla_force_host_platform_device_count (see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.configs.base import ModelConfig, RunConfig, ShapeProfile


def tiny_dense(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
                dtype="float32", param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def dense_cfg():
    return tiny_dense()


@pytest.fixture
def train_shape():
    return ShapeProfile("t", 16, 2, "train")


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def make_emerald(policy="annotate", **kw):
    from repro.core import (CostModel, EmeraldExecutor, MDSS,
                            MigrationManager, default_tiers)
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    return tiers, cm, mdss, mgr
