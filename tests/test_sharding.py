"""Sharding-rule resolution tests (no multi-device mesh needed for the pure
resolution logic — a fake Mesh shape dict suffices via a stub)."""
import types

import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import DP_TP_RULES, FSDP_RULES, get_rules, resolve


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_tp_resolution():
    spec = resolve(DP_TP_RULES, ("embed", "ff"), (1024, 4096), MESH)
    assert spec == P(None, "model")


def test_batch_over_pod_and_data():
    spec = resolve(DP_TP_RULES, ("act_batch", None, None), (256, 4, 4), MESH_POD)
    assert spec == P(("pod", "data"))


def test_batch_partial_when_pod_absent():
    spec = resolve(DP_TP_RULES, ("act_batch",), (256,), MESH)
    assert spec == P("data")


def test_divisibility_fallback_replicates():
    # kv_heads=8 can't shard over a 16-way axis -> replicated
    rules = dict(DP_TP_RULES, kv_heads=("model",))
    spec = resolve(rules, ("embed", "kv_heads", None), (1024, 8, 128), MESH)
    assert spec == P()


def test_divisibility_fallback_keeps_other_dims():
    rules = dict(DP_TP_RULES, kv_heads=("model",))
    spec = resolve(rules, ("kv_heads", "ff"), (8, 4096), MESH)
    assert spec == P(None, "model")


def test_each_mesh_axis_used_once():
    # two dims both wanting 'model': first wins, second replicates
    spec = resolve(DP_TP_RULES, ("ff", "vocab"), (4096, 32000), MESH)
    assert spec == P("model")           # trailing None trimmed


def test_fsdp_shards_embed_over_data():
    spec = resolve(FSDP_RULES, ("embed", "ff"), (4096, 8192), MESH)
    assert spec == P("data", "model")


def test_batch_not_divisible_replicates():
    # long_500k: global_batch=1
    spec = resolve(FSDP_RULES, ("act_batch", "act_kv_seq"), (1, 524288), MESH)
    assert spec == P(None, "model")


def test_overrides():
    rules = get_rules("fsdp", overrides=(("act_batch",
                                          ("pod", "data", "model")),))
    spec = resolve(rules, ("act_batch", None), (256, 4), MESH)
    assert spec == P(("data", "model"))


def test_override_removal():
    rules = get_rules("fsdp", overrides=(("embed", ()),))
    spec = resolve(rules, ("embed", "ff"), (4096, 8192), MESH)
    assert spec == P(None, "model")


def test_multi_axis_dim():
    rules = {"act_batch": ("pod", "data")}
    spec = resolve(rules, ("act_batch",), (64,), MESH_POD)
    assert spec == P(("pod", "data"))
    # 2*16=32 divides 64; with batch 2 only 'pod' fits
    spec2 = resolve(rules, ("act_batch",), (2,), MESH_POD)
    assert spec2 == P("pod")
