"""Paper §3.4 (Fig 10): MDSS reduces network transfer on repeated offloads.

Measures bytes moved per offload of the same step, with MDSS residency
(paper) vs a naive runtime that re-ships application data on every offload
(the paper's strawman: "application data and task code are bundled and
transferred when a remotable step is offloaded").
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import (CostModel, EmeraldExecutor, MDSS, MigrationManager,
                        Workflow, default_tiers, partition)

MB = 1024 * 1024


def build(data_mb: int = 8):
    tiers = default_tiers()
    cm = CostModel(tiers)
    mdss = MDSS(tiers, cost_model=cm)
    mgr = MigrationManager(tiers, mdss, cm)
    wf = Workflow("mdss-bench")
    wf.var("data")
    wf.step("process", lambda data: {"stat": jnp.sum(data)},
            inputs=("data",), outputs=("stat",), remotable=True)
    ex = EmeraldExecutor(partition(wf), mgr)
    data = jnp.ones((data_mb * MB // 4,), jnp.float32)
    return ex, mdss, data


def main() -> List[str]:
    rows = []
    n_offloads = 10
    data_mb = 8
    # --- with MDSS (paper): data uploaded once, then code-only ------------
    ex, mdss, data = build(data_mb)
    ex.run({"data": data}, fetch=("stat",))
    first = mdss.total_bytes_moved()
    for _ in range(n_offloads - 1):
        ex.run({}, fetch=("stat",))
    with_mdss = mdss.total_bytes_moved()
    # --- naive: every offload ships the data ------------------------------
    naive = n_offloads * data.nbytes
    rows.append(row("mdss_bytes_first_offload", first / 1e9, f"{first}B"))
    rows.append(row("mdss_bytes_total_10_offloads", with_mdss / 1e9,
                    f"{with_mdss}B"))
    rows.append(row("naive_bytes_total_10_offloads", naive / 1e9,
                    f"{naive}B"))
    red = 1 - with_mdss / naive
    rows.append(row("mdss_transfer_reduction", 0.0, f"{red * 100:.1f}%"))
    # modeled seconds saved on the paper's 1 GB/s WAN
    saved_s = (naive - with_mdss) / 1e9
    rows.append(row("mdss_wan_seconds_saved_10_offloads", saved_s, "at 1GB/s"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))

EMLINT_WORKFLOWS = [lambda: build(1)[0].pwf.workflow]   # emlint targets
